"""Topology-change exactness: split/merge must be pure re-partitions.

The contract (DESIGN.md §7): ``split_shard`` / ``merge_shards`` change
*where* points live, never *what* the index answers.  In the
insert-only regime the read-outs are bit-identical to a
never-rebalanced index (labels keep their minted ids -- the witness
-edge rebuild preserves identity even for clusters straddling the new
cut); in the localized regime (after any delete) ids may re-mint but
the partition and the core flags stay exact.  Replicas replay the
primary's mutation log -- topology ops included -- and serve
bit-identically; the rebalancer only ever applies these two ops, so
its policy layer is tested here too.
"""

import numpy as np
import pytest

from repro.dist.rebalance import RebalancePolicy, Rebalancer
from repro.index import ReplicaIndex, fit_index, fit_sharded, make_replicas

EPS, MIN_PTS = 0.6, 6


def canon(labels):
    """Canonical partition form: label -> first-occurrence rank."""
    out = np.full(len(labels), -1, np.int64)
    m = {}
    for i, v in enumerate(labels):
        if v >= 0:
            out[i] = m.setdefault(int(v), len(m))
    return out


@pytest.fixture()
def blobs():
    rng = np.random.default_rng(7)
    return np.concatenate([
        rng.normal((0, 0), 1.0, (400, 2)),
        rng.normal((8, 1), 1.2, (400, 2)),
        rng.normal((4, -3), 0.8, (300, 2)),
    ])


@pytest.fixture()
def pair(blobs):
    """(mutated, reference) -- two identical sharded fits; topology ops
    are applied to the first, the second never rebalances."""
    return (fit_sharded(blobs, EPS, MIN_PTS, n_shards=3),
            fit_sharded(blobs, EPS, MIN_PTS, n_shards=3))


class TestSplitMergeExactness:
    def test_split_is_bit_identical(self, pair):
        sidx, ref = pair
        st = sidx.split_shard(1)
        assert st["num_shards"] == 4
        assert st["n_left"] > 0 and st["n_right"] > 0
        assert np.array_equal(sidx.labels_arrival(), ref.labels_arrival())
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())

    def test_merge_is_bit_identical(self, pair):
        sidx, ref = pair
        st = sidx.merge_shards(0)
        assert st["num_shards"] == 2
        assert np.array_equal(sidx.labels_arrival(), ref.labels_arrival())
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())

    def test_split_merge_round_trip_restores_topology(self, pair):
        sidx, ref = pair
        cuts0 = sidx.cuts.copy()
        st = sidx.split_shard(1)
        assert len(sidx.cuts) == len(cuts0) + 1
        st2 = sidx.merge_shards(1)
        assert st2["cut"] == st["cut"]
        assert np.array_equal(sidx.cuts, cuts0)
        assert np.array_equal(sidx.labels_arrival(), ref.labels_arrival())
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())
        assert [op for op, _, _ in sidx.cut_history] == ["split", "merge"]

    def test_split_straddling_cross_cut_cluster(self):
        """A dense strip crossing the new cut: the split separates one
        cluster's members into both sub-shards, and the witness-edge
        rebuild must stitch them back to ONE label, bit-identical to
        the never-split labels."""
        rng = np.random.default_rng(3)
        strip = np.column_stack([rng.uniform(0.0, 10.0, 2000),
                                 rng.normal(0.0, 0.3, 2000)])
        sidx = fit_sharded(strip, EPS, MIN_PTS, n_shards=2)
        ref = fit_sharded(strip, EPS, MIN_PTS, n_shards=2)
        # one connected cluster spanning both slabs
        labs = ref.labels_arrival()
        assert len(np.unique(labs[labs >= 0])) == 1
        st = sidx.split_shard(0)
        # the new cut lands inside the strip -> the cluster straddles it
        assert 0.0 < st["cut"] < 10.0
        assert np.array_equal(sidx.labels_arrival(), labs)
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())
        st2 = sidx.split_shard(2)
        assert 0.0 < st2["cut"] < 10.0
        assert np.array_equal(sidx.labels_arrival(), labs)

    def test_insert_into_locally_disconnected_cluster(self):
        """A U-shaped cluster whose arms connect only through a bridge
        OUTSIDE a slab's pooled view: inside that shard one global
        cluster id spans two *local* components.  The first insert
        re-runs component labeling there and must not write the
        split-cluster sentinel (-2) into border rows -- they take the
        from-scratch border test and stay bit-identical to the
        never-sharded reference (both straight after the sharded fit
        and after a further split)."""
        xs = np.arange(0.0, 10.05, 0.1)
        ys = np.arange(0.2, 5.85, 0.1)
        u = np.concatenate([
            np.column_stack([xs, np.zeros_like(xs)]),       # bottom arm
            np.column_stack([xs, np.full_like(xs, 6.0)]),   # top arm
            np.column_stack([np.full_like(ys, 10.0), ys]),  # bridge
            [[2.05, -0.59], [5.05, -0.59], [8.05, -0.59]],  # borders
        ])
        single = fit_index(u, EPS, MIN_PTS, engine="grit")
        labs = single.labels_arrival()
        assert len(np.unique(labs[labs >= 0])) == 1  # one U cluster
        assert (~single.core_arrival()[-3:]).all()   # borders non-core
        for pre_split in (False, True):
            ref = fit_index(u, EPS, MIN_PTS, engine="grit")
            sidx = fit_sharded(u, EPS, MIN_PTS, n_shards=2)
            # the bridge is beyond shard 0's ghost band: its pooled
            # view holds the one cluster as two local components
            assert sidx.cuts[0] < 10.0 - 2 * EPS
            if pre_split:
                sidx.split_shard(0)
            batch = np.asarray([[1.0, 0.05], [3.0, 5.95]])
            ref.insert(batch)
            sidx.insert(batch)
            out = sidx.labels_arrival()
            assert out.min() >= -1   # no -2 sentinel leaked
            assert np.array_equal(out, ref.labels_arrival())
            assert np.array_equal(sidx.core_arrival(),
                                  ref.core_arrival())

    def test_predict_stream_identical_after_ops(self, pair, blobs):
        sidx, ref = pair
        rng = np.random.default_rng(11)
        q = rng.normal((4, -1), 3.0, (300, 2))
        sidx.split_shard(1)
        assert np.array_equal(sidx.predict(q), ref.predict(q))
        sidx.merge_shards(1)
        assert np.array_equal(sidx.predict(q), ref.predict(q))

    def test_ops_compose_with_inserts(self, pair):
        """insert -> split -> insert -> merge stays identical to the
        same inserts on a static topology."""
        sidx, ref = pair
        rng = np.random.default_rng(5)
        b1 = rng.normal((8, 1), 1.2, (60, 2))
        b2 = rng.normal((0, 0), 1.0, (60, 2))
        sidx.insert(b1); ref.insert(b1)
        sidx.split_shard(2)
        sidx.insert(b2); ref.insert(b2)
        sidx.merge_shards(2)
        assert np.array_equal(sidx.labels_arrival(), ref.labels_arrival())
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())

    def test_localized_regime_partition_exact(self, pair):
        """After a delete (localized shards) topology ops re-mint ids;
        the partition and core flags must stay exact."""
        sidx, ref = pair
        dead = np.arange(0, 80, dtype=np.int64)
        sidx.delete(dead); ref.delete(dead)
        assert sidx.localized
        sidx.split_shard(1)
        sidx.merge_shards(1)
        assert np.array_equal(canon(sidx.labels_arrival()),
                              canon(ref.labels_arrival()))
        assert np.array_equal(sidx.core_arrival(), ref.core_arrival())

    def test_snapshot_split_merge_restore_round_trip(self, pair):
        """The satellite round-trip: snapshot -> split -> merge ->
        restore, read-outs bit-identical to never-rebalanced."""
        import repro.index.sharded as sh
        sidx, ref = pair
        snap = sidx.snapshot()
        back = sh.ShardedGritIndex.restore(snap)
        back.split_shard(1)
        back.merge_shards(1)
        snap2 = back.snapshot()
        final = sh.ShardedGritIndex.restore(snap2)
        assert np.array_equal(final.labels_arrival(),
                              ref.labels_arrival())
        assert np.array_equal(final.core_arrival(), ref.core_arrival())
        assert final.cut_history == back.cut_history


class TestTopologyValidation:
    def test_split_out_of_range(self, pair):
        with pytest.raises(ValueError):
            pair[0].split_shard(7)

    def test_merge_needs_adjacent(self, pair):
        sidx, _ = pair
        with pytest.raises(ValueError):
            sidx.merge_shards(0, 2)
        with pytest.raises(ValueError):
            sidx.merge_shards(2)      # k+1 out of range

    def test_unsplittable_single_column(self):
        rng = np.random.default_rng(0)
        pts = np.column_stack([5.0 + 0.1 * rng.random(60),
                               rng.normal(0, 3.0, 60)])
        sidx = fit_sharded(pts, 1.0, 3, n_shards=2)
        with pytest.raises(ValueError, match="unsplittable|no interior"):
            sidx.split_shard(0)


class TestReplica:
    def test_requires_mutation_log(self, blobs):
        idx = fit_index(blobs, EPS, MIN_PTS)
        with pytest.raises(ValueError, match="enable_mutation_log"):
            ReplicaIndex(idx)

    def test_replay_is_bit_identical(self, blobs):
        rng = np.random.default_rng(2)
        idx = fit_index(blobs[:900], EPS, MIN_PTS)
        idx.enable_mutation_log()
        rep = ReplicaIndex(idx)
        idx.insert(blobs[900:1000])
        idx.insert(blobs[1000:])
        idx.delete(np.arange(30, dtype=np.int64))
        assert rep.lag == 3
        assert rep.catch_up() == 3
        assert rep.lag == 0
        assert np.array_equal(rep.labels_arrival(), idx.labels_arrival())
        assert np.array_equal(rep.core_arrival(), idx.core_arrival())
        q = rng.normal((4, -1), 3.0, (200, 2))
        assert np.array_equal(rep.predict(q), idx.predict(q))

    def test_sharded_replica_replays_topology(self, blobs):
        rng = np.random.default_rng(4)
        sp = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        reps = make_replicas(sp, 2)
        sp.insert(rng.normal((8, 1), 1.2, (50, 2)))
        sp.split_shard(0)
        sp.insert(rng.normal((0, 0), 1.0, (50, 2)))
        sp.merge_shards(0)
        q = rng.normal((4, -1), 3.0, (200, 2))
        want = sp.predict(q)
        for rep in reps:
            assert np.array_equal(rep.predict(q), want)     # catches up
            assert np.array_equal(rep.labels_arrival(),
                                  sp.labels_arrival())
            assert rep.index.cut_history == sp.cut_history
            assert rep.lag == 0

    def test_read_only(self, blobs):
        idx = fit_index(blobs, EPS, MIN_PTS)
        idx.enable_mutation_log()
        rep = ReplicaIndex(idx)
        with pytest.raises(TypeError, match="read-only"):
            rep.insert(blobs[:2])
        with pytest.raises(TypeError, match="read-only"):
            rep.delete(np.asarray([0]))

    def test_stale_cursor_rejected(self, blobs):
        idx = fit_index(blobs, EPS, MIN_PTS)
        log = idx.enable_mutation_log()
        rep = ReplicaIndex(idx)
        idx.insert(blobs[:10] + 100.0)
        log.truncate(log.end)           # primary drops replayed history
        rep.cursor = 0
        with pytest.raises(ValueError, match="re-clone"):
            rep.catch_up()

    def test_log_truncate_keeps_live_suffix(self, blobs):
        idx = fit_index(blobs, EPS, MIN_PTS)
        log = idx.enable_mutation_log()
        rep = ReplicaIndex(idx)
        idx.insert(blobs[:10] + 100.0)
        idx.insert(blobs[10:20] + 100.0)
        rep.catch_up()
        idx.insert(blobs[20:30] + 100.0)
        assert log.truncate(rep.cursor) == 2
        assert rep.catch_up() == 1      # suffix still replayable
        assert np.array_equal(rep.labels_arrival(), idx.labels_arrival())


class TestRebalancer:
    def test_splits_hottest_after_period(self, blobs):
        sidx = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        rb = Rebalancer(RebalancePolicy(period=2, hot_factor=2.0))
        loads = [100.0, 10.0, 10.0]
        rb.observe(loads)
        assert rb.maybe_rebalance(sidx) is None   # inside the period
        rb.observe(loads)
        st = rb.maybe_rebalance(sidx)
        assert st is not None and st["op"] == "split" and st["shard"] == 0
        assert sidx.num_shards == 4
        assert rb.history == [st]
        assert rb.load is None                    # re-learns post-op

    def test_merges_coldest_adjacent_pair(self, blobs):
        sidx = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        rb = Rebalancer(RebalancePolicy(period=1, hot_factor=100.0,
                                        cold_factor=0.5))
        rb.observe([100.0, 1.0, 2.0])
        rb.steps = rb.policy.period + 1
        st = rb.maybe_rebalance(sidx)
        assert st is not None and st["op"] == "merge" and st["shard"] == 1
        assert sidx.num_shards == 2

    def test_no_op_when_balanced(self, blobs):
        sidx = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        rb = Rebalancer(RebalancePolicy(period=1))
        for _ in range(4):
            rb.observe([10.0, 11.0, 9.0])
        assert rb.maybe_rebalance(sidx) is None
        assert sidx.num_shards == 3

    def test_respects_max_shards(self, blobs):
        sidx = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        rb = Rebalancer(RebalancePolicy(period=1, max_shards=3))
        for _ in range(3):
            rb.observe([100.0, 1.0, 1.0])
        assert rb.maybe_rebalance(sidx) is None or \
            rb.history[0]["op"] != "split"
        assert sidx.num_shards <= 3

    def test_shard_count_change_resets_ewma(self):
        rb = Rebalancer()
        rb.observe([1.0, 2.0, 3.0])
        rb.observe([10.0, 20.0])      # topology changed under us
        assert np.array_equal(rb.load, [10.0, 20.0])

    def test_imbalance_gauge_math(self):
        rb = Rebalancer()
        rb.observe([30.0, 10.0, 20.0])
        assert rb.imbalance() == pytest.approx(30.0 / 20.0)

    def test_unsplittable_falls_through(self):
        # shard 0: one dim-0 grid column (unsplittable); shard 1: spread
        rng = np.random.default_rng(0)
        pts = np.column_stack([
            np.concatenate([5.0 + 0.1 * rng.random(60),
                            rng.uniform(20.0, 30.0, 60)]),
            rng.normal(0, 3.0, 120)])
        sidx = fit_sharded(pts, 1.0, 3, n_shards=2)
        assert sidx.num_shards == 2
        # hot_factor low enough to beat the 2-shard median (which the
        # hot shard itself drags up to 50.5)
        rb = Rebalancer(RebalancePolicy(period=1, hot_factor=1.5,
                                        cold_factor=0.0))
        for _ in range(3):
            rb.observe([100.0, 1.0])
        assert rb.maybe_rebalance(sidx) is None   # split raises, no merge
        assert 0 in rb._unsplittable


class TestServeIntegration:
    def _serve(self, blobs, **kw):
        from repro.serve.driver import ClusterServer
        sidx = fit_sharded(blobs, EPS, MIN_PTS, n_shards=3)
        srv = ClusterServer(sidx, slots=2, **kw)
        rng = np.random.default_rng(9)
        for i in range(12):
            if i % 4 == 3:
                srv.submit_insert(rng.normal((8, 1), 1.2, (20, 2)))
            else:
                srv.submit(rng.normal((4, -1), 3.0, (30, 2)))
        return srv, srv.run()

    def test_slab_gauges_exported(self, blobs):
        srv, _ = self._serve(blobs)
        snap = srv.metrics.snapshot()
        gauges = snap.get("gauges", snap)
        names = str(list(gauges))
        assert "serve.slab.imbalance" in names
        assert "serve.slab.load.0" in names

    def test_rebalance_plane_applies_ops(self, blobs):
        srv, _ = self._serve(
            blobs, rebalance=RebalancePolicy(period=1, hot_factor=1.01,
                                             cold_factor=0.0))
        # aggressively low threshold -> at least one split happened
        assert srv.topology_events
        assert srv.index.num_shards > 3
        assert all(e["op"] == "split" for e in srv.topology_events)

    def test_rebalance_needs_topology_backend(self, blobs):
        from repro.serve.driver import ClusterServer
        idx = fit_index(blobs, EPS, MIN_PTS)
        with pytest.raises(ValueError, match="split_shard"):
            ClusterServer(idx, rebalance=True)

    def test_replicated_reads_match_primary_serving(self, blobs):
        """Same request stream through a replicated server and a
        plain one: identical labels on every request."""
        srv_a, done_a = self._serve(blobs)
        srv_b, done_b = self._serve(blobs, replicas=2)
        assert len(srv_b.replicas) == 2
        assert srv_b._rr > 0            # reads actually fanned out
        for ra, rb in zip(done_a, done_b):
            assert ra.kind == rb.kind
            if ra.kind == "predict":
                assert np.array_equal(ra.labels, rb.labels)
        assert np.array_equal(srv_a.index.labels_arrival(),
                              srv_b.index.labels_arrival())
