"""Mutation-plane conformance: the delta engine's ``delete`` (and its
interleaving with ``insert``) must leave both index flavors
label-conformant with a from-scratch ``cluster()`` on the *surviving*
set after every op -- DBSCAN is not monotone under deletion, so this
pins the whole touched-stencil / merge-graph / component-relabel
machinery, including cluster splits, core demotions, deletes below the
shifted origin, emptied grids and threshold compaction.  Also covers
the persistent merge graph (incremental maintenance ==
built-from-scratch), the v1/v2 snapshot compatibility and the unified
mutation stats schema.
"""

import io

import numpy as np
import pytest

from repro.core.dbscan import brute_dbscan
from repro.core.validate import assert_labels_conformant, core_flags
from repro.data.scenarios import churn_scenarios, get_churn_scenario
from repro.engine import cluster
from repro.index import (GritIndex, ShardedGritIndex, build_merge_graph,
                         fit_sharded)
from repro.index.delta import grid_components

CHURN = sorted(s.name for s in churn_scenarios())


def _fit_index(pts, eps, min_pts):
    return cluster(pts, eps, min_pts, engine="grit",
                   return_index=True).index


def _replay(index, ops, base, eps, min_pts, check_every=True):
    """Apply a churn op stream, checking conformance vs the brute
    oracle on the surviving set after every op (or only at the end)."""
    live = {i: p for i, p in enumerate(base)}
    nid = len(base)
    for t, (kind, payload) in enumerate(ops):
        if kind == "insert":
            st = index.insert(payload)
            assert st["inserted"] == len(payload)
            for p in payload:
                live[nid] = p
                nid += 1
        else:
            st = index.delete(payload)
            assert st["deleted"] == sum(int(i) in live for i in payload)
            for i in payload:
                live.pop(int(i), None)
        surv = np.array([live[i] for i in sorted(live)])
        np.testing.assert_array_equal(
            np.fromiter(sorted(live), np.int64, len(live)),
            index.arrival_live())
        if check_every or t == len(ops) - 1:
            ref = brute_dbscan(surv, eps, min_pts)
            assert_labels_conformant(surv, eps, min_pts, ref,
                                     index.labels_arrival())
            np.testing.assert_array_equal(
                index.core_arrival(), core_flags(surv, eps, min_pts))
    return live


# --------------------------------------------------------------------------
# churn scenarios: single-host and host-sharded
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", CHURN)
def test_churn_scenario_conformant(name):
    """Acceptance: every churn op leaves the read-out ≡ cluster() on
    the surviving set (single-host index)."""
    cs = get_churn_scenario(name)
    pts = cs.fit_points()
    eps, min_pts = cs.base.eps, cs.base.min_pts
    _replay(_fit_index(pts, eps, min_pts), cs.ops(), pts, eps, min_pts)


@pytest.mark.parametrize("name", CHURN)
def test_churn_scenario_conformant_sharded(name):
    """The same op streams through a host-sharded ShardedGritIndex
    (owner + ghost-copy removal, label-map rebuild on splits)."""
    cs = get_churn_scenario(name)
    pts = cs.fit_points()
    eps, min_pts = cs.base.eps, cs.base.min_pts
    sidx = fit_sharded(pts, eps, min_pts, n_shards=4, engine="grit")
    _replay(sidx, cs.ops(), pts, eps, min_pts)


def test_bridge_cut_splits_cluster_in_two():
    """Deleting a bridge must split the merged cluster back into two
    components -- the acceptance scenario for non-monotone deletion."""
    rng = np.random.default_rng(3)
    eps, min_pts = 5.0, 4
    left = np.array([20.0, 50.0]) + rng.normal(scale=1.5,
                                               size=(6 * min_pts, 2))
    right = np.array([80.0, 50.0]) + rng.normal(scale=1.5,
                                                size=(6 * min_pts, 2))
    base = np.concatenate([left, right])
    idx = _fit_index(base, eps, min_pts)
    assert len(set(idx.labels_arrival().tolist()) - {-1}) == 2
    t = np.linspace(0, 1, 60)[:, None]
    bridge = left[0] + t * (right[0] - left[0]) + rng.normal(
        scale=0.2, size=(60, 2))
    idx.insert(bridge)
    la = idx.labels_arrival()
    assert len(set(la[la >= 0].tolist())) == 1, "bridge must merge"
    st = idx.delete(np.arange(len(base), len(base) + 60))
    assert st["deleted"] == 60
    la = idx.labels_arrival()
    assert len(set(la[la >= 0].tolist())) == 2, "cut must split"
    ref = brute_dbscan(base, eps, min_pts)
    assert_labels_conformant(base, eps, min_pts, ref, la)


def test_delete_demotes_core_to_border_and_noise():
    """Thinning a neighborhood below MinPts must demote its cores, and
    the demoted rows must re-take the border test themselves."""
    rng = np.random.default_rng(5)
    eps, min_pts = 4.0, 6
    blob = np.full(2, 50.0) + rng.normal(scale=1.0, size=(40, 2))
    idx = _fit_index(blob, eps, min_pts)
    assert idx.core_arrival().all()
    keep_n = min_pts - 2
    kill = np.arange(keep_n, 40)
    st = idx.delete(kill)
    surv = blob[:keep_n]
    np.testing.assert_array_equal(idx.core_arrival(),
                                  core_flags(surv, eps, min_pts))
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx.labels_arrival())
    assert st["demoted"] > 0


def test_delete_below_origin_after_id_shift():
    """Insert below the fitted origin (lattice translation), then
    delete those same points: identifiers must keep resolving through
    the shifted lattice on both mutations."""
    rng = np.random.default_rng(7)
    eps, min_pts = 5.0, 4
    base = rng.uniform(40, 90, size=(120, 2))
    idx = _fit_index(base, eps, min_pts)
    below = base.min(axis=0) - 9 * eps + rng.uniform(
        0, 2 * eps, size=(4 * min_pts, 2))
    st = idx.insert(below)
    assert st["id_shifted"] and (idx.id_shift > 0).any()
    ids = np.arange(len(base), len(base) + len(below))
    st = idx.delete(ids[::2])
    surv = np.concatenate([base, below[1::2]])
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx.labels_arrival())
    st = idx.delete(ids[1::2])
    assert st["deleted"] == len(ids[1::2])
    ref = brute_dbscan(base, eps, min_pts)
    assert_labels_conformant(base, eps, min_pts, ref,
                             idx.labels_arrival())
    # old points still resolve to their stored (shifted) grids
    qids = idx.query_ids(idx.points[idx.alive])
    row_ids = np.repeat(idx.ids, idx.counts, axis=0)[idx.alive]
    np.testing.assert_array_equal(qids, row_ids)


def test_delete_everything_in_a_grid():
    """Emptying one grid outright (its rows all dead) must survive both
    the tombstone phase and the compaction that drops the grid."""
    rng = np.random.default_rng(9)
    eps, min_pts = 6.0, 4
    base = rng.uniform(0, 100, size=(150, 2))
    idx = _fit_index(base, eps, min_pts)
    g = int(np.argmax(idx.live_counts))
    rows = np.arange(idx.starts[g], idx.starts[g] + idx.counts[g])
    ids = idx.arrival[rows]
    grids_before = idx.num_grids
    st = idx.delete(ids)
    assert st["deleted"] == len(ids)
    surv = np.delete(base, ids, axis=0)
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx.labels_arrival())
    idx.compact()
    assert idx.num_grids < grids_before
    assert idx.n == idx.n_live == len(surv)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx.labels_arrival())


def test_delete_everything_then_reuse():
    rng = np.random.default_rng(11)
    eps, min_pts = 5.0, 4
    base = rng.uniform(0, 60, size=(80, 2))
    idx = _fit_index(base, eps, min_pts)
    idx.delete(np.arange(80))
    assert idx.n_live == 0
    assert (idx.predict(base[:7]) == -1).all()
    # and the empty index accepts fresh inserts
    blob = np.full(2, 30.0) + rng.normal(scale=0.8,
                                         size=(4 * min_pts, 2))
    idx.insert(blob)
    ref = brute_dbscan(blob, eps, min_pts)
    assert_labels_conformant(blob, eps, min_pts, ref,
                             idx.labels_arrival())


def test_delete_rejects_unknown_and_double_deletes():
    rng = np.random.default_rng(13)
    base = rng.uniform(0, 50, size=(60, 2))
    idx = _fit_index(base, 4.0, 4)
    st = idx.delete([3, 4, 10 ** 7, -5])
    assert st["deleted"] == 2 and st["rejected"] == 2
    assert set(st["rejected_ids"].tolist()) == {10 ** 7, -5}
    st = idx.delete([3, 4])                  # double delete: rejected
    assert st["deleted"] == 0 and st["rejected"] == 2
    st = idx.delete(np.zeros(0, np.int64))   # empty: full stats shape
    assert st["deleted"] == 0 and "t_total" in st \
        and "affected_grids" in st


@pytest.mark.parametrize("seed", range(4))
def test_churn_random_stress(seed):
    """Randomized insert/delete interleaving: bridges, jittered copies,
    fresh regions, then deletions of a random fifth of the live set --
    conformant vs the brute oracle after every step."""
    rng = np.random.default_rng(2000 + seed)
    eps, min_pts = 6.0, 4
    centers = rng.uniform(20, 80, size=(3, 2))
    base = np.concatenate([
        centers[rng.integers(0, 3, 90)] + rng.normal(scale=4.0,
                                                     size=(90, 2)),
        rng.uniform(0, 100, size=(20, 2)),
    ])
    idx = _fit_index(base, eps, min_pts)
    live = {i: p for i, p in enumerate(base)}
    nid = len(base)
    for _ in range(3):
        a, b = base[rng.integers(0, len(base), (2, 12))]
        batch = np.concatenate([
            a + rng.uniform(0, 1, size=(12, 1)) * (b - a),
            base[rng.integers(0, len(base), 8)] + rng.normal(
                scale=0.5 * eps, size=(8, 2)),
            rng.uniform(-15, 115, size=(8, 2)),
        ])
        idx.insert(batch)
        for p in batch:
            live[nid] = p
            nid += 1
        kill = rng.choice(sorted(live), size=len(live) // 5,
                          replace=False)
        idx.delete(kill)
        for k in kill:
            live.pop(int(k))
        surv = np.array([live[i] for i in sorted(live)])
        ref = brute_dbscan(surv, eps, min_pts)
        assert_labels_conformant(surv, eps, min_pts, ref,
                                 idx.labels_arrival())
        np.testing.assert_array_equal(
            idx.core_arrival(), core_flags(surv, eps, min_pts))


# --------------------------------------------------------------------------
# persistent merge graph
# --------------------------------------------------------------------------

def test_merge_graph_incremental_equals_from_scratch():
    """After arbitrary churn, the incrementally-maintained edge array
    must equal a from-scratch FastMerging decision over the same
    state -- the invariant everything above stands on."""
    cs = get_churn_scenario("churn-split-2d")
    pts = cs.fit_points()
    eps, min_pts = cs.base.eps, cs.base.min_pts
    idx = _fit_index(pts, eps, min_pts)
    for kind, payload in cs.ops():
        (idx.insert if kind == "insert" else idx.delete)(payload)
        fresh = GritIndex.restore(idx.snapshot())
        fresh.merge_edges = None
        np.testing.assert_array_equal(idx.merge_edges,
                                      build_merge_graph(fresh))


def test_merge_graph_bbox_covers_last_core_grid():
    """Regression: the batch edge evaluator's bbox tier must cover the
    *entire* last core-bearing grid even when zero-core grids sort
    after it (a clamped reduceat boundary used to shear that grid's
    final core row out of its bbox, falsely rejecting a true edge --
    and a later unrelated delete then split the cluster)."""
    eps, min_pts = 1.0, 3
    base = np.array([[0.04, 0.0], [0.05, 0.0], [0.06, 0.0],
                     [1.04, 0.0], [1.41, 0.0], [1.41, 0.01],
                     [5.0, 0.0]])              # lone noise, lex-last grid
    idx = _fit_index(base, eps, min_pts)
    edges = idx.ensure_merge_graph()
    assert len(edges) == 1, "the A-B core-grid edge must be found"
    st = idx.delete([6])                      # unrelated noise point
    assert st["deleted"] == 1
    la = idx.labels_arrival()
    assert len(set(la[la >= 0].tolist())) == 1, \
        "deleting unrelated noise must not split the cluster"
    ref = brute_dbscan(base[:6], eps, min_pts)
    assert_labels_conformant(base[:6], eps, min_pts, ref, la)


def test_grid_components_matches_bfs():
    rng = np.random.default_rng(17)
    G = 40
    edges = np.unique(np.sort(rng.integers(0, G, size=(60, 2)), axis=1),
                      axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    comp = grid_components(G, edges)
    # brute reference: repeated relaxation
    ref = np.arange(G)
    for _ in range(G):
        for a, b in edges:
            m = min(ref[a], ref[b])
            ref[a] = ref[b] = m
    for _ in range(G):
        ref = ref[ref]
    np.testing.assert_array_equal(comp, ref)


def test_compaction_threshold_triggers_and_preserves_state():
    rng = np.random.default_rng(19)
    eps, min_pts = 5.0, 4
    base = rng.uniform(0, 80, size=(200, 2))
    idx = _fit_index(base, eps, min_pts)
    idx.compact_threshold = 0.1
    st = idx.delete(np.arange(0, 60))        # 30% dead > 10% threshold
    assert st["compacted"] and idx.n == idx.n_live == 140
    surv = base[60:]
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx.labels_arrival())
    # predict against the compacted state == predict on a fresh fit
    q = surv[:20] + rng.normal(scale=0.2 * eps, size=(20, 2))
    fresh = _fit_index(surv, eps, min_pts)
    got, ref_lab = idx.predict(q, mode="host"), fresh.predict(q,
                                                             mode="host")
    np.testing.assert_array_equal(got == -1, ref_lab == -1)


# --------------------------------------------------------------------------
# snapshots: v2 round-trip + v1 back-compat
# --------------------------------------------------------------------------

def _strip_to_v1(snap):
    """Rewrite a v2 GritIndex snapshot as its v1 schema."""
    v1 = {k: v for k, v in snap.items()
          if k not in ("alive", "live_counts", "merge_edges",
                       "has_merge_graph")}
    v1["version"] = np.asarray([1], np.int64)
    v1["scalars_i"] = snap["scalars_i"][:2]
    return v1


def test_snapshot_v2_roundtrip_after_churn():
    cs = get_churn_scenario("ttl-drift-3d")
    pts = cs.fit_points()
    eps, min_pts = cs.base.eps, cs.base.min_pts
    idx = _fit_index(pts, eps, min_pts)
    live = _replay(idx, cs.ops(), pts, eps, min_pts, check_every=False)
    buf = io.BytesIO()
    idx.save(buf)
    buf.seek(0)
    idx2 = GritIndex.load(buf)
    for f in ("points", "arrival", "ids", "starts", "counts", "core",
              "labels", "alive", "live_counts", "merge_edges"):
        np.testing.assert_array_equal(getattr(idx, f), getattr(idx2, f))
    assert idx2.next_arrival == idx.next_arrival
    np.testing.assert_array_equal(idx.labels_arrival(),
                                  idx2.labels_arrival())
    # the restored index keeps mutating exactly
    ids = idx2.arrival_live()[:10]
    idx2.delete(ids)
    surv = np.array([live[i] for i in sorted(live)
                     if i not in set(ids.tolist())])
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx2.labels_arrival())


def test_snapshot_v1_still_restores_and_mutates():
    """A previous-version snapshot (no tombstones, no merge graph)
    must restore, rebuild the merge graph lazily on the first
    mutation, and serve deletes exactly."""
    rng = np.random.default_rng(23)
    eps, min_pts = 5.0, 4
    base = rng.uniform(0, 70, size=(150, 2))
    idx = _fit_index(base, eps, min_pts)
    v1 = _strip_to_v1(idx.snapshot())
    idx2 = GritIndex.restore(v1)
    assert idx2.merge_edges is None and idx2.alive.all()
    assert idx2.next_arrival == len(base)
    np.testing.assert_array_equal(idx2.labels_arrival(),
                                  idx.labels_arrival())
    st = idx2.delete(np.arange(0, 30))
    assert st["merge_graph_built"]
    surv = base[30:]
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             idx2.labels_arrival())


def test_snapshot_unknown_version_rejected():
    rng = np.random.default_rng(29)
    idx = _fit_index(rng.uniform(0, 50, size=(60, 2)), 4.0, 4)
    snap = idx.snapshot()
    snap["version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match="snapshot version"):
        GritIndex.restore(snap)


def test_sharded_snapshot_roundtrip_after_delete():
    cs = get_churn_scenario("churn-split-2d")
    pts = cs.fit_points()
    eps, min_pts = cs.base.eps, cs.base.min_pts
    sidx = fit_sharded(pts, eps, min_pts, n_shards=3, engine="grit")
    live = _replay(sidx, cs.ops()[:4], pts, eps, min_pts,
                   check_every=False)
    assert sidx.localized
    buf = io.BytesIO()
    sidx.save(buf)
    buf.seek(0)
    s2 = ShardedGritIndex.load(buf)
    assert s2.localized
    np.testing.assert_array_equal(s2.labels_arrival(),
                                  sidx.labels_arrival())
    ids = s2.arrival_live()[-8:]
    s2.delete(ids)
    surv = np.array([live[i] for i in sorted(live)
                     if i not in set(ids.tolist())])
    ref = brute_dbscan(surv, eps, min_pts)
    assert_labels_conformant(surv, eps, min_pts, ref,
                             s2.labels_arrival())


# --------------------------------------------------------------------------
# unified stats schema + compat shim
# --------------------------------------------------------------------------

_SHARED_INSERT = {"op", "inserted", "n", "n_live", "touched_grids",
                  "affected_grids", "changed_grids", "newly_core",
                  "merge_checks", "dist_evals", "relabeled",
                  "id_shifted", "t_total"}
_SHARED_DELETE = {"op", "requested", "deleted", "rejected",
                  "rejected_ids", "n", "n_live", "touched_grids",
                  "affected_grids", "changed_grids", "demoted",
                  "merge_checks", "dist_evals", "relabeled",
                  "compacted", "t_total"}


def test_unified_mutation_stats_schema():
    """GritIndex and ShardedGritIndex mutations share one stats schema
    (sharded sums the counters), so the serve driver and benchmarks
    can consume either without special-casing."""
    rng = np.random.default_rng(31)
    base = rng.uniform(0, 100, size=(160, 2))
    eps, min_pts = 6.0, 4
    idx = _fit_index(base, eps, min_pts)
    sidx = fit_sharded(base, eps, min_pts, n_shards=3, engine="grit")
    batch = rng.uniform(0, 100, size=(20, 2))
    s1, s2 = idx.insert(batch), sidx.insert(batch)
    assert _SHARED_INSERT <= set(s1) and _SHARED_INSERT <= set(s2)
    for f in ("inserted", "n", "n_live"):
        assert s1[f] == s2[f], f
    d1, d2 = idx.delete(np.arange(10)), sidx.delete(np.arange(10))
    assert _SHARED_DELETE <= set(d1) and _SHARED_DELETE <= set(d2)
    assert d1["deleted"] == d2["deleted"] == 10
    assert d1["demoted"] == d2["demoted"]
    # empty batches return the full schema (serving loops log
    # unconditionally)
    assert _SHARED_INSERT <= set(idx.insert(np.zeros((0, 2))))
    assert _SHARED_INSERT <= set(sidx.insert(np.zeros((0, 2))))


def test_insert_batch_compat_shim():
    """`insert_batch` stays importable from its pre-refactor home, now
    behind a DeprecationWarning pointing at the unified mutation
    plane."""
    import importlib
    import sys

    sys.modules.pop("repro.index.insert", None)
    with pytest.warns(DeprecationWarning, match=r"repro\.index\.delta"):
        shim_mod = importlib.import_module("repro.index.insert")
    shim = shim_mod.insert_batch
    from repro.index.delta import insert_batch as real
    assert shim is real
    assert shim_mod.__all__ == ["insert_batch"]
    rng = np.random.default_rng(37)
    idx = _fit_index(rng.uniform(0, 40, size=(50, 2)), 4.0, 4)
    st = shim(idx, rng.uniform(0, 40, size=(5, 2)))
    assert st["inserted"] == 5
