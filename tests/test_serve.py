"""Serving-plane tests: the continuous-batching driver must return
exactly what a direct ``GritIndex.predict`` returns for every ragged
request, record per-request latency, and grow its caps (never truncate)
when traffic exceeds them.
"""

import numpy as np
import pytest

from repro.data.scenarios import get_serving_scenario
from repro.engine import cluster
from repro.serve import ClusterServer


@pytest.fixture(scope="module")
def served_index():
    ss = get_serving_scenario("query-heavy-3d")
    pts = ss.fit_points()
    res = cluster(pts, ss.base.eps, ss.base.min_pts, engine="grit",
                  return_index=True)
    return ss, res.index


def _ragged_requests(ss, seed, sizes):
    rng = np.random.default_rng(seed)
    q = ss.query_batch(seed=seed, n=int(sum(sizes)))
    out, off = [], 0
    for m in sizes:
        out.append(q[off:off + m])
        off += m
    return out


def test_server_labels_match_direct_predict(served_index):
    ss, idx = served_index
    reqs = _ragged_requests(ss, 0, [7, 31, 2, 18, 25, 13])
    srv = ClusterServer(idx, slots=4, mode="host")
    rids = [srv.submit(r) for r in reqs]
    done = srv.run()
    assert sorted(r.rid for r in done) == rids
    for r, pts in zip(sorted(done, key=lambda r: r.rid), reqs):
        np.testing.assert_array_equal(r.labels,
                                      idx.predict(pts, mode="host"))
        assert r.latency_ms >= 0.0


def test_server_batches_into_slots(served_index):
    ss, idx = served_index
    srv = ClusterServer(idx, slots=3, mode="host")
    for r in _ragged_requests(ss, 1, [5] * 7):
        srv.submit(r)
    srv.run()
    # 7 requests over 3 slots -> ceil(7/3) = 3 steps
    assert len(srv.step_log) == 3
    assert [s["requests"] for s in srv.step_log] == [3, 3, 1]
    assert all(s["queries"] == s["requests"] * 5 for s in srv.step_log)


def test_server_grows_query_cap_on_oversized_request(served_index):
    ss, idx = served_index
    srv = ClusterServer(idx, slots=2, query_cap=8, mode="host")
    big = _ragged_requests(ss, 2, [50])[0]
    srv.submit(big)
    (done,) = srv.step()
    assert len(done.labels) == 50
    assert srv.query_cap >= 50
    growth = [e for e in srv.growth_events if e["cap"] == "query_cap"]
    assert growth and growth[0]["was"] == 8
    # caps never shrink: a later small request keeps the grown cap
    srv.submit(_ragged_requests(ss, 3, [4])[0])
    srv.step()
    assert srv.query_cap == growth[0]["now"]


def test_server_kernel_mode_records_predict_caps(served_index):
    ss, idx = served_index
    srv = ClusterServer(idx, slots=2, mode="kernel")
    for r in _ragged_requests(ss, 4, [12, 20]):
        srv.submit(r)
    srv.run()
    assert all(s["predict"]["mode"] == "kernel" for s in srv.step_log)


def test_server_summary_stats(served_index):
    ss, idx = served_index
    srv = ClusterServer(idx, slots=4, mode="host")
    for r in _ragged_requests(ss, 5, [10, 10, 10, 10]):
        srv.submit(r)
    srv.run()
    s = srv.summary()
    assert s["requests"] == 4 and s["queries"] == 40
    assert s["steps"] == 1
    assert s["latency_ms_p95"] >= s["latency_ms_p50"] > 0
    assert s["queries_per_s"] > 0
    assert 0 < s["mean_slot_fill"] <= 1


def test_server_rejects_bad_request_at_admission(served_index):
    """Malformed requests must be rejected in submit(), before they can
    join a batch -- a NaN request must never poison co-batched ones."""
    ss, idx = served_index
    srv = ClusterServer(idx, mode="host")
    with pytest.raises(ValueError, match="request must be"):
        srv.submit(np.zeros((4, idx.d + 1)))
    good = _ragged_requests(ss, 6, [9])[0]
    srv.submit(good)
    bad = np.zeros((4, idx.d))
    bad[2, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(bad)
    (done,) = srv.run()              # the good request still serves
    np.testing.assert_array_equal(done.labels,
                                  idx.predict(good, mode="host"))


def test_server_idle_step_is_noop(served_index):
    _, idx = served_index
    srv = ClusterServer(idx)
    assert srv.step() == []
    assert srv.step_log == []


# --------------------------------------------------------------------------
# sharded backend: the driver is index-agnostic
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_index():
    from repro.data.scenarios import get_dist_serving_scenario
    from repro.index import fit_sharded

    ss = get_dist_serving_scenario("slab-serve-2d")
    pts = ss.fit_points()
    sidx = fit_sharded(pts, ss.base.eps, ss.base.min_pts, n_shards=4,
                       engine="grit")
    return ss, sidx


def test_server_sharded_backend_matches_direct_predict(sharded_index):
    """A ShardedGritIndex drops into the driver unchanged: per-request
    labels equal a direct slab-routed predict, and the step log carries
    the slab-routing counters."""
    ss, sidx = sharded_index
    reqs = _ragged_requests(ss, 7, [11, 29, 4, 17])
    srv = ClusterServer(sidx, slots=3, mode="host")
    rids = [srv.submit(r) for r in reqs]
    done = srv.run()
    assert sorted(r.rid for r in done) == rids
    for r, pts in zip(sorted(done, key=lambda r: r.rid), reqs):
        np.testing.assert_array_equal(r.labels,
                                      sidx.predict(pts, mode="host"))
    for s in srv.step_log:
        assert s["predict"]["shards"] == sidx.num_shards
        assert sum(s["predict"]["owned_per_shard"]) == s["queries"]


def test_server_sharded_routes_cut_band_queries(sharded_index):
    """Slab-band traffic (the scenario's query mix) must show up as
    multi-routed queries in the serve-step stats."""
    ss, sidx = sharded_index
    srv = ClusterServer(sidx, slots=2, mode="host")
    srv.submit(ss.query_batch(seed=1))
    srv.run()
    assert sum(s["predict"]["multi_routed"] for s in srv.step_log) > 0
