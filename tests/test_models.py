"""Per-arch smoke tests (reduced configs): forward/train-step shapes, no
NaNs, and cached-decode == uncached-forward consistency."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import (init_params, loss_fn, init_cache, prefill,
                          decode_step, forward, count_params, active_params)
from repro.models.lm import logits_for

KEY = jax.random.PRNGKey(0)

# smoke-test the smallest config in the default run; the rest of the zoo
# is nightly (slow) -- each arch costs ~5-12s of CPU compile
_FAST_ARCHS = {"qwen1_5_0_5b"}


def _arch_matrix():
    return [a if a in _FAST_ARCHS
            else pytest.param(a, marks=pytest.mark.slow)
            for a in list_archs()]


def _batch(cfg, B, S, key=KEY):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": tok}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32) * 0.02
    return b


@pytest.mark.parametrize("arch", _arch_matrix())
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    B, S = 2, 32
    batch = _batch(cfg, B, S + 1)
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: loss_fn(cfg, q, b), has_aux=True)(p))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"
    # forward output shapes
    h, _, _ = forward(cfg, params, {**batch, "tokens": batch["tokens"][:, :-1]})
    extra = cfg.num_patches if cfg.family == "vlm" else 0
    assert h.shape == (B, S + extra, cfg.d_model)


@pytest.mark.parametrize("arch", _arch_matrix())
def test_smoke_decode_consistency(arch):
    cfg = get_config(arch, smoke=True).with_overrides(
        dtype="float32", remat=False)
    if cfg.moe is not None:
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S, key=jax.random.PRNGKey(1))
    h, _, _ = forward(cfg, params, batch)
    full = logits_for(cfg, params, h)
    if cfg.family == "vlm":
        full = full[:, cfg.num_patches:]
    cache = init_cache(cfg, B, 32)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :6]
    lg, cache = prefill(cfg, params, pb, cache)
    errs = [np.abs(np.asarray(lg) - np.asarray(full[:, 5])).max()]
    for t in range(6, S):
        lg, cache = decode_step(cfg, params, batch["tokens"][:, t], cache)
        errs.append(np.abs(np.asarray(lg) - np.asarray(full[:, t])).max())
    assert max(errs) < 2e-2, f"{arch}: decode mismatch {errs}"


def test_full_config_param_counts():
    """Exact parameter counts of the FULL configs via eval_shape (no
    allocation) -- pins each architecture's scale."""
    expect = {
        "rwkv6_3b": (1.4e9, 3.5e9),
        "mixtral_8x7b": (45e9, 48e9),
        "arctic_480b": (450e9, 520e9),
        "qwen2_1_5b": (1.2e9, 1.9e9),
        "stablelm_3b": (2.5e9, 3.5e9),
        "qwen1_5_0_5b": (0.4e9, 0.7e9),
        "gemma2_27b": (26e9, 30e9),
        "whisper_small": (0.2e9, 0.4e9),
        "zamba2_2_7b": (2.2e9, 3.2e9),
        "internvl2_1b": (0.4e9, 0.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params_below_total():
    cfg = get_config("mixtral_8x7b")
    total, act = count_params(cfg), active_params(cfg)
    assert act < total * 0.35          # 2-of-8 experts active


def test_swa_ring_cache_is_window_bounded():
    cfg = get_config("mixtral_8x7b", smoke=True)
    cache = init_cache(cfg, 2, 64)      # window=16 -> ring of 16
    k = cache["slots"][0]["k"]
    assert k.shape[3] == cfg.window


@pytest.mark.parametrize("arch", [
    "qwen2_1_5b",
    pytest.param("gemma2_27b", marks=pytest.mark.slow),
    pytest.param("zamba2_2_7b", marks=pytest.mark.slow)])
def test_bf16_logit_buffers_numerically_close(arch):
    """§Perf lever: bf16 logit/score buffers must not move the loss."""
    from repro.models import loss_fn
    cfg32 = get_config(arch, smoke=True).with_overrides(
        dtype="float32", remat=False)
    cfg16 = cfg32.with_overrides(logit_dtype="bfloat16")
    params = init_params(cfg32, KEY)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                             cfg32.vocab_size)
    l32, _ = loss_fn(cfg32, params, {"tokens": tok})
    l16, _ = loss_fn(cfg16, params, {"tokens": tok})
    assert abs(float(l32) - float(l16)) / float(l32) < 2e-3
