"""Tests for ``repro.analysis`` -- the AST invariant linter.

Three layers:

* per-rule fixture triplets: a *positive* file that must trip the rule,
  a *negative* file that must not, and a *pragma'd* positive whose
  finding must survive in the report as suppressed-with-reason;
* the tier-1 self-run: the live ``src/repro`` tree must be clean (zero
  unsuppressed violations) -- this is the contract that a PR breaking a
  serving invariant fails CI;
* the CLI: exit 0 on clean, 1 on violations, 2 on usage errors.

Fixture files are written into ``tmp_path`` subdirectories matching the
path scoping of the rules (``core/``, ``kernels/``, ...).
"""

import os
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import analyze_paths, rule_names

REPO_ROOT = Path(__file__).resolve().parents[1]
SRC = REPO_ROOT / "src"


def run_fixture(tmp_path, files, select=None):
    """Write ``{relpath: source}`` under tmp_path and analyze it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return analyze_paths([str(tmp_path)], select=select)


def active_of(report, rule):
    return [v for v in report.active if v.rule == rule]


def suppressed_of(report, rule):
    return [v for v in report.suppressed if v.rule == rule]


# ---------------------------------------------------------------------------
# registry / plumbing
# ---------------------------------------------------------------------------

def test_all_five_rules_registered():
    assert set(rule_names()) == {
        "donation-aliasing", "f64-discipline", "hot-path-sync",
        "recompile-hazard", "sentinel-mask"}


def test_syntax_error_reported_not_raised(tmp_path):
    report = run_fixture(tmp_path, {"broken.py": "def f(:\n"})
    assert [v.rule for v in report.active] == ["parse"]
    assert not report.ok


def test_unknown_select_raises_keyerror(tmp_path):
    (tmp_path / "a.py").write_text("x = 1\n")
    with pytest.raises(KeyError):
        analyze_paths([str(tmp_path)], select=["no-such-rule"])


# ---------------------------------------------------------------------------
# rule 1: donation-aliasing
# ---------------------------------------------------------------------------

_DONATION_POS = """
    import functools
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scat(buf, rows):
        return buf.at[rows].set(False)

    def caller(state, rows):
        out = scat(state.buf, rows)
        return state.buf.sum() + out.sum()   # stale read of donated buf
"""

_DONATION_NEG = """
    import functools
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scat(buf, rows):
        return buf.at[rows].set(False)

    def caller(state, rows):
        state.buf = scat(state.buf, rows)    # rebind at the call site
        return state.buf.sum()
"""


def test_donation_positive(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _DONATION_POS},
                         select=["donation-aliasing"])
    vs = active_of(report, "donation-aliasing")
    assert len(vs) == 1
    assert "state.buf" in vs[0].message and "donated" in vs[0].message


def test_donation_negative(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _DONATION_NEG},
                         select=["donation-aliasing"])
    assert active_of(report, "donation-aliasing") == []


def test_donation_pragma_suppresses_with_reason(tmp_path):
    src = _DONATION_POS.replace(
        "return state.buf.sum() + out.sum()   # stale read of donated buf",
        "return state.buf.sum() + out.sum()  "
        "# grit-lint: disable=donation-aliasing -- buffer re-uploaded below")
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["donation-aliasing"])
    assert active_of(report, "donation-aliasing") == []
    sup = suppressed_of(report, "donation-aliasing")
    assert len(sup) == 1
    assert sup[0].reason == "buffer re-uploaded below"


def test_donation_rebind_before_read_is_clean(tmp_path):
    src = """
        import jax

        def g(buf):
            return buf

        scat = jax.jit(g, donate_argnums=(0,))

        def caller(buf):
            scat(buf)
            buf = make_new()
            return buf.sum()
    """
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["donation-aliasing"])
    assert active_of(report, "donation-aliasing") == []


# ---------------------------------------------------------------------------
# rule 2: f64-discipline
# ---------------------------------------------------------------------------

_PRECISION_POS = """
    import numpy as np

    def decide(d2, eps):
        eps2 = np.float32(eps) ** 2          # f32 cast in core/
        return d2 <= eps2
"""


def test_precision_positive_in_core(tmp_path):
    report = run_fixture(tmp_path, {"core/foo.py": _PRECISION_POS},
                         select=["f64-discipline"])
    vs = active_of(report, "f64-discipline")
    assert vs and any("float32" in v.message for v in vs)


def test_precision_out_of_scope_is_clean(tmp_path):
    # the same source outside core//index/ is none of this rule's business
    report = run_fixture(tmp_path, {"serve/foo.py": _PRECISION_POS},
                         select=["f64-discipline"])
    assert active_of(report, "f64-discipline") == []


def test_precision_negative_f64_in_core(tmp_path):
    src = """
        import numpy as np

        def decide(d2, eps):
            eps2 = np.float64(eps) ** 2
            return d2 <= eps2
    """
    report = run_fixture(tmp_path, {"core/foo.py": src},
                         select=["f64-discipline"])
    assert active_of(report, "f64-discipline") == []


def test_precision_allowlisted_dispatch_is_clean(tmp_path):
    src = """
        import jax.numpy as jnp

        def fast_merging_masked(si, sj, eps):
            si = si.astype(jnp.float32)
            return si
    """
    report = run_fixture(tmp_path, {"core/merging.py": src},
                         select=["f64-discipline"])
    assert active_of(report, "f64-discipline") == []


def test_precision_mixed_compare(tmp_path):
    src = """
        import numpy as np

        def decide(d2_exact, eps):
            t = np.float32(eps)
            return d2_exact <= t             # mixed f32/f64 compare
    """
    report = run_fixture(tmp_path, {"index/foo.py": src},
                         select=["f64-discipline"])
    msgs = [v.message for v in active_of(report, "f64-discipline")]
    assert any("mixes" in m for m in msgs)


def test_precision_pragma(tmp_path):
    src = _PRECISION_POS.replace(
        "eps2 = np.float32(eps) ** 2          # f32 cast in core/",
        "eps2 = np.float32(eps) ** 2  "
        "# grit-lint: disable=f64-discipline -- certain-only path, band applied")
    report = run_fixture(tmp_path, {"core/foo.py": src},
                         select=["f64-discipline"])
    assert active_of(report, "f64-discipline") == []
    sup = suppressed_of(report, "f64-discipline")
    assert sup and sup[0].reason == "certain-only path, band applied"


# ---------------------------------------------------------------------------
# rule 3: recompile-hazard
# ---------------------------------------------------------------------------

_RECOMPILE_POS = """
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops as kernel_ops

    def f(q):
        n = q.shape[0]
        buf = np.zeros((n, 4))               # raw data-dependent shape
        return kernel_ops.eps_count_batch(jnp.asarray(buf))
"""

_RECOMPILE_NEG = """
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops as kernel_ops

    def _pow2_at_least(n, lo=8):
        return max(lo, 1 << (int(n) - 1).bit_length())

    def f(q):
        n = q.shape[0]
        cap = _pow2_at_least(n)
        buf = np.zeros((cap, 4))             # pow2-bucketed shape
        return kernel_ops.eps_count_batch(jnp.asarray(buf))
"""


def test_recompile_positive(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _RECOMPILE_POS},
                         select=["recompile-hazard"])
    vs = active_of(report, "recompile-hazard")
    assert len(vs) == 1 and "'buf'" in vs[0].message


def test_recompile_negative_bucketed(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _RECOMPILE_NEG},
                         select=["recompile-hazard"])
    assert active_of(report, "recompile-hazard") == []


def test_recompile_static_argnames_array(tmp_path):
    src = """
        import functools
        import numpy as np
        import jax

        @functools.partial(jax.jit, static_argnames=("block",))
        def k(x, *, block):
            return x

        def g(x):
            return k(x, block=np.asarray([1, 2]))
    """
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["recompile-hazard"])
    vs = active_of(report, "recompile-hazard")
    assert vs and "static argument 'block'" in vs[0].message


def test_recompile_static_argnames_scalar_is_clean(tmp_path):
    src = """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("block",))
        def k(x, *, block):
            return x

        def g(x):
            return k(x, block=128)
    """
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["recompile-hazard"])
    assert active_of(report, "recompile-hazard") == []


def test_recompile_pragma(tmp_path):
    src = _RECOMPILE_POS.replace(
        "        return kernel_ops.eps_count_batch(jnp.asarray(buf))",
        "        # grit-lint: disable=recompile-hazard -- cold path, runs once\n"
        "        return kernel_ops.eps_count_batch(jnp.asarray(buf))")
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["recompile-hazard"])
    assert active_of(report, "recompile-hazard") == []
    sup = suppressed_of(report, "recompile-hazard")
    assert sup and sup[0].reason == "cold path, runs once"


# ---------------------------------------------------------------------------
# rule 4: hot-path-sync
# ---------------------------------------------------------------------------

_HOTSYNC_POS = """
    import numpy as np
    import jax.numpy as jnp

    class ClusterServer:
        def step(self, batch):
            return helper(batch)

    def helper(batch):
        d2dev = jnp.zeros(4)
        return float(np.asarray(d2dev))      # sync inside the hot graph
"""

_HOTSYNC_NEG = """
    import numpy as np
    import jax.numpy as jnp

    class ClusterServer:
        def step(self, batch):
            return pack(batch)

    def pack(batch):
        return np.asarray(batch, np.int32)   # host value: not a sync

    def offline_report(res):
        d2dev = jnp.zeros(4)
        return float(np.asarray(d2dev))      # not reachable from step
"""


def test_hotsync_positive(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _HOTSYNC_POS},
                         select=["hot-path-sync"])
    vs = active_of(report, "hot-path-sync")
    assert vs and "helper()" in vs[0].message


def test_hotsync_negative_unreachable_and_host_values(tmp_path):
    report = run_fixture(tmp_path, {"m.py": _HOTSYNC_NEG},
                         select=["hot-path-sync"])
    assert active_of(report, "hot-path-sync") == []


def test_hotsync_block_until_ready_flags(tmp_path):
    src = """
        class ClusterServer:
            def step(self, batch):
                out = launch(batch)
                out.block_until_ready()
                return out
    """
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["hot-path-sync"])
    vs = active_of(report, "hot-path-sync")
    assert vs and "block_until_ready" in vs[0].message


def test_hotsync_pragma(tmp_path):
    src = _HOTSYNC_POS.replace(
        "return float(np.asarray(d2dev))      # sync inside the hot graph",
        "return float(np.asarray(d2dev))  "
        "# grit-lint: disable=hot-path-sync -- the stage's intended block point")
    report = run_fixture(tmp_path, {"m.py": src},
                         select=["hot-path-sync"])
    assert active_of(report, "hot-path-sync") == []
    sup = suppressed_of(report, "hot-path-sync")
    assert sup and sup[0].reason == "the stage's intended block point"


# ---------------------------------------------------------------------------
# rule 5: sentinel-mask
# ---------------------------------------------------------------------------

_SENTINEL_POS = """
    import jax.numpy as jnp

    def row_min_wrapper(d2):
        return jnp.min(d2, axis=-1)          # raw reduce over padded buf
"""

_SENTINEL_NEG = """
    import jax.numpy as jnp

    def row_min_wrapper(d2, valid):
        d2m = jnp.where(valid, d2, jnp.inf)
        return jnp.min(d2m, axis=-1)
"""


def test_sentinel_positive(tmp_path):
    report = run_fixture(tmp_path, {"kernels/foo.py": _SENTINEL_POS},
                         select=["sentinel-mask"])
    vs = active_of(report, "sentinel-mask")
    assert len(vs) == 1 and "validity" in vs[0].message


def test_sentinel_negative_masked(tmp_path):
    report = run_fixture(tmp_path, {"kernels/foo.py": _SENTINEL_NEG},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask") == []


def test_sentinel_out_of_scope_is_clean(tmp_path):
    report = run_fixture(tmp_path, {"serve/foo.py": _SENTINEL_POS},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask") == []


def test_sentinel_kernel_body_exempt(tmp_path):
    src = """
        import jax.numpy as jnp

        def _row_min_kernel(a_ref, out_ref):
            out_ref[...] = jnp.min(a_ref[...], axis=-1)
    """
    report = run_fixture(tmp_path, {"kernels/foo.py": src},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask") == []


def test_sentinel_pragma(tmp_path):
    src = _SENTINEL_POS.replace(
        "return jnp.min(d2, axis=-1)          # raw reduce over padded buf",
        "return jnp.min(d2, axis=-1)  "
        "# grit-lint: disable=sentinel-mask -- caller FAR-folds per contract")
    report = run_fixture(tmp_path, {"kernels/foo.py": src},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask") == []
    sup = suppressed_of(report, "sentinel-mask")
    assert sup and sup[0].reason == "caller FAR-folds per contract"


# ---------------------------------------------------------------------------
# pragma meta-rule
# ---------------------------------------------------------------------------

def test_reasonless_pragma_reported_and_does_not_suppress(tmp_path):
    src = _SENTINEL_POS.replace(
        "return jnp.min(d2, axis=-1)          # raw reduce over padded buf",
        "return jnp.min(d2, axis=-1)  # grit-lint: disable=sentinel-mask")
    report = run_fixture(tmp_path, {"kernels/foo.py": src},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask"), \
        "reasonless pragma must not suppress"
    assert active_of(report, "pragma"), \
        "reasonless pragma must itself be reported"


def test_unknown_rule_pragma_reported(tmp_path):
    src = _SENTINEL_POS.replace(
        "return jnp.min(d2, axis=-1)          # raw reduce over padded buf",
        "return jnp.min(d2, axis=-1)  "
        "# grit-lint: disable=no-such-rule -- whatever")
    report = run_fixture(tmp_path, {"kernels/foo.py": src},
                         select=["sentinel-mask"])
    assert active_of(report, "sentinel-mask")
    assert any("unknown rule" in v.message
               for v in active_of(report, "pragma"))


# ---------------------------------------------------------------------------
# tier-1 self-run: the live tree is the contract
# ---------------------------------------------------------------------------

def test_live_tree_is_clean():
    report = analyze_paths([str(SRC / "repro")])
    assert report.files_checked > 50
    assert report.ok, "live src/repro must have zero unsuppressed " \
        "violations:\n" + report.format()
    # every escape hatch in the tree carries a written justification
    assert report.suppressed, "the known block points should be pragma'd"
    for v in report.suppressed:
        assert v.reason.strip(), v.format()


# ---------------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, timeout=120)


def test_cli_exit_zero_on_clean_file(tmp_path):
    p = tmp_path / "clean.py"
    p.write_text("def f(x):\n    return x + 1\n")
    proc = _run_cli("--check", str(p))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_exit_nonzero_on_violation(tmp_path):
    p = tmp_path / "kernels"
    p.mkdir()
    (p / "bad.py").write_text(textwrap.dedent(_SENTINEL_POS))
    proc = _run_cli("--check", str(tmp_path))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "sentinel-mask" in proc.stdout


def test_cli_usage_error_without_paths():
    proc = _run_cli()
    assert proc.returncode == 2


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for name in rule_names():
        assert name in proc.stdout


# ---------------------------------------------------------------------------
# external tools (CI lint job); skipped where not installed
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shutil.which("ruff") is None,
                    reason="ruff not installed (CI lint job runs it)")
def test_ruff_clean():
    proc = subprocess.run(
        ["ruff", "check", "src", "tests", "benchmarks"],
        capture_output=True, text=True, cwd=REPO_ROOT, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr


@pytest.mark.skipif(shutil.which("mypy") is None,
                    reason="mypy not installed (CI lint job runs it)")
def test_mypy_clean():
    proc = subprocess.run(
        ["mypy"], capture_output=True, text=True, cwd=REPO_ROOT,
        timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
