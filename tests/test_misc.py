"""Small pure-python units: caps sizing, registry, shapes, report."""

import math

from repro.core.device_dbscan import GritCaps
from repro.configs import canonical, list_archs, get_shape, SHAPES
from repro.configs.registry import long_500k_supported
from benchmarks.roofline_report import build_table


def test_gritcaps_for_dim_fanout_bound():
    for d in (2, 3, 5, 7):
        caps = GritCaps.for_dim(d)
        r = 2 * math.ceil(math.sqrt(d)) + 1
        assert caps.frontier_cap == max(min(r ** (d - 1), 256), 8)
        assert caps.merge_iters == 16


def test_registry_canonical_ids():
    assert canonical("qwen2-1.5b") == "qwen2_1_5b"
    assert canonical("qwen1.5-0.5b") == "qwen1_5_0_5b"
    assert canonical("mixtral-8x7b") == "mixtral_8x7b"
    assert len(list_archs()) == 10


def test_shape_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert get_shape("train_4k").kind == "train"
    assert get_shape("decode_32k").kind == "decode"
    assert get_shape("long_500k").global_batch == 1


def test_long_500k_policy():
    assert long_500k_supported("rwkv6-3b")
    assert long_500k_supported("zamba2-2.7b")
    assert long_500k_supported("mixtral-8x7b")     # bounded SWA window
    assert not long_500k_supported("gemma2-27b")   # global layers


def test_roofline_report_table():
    recs = [
        {"arch": "a", "shape": "train_4k", "mesh": "16x16", "status": "ok",
         "kind": "train", "chips": 256, "flops_per_chip": 1e12,
         "bytes_per_chip": 1e12,
         "roofline": {"t_compute": 1e-2, "t_memory": 2e-2,
                      "t_collective": 1e-3, "dominant": "memory",
                      "bound": 2e-2, "compute_fraction": 0.5}},
        {"arch": "b", "shape": "long_500k", "mesh": "16x16",
         "status": "skipped", "reason": "full-attention arch"},
    ]
    t = build_table(recs)
    assert "memory" in t and "skip" in t
