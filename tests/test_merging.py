"""FastMerging property tests: exactness (paper Theorem 2) on arbitrary
linearly-separable point sets; masked device engine == host engine."""

import numpy as np
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp

from repro.core.merging import (fast_merging, fast_merging_masked,
                                brute_min_dist, center_prune_merge)


@st.composite
def two_sets(draw):
    d = draw(st.integers(min_value=2, max_value=5))
    m1 = draw(st.integers(min_value=1, max_value=25))
    m2 = draw(st.integers(min_value=1, max_value=25))
    seed = draw(st.integers(0, 2**31 - 1))
    gap = draw(st.floats(min_value=0.0, max_value=3.0))
    rng = np.random.default_rng(seed)
    # linearly separable along dim 0 (as grid core sets are)
    a = rng.uniform(0, 1, size=(m1, d))
    b = rng.uniform(0, 1, size=(m2, d))
    b[:, 0] += 1.0 + gap
    eps = draw(st.floats(min_value=0.05, max_value=4.0))
    return a, b, eps


@given(two_sets())
@settings(max_examples=120, deadline=None)
def test_fast_merging_exact(sets):
    a, b, eps = sets
    want = brute_min_dist(a, b) <= eps
    stats = {}
    got = fast_merging(a, b, eps, stats=stats)
    assert got == want
    # Theorem 3 progress guarantee: terminates within m1+m2 iterations
    assert stats["max_iters"] <= len(a) + len(b) + 1


@given(two_sets())
@settings(max_examples=60, deadline=None)
def test_masked_engine_matches_host(sets):
    a, b, eps = sets
    want = brute_min_dist(a, b) <= eps
    Mi, Mj = 32, 32
    ap = np.zeros((Mi, a.shape[1]), np.float32)
    bp = np.zeros((Mj, b.shape[1]), np.float32)
    ap[:len(a)] = a
    bp[:len(b)] = b
    va = np.arange(Mi) < len(a)
    vb = np.arange(Mj) < len(b)
    got, iters = fast_merging_masked(
        jnp.asarray(ap), jnp.asarray(va), jnp.asarray(bp), jnp.asarray(vb),
        eps, max_iters=128)
    assert bool(got) == want
    assert int(iters) <= 128


@given(two_sets())
@settings(max_examples=60, deadline=None)
def test_center_prune_baseline_exact(sets):
    a, b, eps = sets
    want = brute_min_dist(a, b) <= eps
    assert center_prune_merge(a, b, eps) == want


def test_fast_merging_prunes_distance_work():
    """The point of the paper: far fewer distance evals than brute force
    on dense sets that are just out of range."""
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 1, size=(400, 3))
    b = rng.uniform(0, 1, size=(400, 3))
    b[:, 0] += 2.5
    eps = 0.5
    stats = {}
    assert fast_merging(a, b, eps, stats=stats) is False
    brute_evals = len(a) * len(b)
    assert stats["dist_evals"] < brute_evals / 10
