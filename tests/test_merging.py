"""FastMerging property tests: exactness (paper Theorem 2) on arbitrary
linearly-separable point sets; masked device engine == host engine.

``hypothesis`` is optional: when present we fuzz; without it the same
properties run on a deterministic seeded sweep.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.merging import (fast_merging, fast_merging_masked,
                                brute_min_dist, center_prune_merge)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _make_two_sets(rng: np.random.Generator):
    """Two point sets linearly separable along dim 0 (as grid core sets
    are), with a random gap and eps."""
    d = int(rng.integers(2, 6))
    m1 = int(rng.integers(1, 26))
    m2 = int(rng.integers(1, 26))
    gap = float(rng.uniform(0.0, 3.0))
    a = rng.uniform(0, 1, size=(m1, d))
    b = rng.uniform(0, 1, size=(m2, d))
    b[:, 0] += 1.0 + gap
    eps = float(rng.uniform(0.05, 4.0))
    return a, b, eps


def _check_fast_merging_exact(a, b, eps) -> None:
    want = brute_min_dist(a, b) <= eps
    stats = {}
    got = fast_merging(a, b, eps, stats=stats)
    assert got == want
    # Theorem 3 progress guarantee: terminates within m1+m2 iterations
    assert stats["max_iters"] <= len(a) + len(b) + 1


def _check_masked_matches_host(a, b, eps) -> None:
    want = brute_min_dist(a, b) <= eps
    Mi, Mj = 32, 32
    ap = np.zeros((Mi, a.shape[1]), np.float32)
    bp = np.zeros((Mj, b.shape[1]), np.float32)
    ap[:len(a)] = a
    bp[:len(b)] = b
    va = np.arange(Mi) < len(a)
    vb = np.arange(Mj) < len(b)
    got, iters = fast_merging_masked(
        jnp.asarray(ap), jnp.asarray(va), jnp.asarray(bp), jnp.asarray(vb),
        eps, max_iters=128)
    assert bool(got) == want
    assert int(iters) <= 128


# ---- hypothesis fuzzing (when available) ---------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def two_sets(draw):
        seed = draw(st.integers(0, 2 ** 31 - 1))
        return _make_two_sets(np.random.default_rng(seed))

    @given(two_sets())
    @settings(max_examples=120, deadline=None)
    def test_fast_merging_exact(sets):
        _check_fast_merging_exact(*sets)

    @given(two_sets())
    @settings(max_examples=60, deadline=None)
    def test_masked_engine_matches_host(sets):
        _check_masked_matches_host(*sets)

    @given(two_sets())
    @settings(max_examples=60, deadline=None)
    def test_center_prune_baseline_exact(sets):
        a, b, eps = sets
        want = brute_min_dist(a, b) <= eps
        assert center_prune_merge(a, b, eps) == want


# ---- deterministic fallback sweep (always runs) ---------------------------

@pytest.mark.parametrize("seed", range(40))
def test_fast_merging_exact_seeded(seed, make_rng):
    a, b, eps = _make_two_sets(make_rng(seed))
    _check_fast_merging_exact(a, b, eps)
    want = brute_min_dist(a, b) <= eps
    assert center_prune_merge(a, b, eps) == want


@pytest.mark.parametrize("seed", range(8))
def test_masked_engine_matches_host_seeded(seed, make_rng):
    a, b, eps = _make_two_sets(make_rng(1000 + seed))
    _check_masked_matches_host(a, b, eps)


def test_fast_merging_prunes_distance_work():
    """The point of the paper: far fewer distance evals than brute force
    on dense sets that are just out of range."""
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 1, size=(400, 3))
    b = rng.uniform(0, 1, size=(400, 3))
    b[:, 0] += 2.5
    eps = 0.5
    stats = {}
    assert fast_merging(a, b, eps, stats=stats) is False
    brute_evals = len(a) * len(b)
    assert stats["dist_evals"] < brute_evals / 10
