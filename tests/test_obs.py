"""Observability-plane tests: the ``repro.obs`` tracer / metrics /
export contract.

Pins the four invariants the plane is built on:

* **Disabled tracer is free** -- ``span()`` returns the shared no-op
  object (zero events, zero allocations), and the obs package itself is
  clean under the ``repro.analysis`` hot-path-sync rule with exactly
  the one justified pragma at the enabled-mode span close.
* **Chrome trace export round-trips** -- the exported document is valid
  JSON in trace-event shape, ``load_trace`` recovers the events, and
  interval-containment nesting reconstructs the lexical entry/exit
  order the spans were recorded with.
* **Counter registry loses nothing under the serve driver** -- the
  double-buffered step (predict dispatch for batch k+1 overlapping
  resolve of batch k) must account every request/query exactly once,
  and ``summary()`` stays a faithful view over the registry.
* **Provenance stamps are complete** -- ``bench_meta()`` carries the
  fields that make a BENCH row comparable across machines.
"""

import json

import numpy as np
import pytest

from repro import obs
from repro.obs import view as obs_view
from repro.obs.export import load_trace, write_chrome_trace, write_jsonl
from repro.obs.metrics import MetricsRegistry


@pytest.fixture
def tracer():
    """Fresh enabled tracer, restored to prior state afterwards."""
    was = obs.enabled()
    t = obs.enable(clear=True)
    yield t
    if not was:
        obs.disable()


# ---------------------------------------------------------------------------
# disabled-tracer invariant
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_shared_noop():
    was = obs.enabled()
    obs.disable()
    try:
        s1 = obs.span("anything", n=3)
        s2 = obs.span("else")
        assert s1 is s2 is obs.NOOP_SPAN
        # reentrant, chainable, recordless
        with obs.span("outer") as sp:
            assert sp.set(k=1) is sp
            assert sp.sync(object()) is sp
            with obs.span("inner"):
                pass
        assert obs.get_tracer() is None
        assert not obs.enabled()
    finally:
        if was:
            obs.enable()


def test_obs_package_clean_under_hot_path_sync_rule():
    """The obs package is *not* excluded from the repo linter: with
    tracing wired through the serving stack, ``src`` must still be
    clean under every rule, and the tracer's one enabled-mode sync
    site carries its justified pragma."""
    import os
    from repro.analysis import analyze_paths

    pkg = os.path.dirname(obs.__file__)
    src = os.path.dirname(os.path.dirname(pkg))
    report = analyze_paths([src])
    assert not report.active, [(v.rule, v.path) for v in report.active]
    with open(os.path.join(pkg, "trace.py")) as f:
        text = f.read()
    assert "block_until_ready" in text
    assert "grit-lint: disable=hot-path-sync --" in text


# ---------------------------------------------------------------------------
# spans + chrome export round-trip
# ---------------------------------------------------------------------------

def _record_nested(tracer):
    with obs.span("fit", n=100):
        with obs.span("pack"):
            pass
        with obs.span("cluster"):
            with obs.span("kernel", bucket=256):
                pass
        with obs.span("unpack"):
            pass
    return tracer.snapshot_events()


def test_span_events_record_entry_exit_order(tracer):
    events = _record_nested(tracer)
    # complete events append at *exit*: children precede the parent
    assert [e["name"] for e in events] == [
        "pack", "kernel", "cluster", "unpack", "fit"]
    by = {e["name"]: e for e in events}
    assert by["fit"]["depth"] == 0
    assert by["pack"]["depth"] == by["cluster"]["depth"] == 1
    assert by["kernel"]["depth"] == 2
    assert by["fit"]["args"] == {"n": 100}
    # containment: every child interval sits inside its parent's
    for child, parent in [("pack", "fit"), ("cluster", "fit"),
                          ("kernel", "cluster")]:
        c, p = by[child], by[parent]
        assert c["ts"] >= p["ts"]
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6


def test_chrome_trace_roundtrip_and_nesting(tracer, tmp_path):
    events = _record_nested(tracer)
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), events,
                       metrics={"k.count": 3}, meta={"git_rev": "abc"})
    doc = json.loads(path.read_text())           # valid JSON
    assert {"traceEvents", "displayTimeUnit", "otherData"} <= set(doc)
    assert all(e["ph"] == "X" and e["dur"] >= 0.0
               for e in doc["traceEvents"])
    got, metrics, meta = load_trace(str(path))
    assert [e["name"] for e in got] == [e["name"] for e in events]
    assert metrics == {"k.count": 3} and meta == {"git_rev": "abc"}
    # viewer reconstructs the lexical nesting from intervals alone
    parents = {e["name"]: e["parent"] for e in obs_view._nest(got)}
    assert parents == {"fit": None, "pack": "fit", "cluster": "fit",
                       "kernel": "cluster", "unpack": "fit"}


def test_jsonl_roundtrip(tracer, tmp_path):
    events = _record_nested(tracer)
    path = tmp_path / "trace.jsonl"
    write_jsonl(str(path), events, metrics={"c": 1},
                meta={"git_rev": "abc"})
    got, metrics, meta = load_trace(str(path))
    assert [e["name"] for e in got] == [e["name"] for e in events]
    assert metrics == {"c": 1} and meta["git_rev"] == "abc"


def test_attribution_and_view_cli(tracer, tmp_path, capsys):
    events = _record_nested(tracer)
    att = obs_view.attribution(events, root="fit")
    assert set(att["children"]) == {"pack", "cluster", "unpack"}
    assert 0.0 < att["coverage"] <= 1.0 + 1e-9
    path = tmp_path / "trace.json"
    write_chrome_trace(str(path), events, metrics={"adaptive.retries": 2})
    assert obs_view.main([str(path), "--root", "fit"]) == 0
    out = capsys.readouterr().out
    assert "attribution of 'fit'" in out
    assert "adaptive.retries" in out


def test_span_error_path_still_records(tracer):
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    (ev,) = tracer.snapshot_events()
    assert ev["name"] == "boom" and ev["args"]["error"] is True


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    reg.gauge("g").set(0.25)
    h = reg.histogram("h")
    vals = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    for v in vals:
        h.observe(v)
    assert reg.counter("c").value == 5
    assert reg.gauge("g").value == 0.25
    assert h.count == len(vals) and h.total == sum(vals)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q))
    snap = reg.snapshot()
    assert snap["c"] == 5
    reg.reset()
    assert reg.counter("c").value == 0


def test_bench_meta_provenance_keys():
    meta = obs.bench_meta()
    for k in ("timestamp", "python", "platform", "git_rev", "jax",
              "backend", "device_count"):
        assert k in meta, k
    json.dumps(meta)                              # JSON-able


# ---------------------------------------------------------------------------
# serve driver: no lost increments under the double-buffered step
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_index():
    from repro.data.scenarios import get_serving_scenario
    from repro.engine import cluster

    ss = get_serving_scenario("query-heavy-3d")
    res = cluster(ss.fit_points(), ss.base.eps, ss.base.min_pts,
                  engine="grit", return_index=True)
    return ss, res.index


def test_serve_counters_account_every_request(served_index):
    from repro.serve import ClusterServer

    ss, idx = served_index
    sizes = [7, 31, 2, 18, 25, 13, 9, 4]
    rng = np.random.default_rng(3)
    q = ss.query_batch(seed=3, n=int(sum(sizes)))
    srv = ClusterServer(idx, slots=3, mode="host")
    off = 0
    for m in sizes:
        srv.submit(q[off:off + m])
        off += m
    done = srv.run()
    reg = srv.metrics
    assert reg.counter("serve.requests").value == len(sizes) == len(done)
    assert reg.counter("serve.queries").value == sum(sizes)
    assert reg.counter("serve.steps").value == len(srv.step_log)
    assert reg.histogram("serve.latency_ms").count == len(sizes)
    qw = reg.histogram("serve.queue_wait_ms")
    assert qw.count == len(sizes)
    assert all(s["queue_wait_ms"] >= 0.0 for s in srv.step_log)

    s = srv.summary()
    # summary is a *view* over the registry: same books, old keys intact
    assert s["requests"] == len(sizes) and s["queries"] == sum(sizes)
    lat = reg.histogram("serve.latency_ms")
    assert s["latency_ms_p50"] == pytest.approx(lat.percentile(50))
    assert s["latency_ms_p99"] == pytest.approx(lat.percentile(99))
    assert s["queue_wait_ms_p50"] == pytest.approx(qw.percentile(50))
    assert s["latency_ms_p50"] <= s["latency_ms_p95"] \
        <= s["latency_ms_p99"]


def test_serve_counters_survive_tracing_toggle(served_index):
    """Tracing on must not change the request/query accounting."""
    from repro.serve import ClusterServer

    ss, idx = served_index
    was = obs.enabled()
    obs.enable(clear=True)
    try:
        srv = ClusterServer(idx, slots=2, mode="host")
        for seed in range(5):
            srv.submit(ss.query_batch(seed=seed, n=6))
        srv.run()
        assert srv.metrics.counter("serve.requests").value == 5
        assert srv.metrics.counter("serve.queries").value == 30
        names = {e["name"] for e in obs.get_tracer().snapshot_events()}
        assert "serve.step" in names
        assert "serve.step.dispatch" in names
    finally:
        if not was:
            obs.disable()
