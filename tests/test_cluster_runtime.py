"""Fault-tolerance runtime: straggler guard, crash-restore loop, heartbeat."""

import os
import tempfile
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.cluster import (Heartbeat, StepGuard, StragglerDetected,
                                  run_resilient)
from repro.train import checkpoint as ckpt


def test_step_guard_retries_transient_failures():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return state, {"ok": 1}

    guard = StepGuard(max_retries=3)
    out = guard(flaky, {}, {})
    assert out[1]["ok"] == 1
    assert calls["n"] == 3


def test_step_guard_raises_after_max_retries():
    def always_fails(state, batch):
        raise RuntimeError("hard")

    guard = StepGuard(max_retries=2)
    with pytest.raises(RuntimeError):
        guard(always_fails, {}, {})


def test_step_guard_detects_straggler():
    guard = StepGuard(factor=3.0, min_samples=3)
    def fast(s, b):
        time.sleep(0.005)
        return s, {}
    for _ in range(5):
        guard(fast, {}, {})

    def slow(s, b):
        time.sleep(0.2)
        return s, {}
    with pytest.raises(StragglerDetected):
        guard(slow, {}, {})


def test_run_resilient_crash_restore():
    """Inject a crash mid-run; the loop must restore from the latest
    checkpoint and still complete all steps with the right final state."""
    state = {"params": {"w": jnp.zeros((4,))}, "opt": {},
             "step": jnp.zeros((), jnp.int32)}

    def step_fn(state, batch):
        return {**state, "step": state["step"] + 1,
                "params": {"w": state["params"]["w"] + 1.0}}, \
            {"loss": jnp.zeros(())}

    crashed = {"done": False}

    def inject(i):
        if i == 7 and not crashed["done"]:
            crashed["done"] = True
            return RuntimeError("simulated node failure")
        return None

    with tempfile.TemporaryDirectory() as d:
        final, ran = run_resilient(
            state, step_fn, lambda: {}, ckpt_dir=d, num_steps=10,
            ckpt_every=5, inject_failure=inject)
        assert int(final["step"]) == 10
        # w incremented exactly once per counted step (no double-apply)
        np.testing.assert_allclose(np.asarray(final["params"]["w"]), 10.0)
        assert ckpt.latest_step(d) == 10


def test_run_resilient_straggler_checkpoints_before_raising():
    state = {"params": {"w": jnp.zeros((2,))}, "opt": {},
             "step": jnp.zeros((), jnp.int32)}

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] > 6:
            time.sleep(0.3)
        else:
            time.sleep(0.005)
        return {**state, "step": state["step"] + 1}, {}

    guard = StepGuard(factor=3.0, min_samples=3)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(StragglerDetected):
            run_resilient(state, step_fn, lambda: {}, ckpt_dir=d,
                          num_steps=20, ckpt_every=100, guard=guard)
        assert ckpt.latest_step(d) is not None   # emergency checkpoint


def test_heartbeat_staleness():
    with tempfile.TemporaryDirectory() as d:
        hb0 = Heartbeat(d, 0)
        hb1 = Heartbeat(d, 1)
        hb0.beat()
        hb1.beat()
        assert hb0.stale_hosts(timeout_s=5.0) == []
        time.sleep(0.15)
        hb0.beat()
        assert hb0.stale_hosts(timeout_s=0.1) == [1]
