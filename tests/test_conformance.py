"""Cross-engine conformance matrix: every registered engine must produce
labels equivalent to the O(n^2) ``brute`` oracle on the shared scenario
catalogue (``repro.data.scenarios``), label-for-label after
canonicalization wherever DBSCAN's output is unique.

This is the load-bearing property of the repo -- the paper's Theorem 4
claims GriT-DBSCAN is *exact*, so agreement-with-oracle across
adversarial scenarios is what "correct" means here (the same discipline
Wang/Gu/Shun and de Berg et al. use to validate their parallel/grid
variants).

Also covers the adaptive-cap driver: per-cap overflow flags must fire on
under-provisioned ``GritCaps``, and the driver must recover the exact
labels without manual tuning.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.data.scenarios import default_scenarios, scenario_map
from repro.core.dbscan import brute_dbscan
from repro.core.device_dbscan import GritCaps, device_dbscan
from repro.core.validate import assert_labels_conformant, core_flags
from repro.engine import (CapOverflowError, adaptive_device_dbscan,
                          available_engines, cluster, estimate_caps,
                          grid_stats, grow_caps, stencil_neighbor_bound)

SCENARIOS = scenario_map()
ALL = sorted(SCENARIOS)
QUICK = sorted(s.name for s in default_scenarios() if s.has("quick"))
SLAB = sorted(s.name for s in default_scenarios() if s.has("slab"))
NOT_QUICK = [n for n in ALL if n not in QUICK]

HOST_ENGINES = ["grit", "grit-ldf"]
# both distance planes of the in-graph pipeline: naive broadcast and
# the batched Pallas kernel route (matmul-form jnp on CPU)
DEVICE_ENGINES = ["device", "device-kernels"]


def _oracle(name, oracle_cache):
    """brute labels + core flags, memoized across the whole session."""
    if name not in oracle_cache:
        sc = SCENARIOS[name]
        pts = sc.points()
        labels = brute_dbscan(pts, sc.eps, sc.min_pts)
        core = core_flags(pts, sc.eps, sc.min_pts)
        oracle_cache[name] = (pts, labels, core)
    return oracle_cache[name]


def _conform(name, engine, oracle_cache, **opts):
    sc = SCENARIOS[name]
    pts, ref, core = _oracle(name, oracle_cache)
    res = cluster(pts, sc.eps, sc.min_pts, engine=engine, **opts)
    assert res.engine == engine
    assert res.overflow == (), \
        f"{engine} on {name}: unresolved overflow {res.overflow}"
    assert_labels_conformant(pts, sc.eps, sc.min_pts, ref, res.labels,
                             core=core)
    if res.core is not None:
        np.testing.assert_array_equal(np.asarray(res.core), core)
    return res


# --------------------------------------------------------------------------
# registry basics
# --------------------------------------------------------------------------

def test_registry_lists_all_engines():
    assert set(available_engines()) >= {
        "brute", "grit", "grit-ldf", "device", "device-kernels",
        "distributed"}


def test_unknown_engine_raises():
    with pytest.raises(KeyError, match="unknown engine"):
        cluster(np.zeros((4, 2)), 1.0, 2, engine="nope")


def test_bad_inputs_raise():
    with pytest.raises(ValueError):
        cluster(np.zeros((0, 2)), 1.0, 2)
    with pytest.raises(ValueError):
        cluster(np.zeros((4, 2)), -1.0, 2)
    with pytest.raises(ValueError):
        cluster(np.zeros((4, 2)), 1.0, 0)


@pytest.mark.parametrize("engine", sorted(available_engines()) + ["auto"])
def test_degenerate_inputs_rejected_uniformly(engine):
    """Empty sets, n < min_pts and non-finite coordinates must raise the
    same clear ValueError for *every* engine: validation lives at the
    cluster() boundary, before any backend runs (so e.g. build_grids'
    own empty-set guard is defense-in-depth, not the API surface)."""
    opts = {"engine": engine}
    with pytest.raises(ValueError, match="n > 0"):
        cluster(np.zeros((0, 2)), 1.0, 2, **opts)
    with pytest.raises(ValueError, match="min_pts"):
        cluster(np.random.default_rng(0).uniform(0, 10, (3, 2)), 1.0, 5,
                **opts)
    bad = np.random.default_rng(0).uniform(0, 10, (16, 2))
    bad[3, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        cluster(bad, 1.0, 2, **opts)
    bad[3, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        cluster(bad, 1.0, 2, **opts)


@pytest.mark.parametrize("engine", ["device", "device-kernels"])
def test_device_engines_reject_identifier_overflow(engine):
    """A valid point whose grid interval index would exceed the f32
    device-grid identifier range (span/side >= 2^22: whole-cell f32
    quantization, and near 2^30 the PAD_ID clamp itself) must be
    rejected host-side rather than silently mislabeled in-graph."""
    pts = np.array([[0.0, 0.0], [1e9, 1e9], [1e9, 0.0]])
    with pytest.raises(ValueError, match="device-grid identifier range"):
        cluster(pts, 1e-3, 2, engine=engine)
    # the host pipeline uses int64 identifiers and must still work
    res = cluster(pts, 1e-3, 2, engine="grit")
    assert (res.labels == -1).all()


def test_auto_resolves_to_registered_engine():
    r = cluster(np.random.default_rng(0).uniform(0, 100, (32, 2)), 5.0, 3)
    assert r.engine in available_engines()


def test_auto_dispatch_per_platform(monkeypatch):
    """resolve_auto's full decision table, platform-monkeypatched:
    multi-device -> distributed, TPU -> device-kernels, other
    accelerator -> device, CPU -> grit (DESIGN.md §3)."""
    from repro.engine import resolve_auto

    monkeypatch.setattr(jax, "device_count", lambda: 4)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert resolve_auto() == "distributed"

    monkeypatch.setattr(jax, "device_count", lambda: 1)
    assert resolve_auto() == "device-kernels"

    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert resolve_auto() == "device"

    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert resolve_auto() == "grit"


def test_auto_dispatch_reaches_the_engine(monkeypatch):
    """The resolved name must be the engine that actually runs (and its
    result must carry that engine's name)."""
    monkeypatch.setattr(jax, "device_count", lambda: 1)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    r = cluster(np.random.default_rng(0).uniform(0, 100, (32, 2)), 5.0, 3,
                engine="auto")
    assert r.engine == "grit"


# --------------------------------------------------------------------------
# host engines: full scenario matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", HOST_ENGINES)
@pytest.mark.parametrize("name", ALL)
def test_host_engine_conformance(name, engine, oracle_cache):
    _conform(name, engine, oracle_cache)


def test_brute_engine_self_consistent(oracle_cache):
    pts, ref, core = _oracle("blobs-2d", oracle_cache)
    sc = SCENARIOS["blobs-2d"]
    res = cluster(pts, sc.eps, sc.min_pts, engine="brute")
    np.testing.assert_array_equal(res.labels, ref)
    np.testing.assert_array_equal(res.core, core)


# --------------------------------------------------------------------------
# device engine (both distance planes): quick subset by default, the
# rest nightly (slow)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", DEVICE_ENGINES)
@pytest.mark.parametrize("name", QUICK)
def test_device_engine_conformance_quick(name, engine, oracle_cache):
    res = _conform(name, engine, oracle_cache)
    assert res.attempts, "device engine must record its cap attempts"
    assert res.attempts[-1]["overflow"] == ()


@pytest.mark.slow
@pytest.mark.parametrize("engine", DEVICE_ENGINES)
@pytest.mark.parametrize("name", NOT_QUICK)
def test_device_engine_conformance_full(name, engine, oracle_cache):
    _conform(name, engine, oracle_cache)


def test_device_result_point_grid_is_consistent(oracle_cache):
    """The device result's original-order ``point_grid`` provenance:
    every group of points mapped to one grid row must lie within the
    grid diagonal (side * sqrt(d) == eps), rows must be in range, and
    the partition must cover all n points."""
    pts, _, _ = _oracle("blobs-2d", oracle_cache)
    sc = SCENARIOS["blobs-2d"]
    from repro.engine import estimate_caps
    caps = estimate_caps(pts, sc.eps, sc.min_pts)
    res = device_dbscan(jnp.asarray(pts, jnp.float32), sc.eps,
                        sc.min_pts, caps)
    pg = np.asarray(res.point_grid)
    assert pg.shape == (len(pts),)
    assert (pg >= 0).all() and (pg < caps.grid_cap).all()
    for g in np.unique(pg):
        own = pts[pg == g]
        if len(own) > 1:
            d2 = ((own[:, None, :] - own[None, :, :]) ** 2).sum(-1)
            assert d2.max() <= (sc.eps * (1 + 1e-5)) ** 2, \
                f"grid {g} spans more than the grid diagonal"


def test_kernelized_caps_share_overflow_machinery(oracle_cache):
    """use_kernels must not perturb overflow reporting: identical tiny
    caps raise identical per-cap flags on both distance planes, and the
    adaptive driver recovers the kernelized path exactly like the naive
    one (the flags come from candidate totals, never distance values)."""
    pts, ref, core = _oracle("duplicates-2d", oracle_cache)
    sc = SCENARIOS["duplicates-2d"]
    tiny_k = dataclasses.replace(TINY, use_kernels=True)
    r_naive = device_dbscan(jnp.asarray(pts, jnp.float32), sc.eps,
                            sc.min_pts, TINY)
    r_kern = device_dbscan(jnp.asarray(pts, jnp.float32), sc.eps,
                           sc.min_pts, tiny_k)
    assert (jax.device_get(r_naive.report).overflowing()
            == jax.device_get(r_kern.report).overflowing())
    res, attempts = adaptive_device_dbscan(
        jnp.asarray(pts, jnp.float32), sc.eps, sc.min_pts, tiny_k,
        growth=3.0)
    assert attempts[0]["overflow"] and attempts[-1]["overflow"] == ()
    assert all(a["caps"]["use_kernels"] for a in attempts), \
        "use_kernels must survive every growth round"
    assert_labels_conformant(pts, sc.eps, sc.min_pts, ref,
                             np.asarray(res.labels), core=core)


# --------------------------------------------------------------------------
# distributed engine: in-process single-shard mesh by default; real
# multi-device parity runs in a subprocess (forced host devices, slow)
# --------------------------------------------------------------------------

def test_distributed_engine_conformance_single_shard(oracle_cache):
    mesh = jax.make_mesh((1,), ("shard",))
    _conform("cross-slab-2d", "distributed", oracle_cache, mesh=mesh)


@pytest.mark.slow
@pytest.mark.parametrize("name", SLAB + ["blobs-2d"])
def test_distributed_engine_conformance_multidevice(name):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(f"""
            import numpy as np
            from repro.data.scenarios import get_scenario
            from repro.core.dbscan import brute_dbscan
            from repro.core.validate import assert_labels_conformant
            from repro.engine import cluster

            sc = get_scenario({name!r})
            pts = sc.points()
            ref = brute_dbscan(pts, sc.eps, sc.min_pts)
            res = cluster(pts, sc.eps, sc.min_pts, engine="distributed")
            assert res.stats["n_shards"] == 4, res.stats
            assert res.overflow == (), res.overflow
            assert_labels_conformant(pts, sc.eps, sc.min_pts, ref,
                                     res.labels)
            print("CONFORM OK")
        """)], env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "CONFORM OK" in out.stdout


# --------------------------------------------------------------------------
# cap estimation + adaptive overflow recovery (satellite: overflow tests)
# --------------------------------------------------------------------------

TINY = GritCaps(grid_cap=8, frontier_cap=8, k_cap=8, c_cap=16, m_cap=8,
                pair_cap=16, grid_block=8, pair_block=8, merge_iters=20)


def test_overflow_flags_fire_on_tiny_caps(oracle_cache):
    """A dataset that exceeds a deliberately tiny GritCaps must raise
    per-cap overflow flags (not silently truncate)."""
    pts, _, _ = _oracle("blobs-2d", oracle_cache)
    res = device_dbscan(jnp.asarray(pts, jnp.float32),
                        SCENARIOS["blobs-2d"].eps,
                        SCENARIOS["blobs-2d"].min_pts, TINY)
    report = jax.device_get(res.report)
    assert bool(res.overflow)
    flagged = report.overflowing()
    assert flagged, "overflow scalar set but no per-cap flag named"
    assert set(flagged) <= set(report.FIELDS)
    # this dataset has ~tens of grids and >8-point clusters: both the
    # grid table and the per-grid core sets must blow the tiny caps
    assert "grid" in flagged
    assert "core_set" in flagged


def test_adaptive_driver_recovers_from_tiny_caps(oracle_cache):
    """Satellite acceptance: starting from under-provisioned caps, the
    adaptive driver must converge to the exact brute labels without
    manual tuning.  duplicates-2d blows both grid_cap and m_cap (38
    copies per location vs m_cap=8) while staying small to compile."""
    sc = SCENARIOS["duplicates-2d"]
    pts, ref, core = _oracle("duplicates-2d", oracle_cache)
    res, attempts = adaptive_device_dbscan(
        jnp.asarray(pts, jnp.float32), sc.eps, sc.min_pts, TINY,
        growth=3.0)
    assert len(attempts) > 1, "tiny caps should need at least one retry"
    assert attempts[0]["overflow"], "first attempt must report overflow"
    assert attempts[-1]["overflow"] == ()
    assert not bool(res.overflow)
    assert_labels_conformant(pts, sc.eps, sc.min_pts, ref,
                             np.asarray(res.labels), core=core)


def test_adaptive_driver_raises_when_out_of_retries(oracle_cache):
    pts, _, _ = _oracle("blobs-2d", oracle_cache)
    sc = SCENARIOS["blobs-2d"]
    with pytest.raises(CapOverflowError, match="overflowing"):
        adaptive_device_dbscan(jnp.asarray(pts, jnp.float32), sc.eps,
                               sc.min_pts, TINY, max_retries=0)


def test_estimate_caps_from_grid_statistics(oracle_cache):
    pts, _, _ = _oracle("varden-3d", oracle_cache)
    sc = SCENARIOS["varden-3d"]
    num_grids, max_occ = grid_stats(pts, sc.eps)
    caps = estimate_caps(pts, sc.eps, sc.min_pts)
    assert caps.grid_cap >= num_grids
    assert caps.m_cap >= max_occ
    assert caps.k_cap <= stencil_neighbor_bound(3)
    assert caps.grid_cap % caps.grid_block == 0
    assert caps.pair_cap % caps.pair_block == 0
    # merge_iters covers the Theorem-3 bound |s_i| + |s_j| <= 2 * m_cap
    assert caps.merge_iters >= 2 * caps.m_cap


def test_grow_caps_grows_only_what_overflowed():
    caps = estimate_caps(np.random.default_rng(0).uniform(0, 1e5, (64, 2)),
                         3000.0, 5)
    grown = grow_caps(caps, ("pairs",), n=64, d=2)
    assert grown.pair_cap > caps.pair_cap
    assert grown.grid_cap == caps.grid_cap
    assert grown.k_cap == caps.k_cap
    assert grown.m_cap == caps.m_cap


def test_grow_caps_raises_at_clamp():
    """Every overflowed cap already at its provable max -> error, not an
    infinite loop."""
    caps = dataclasses.replace(
        TINY, c_cap=64, grid_block=8)          # c_cap clamp is n
    with pytest.raises(CapOverflowError):
        grow_caps(caps, ("candidates",), n=64, d=2)
