"""Chunkwise recurrences vs sequential oracles (RWKV6 WKV, Mamba2 SSD)
and equivalence of the four attention execution paths."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.rwkv import _wkv_chunk, wkv_sequential
from repro.models.ssm import _ssd_chunk, ssd_sequential
from repro.models import layers as L

RNG = np.random.default_rng(0)


def test_wkv_chunk_matches_sequential():
    B, C, H, D = 2, 16, 3, 8
    r = jnp.asarray(RNG.normal(size=(B, C, H, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, C, H, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, C, H, D)), jnp.float32)
    lw = jnp.asarray(-RNG.uniform(0.01, 2.0, size=(B, C, H, D)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, D)) * 0.1, jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(B, H, D, D)) * 0.1, jnp.float32)
    y1, s1 = _wkv_chunk(r, k, v, lw, u, s0)
    y2, s2 = wkv_sequential(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_wkv_chunking_invariance():
    """Two chunks of 8 == one chunk of 16 (state carried across)."""
    B, H, D = 1, 2, 8
    r, k, v = (jnp.asarray(RNG.normal(size=(B, 16, H, D)), jnp.float32)
               for _ in range(3))
    lw = jnp.asarray(-RNG.uniform(0.01, 1.0, size=(B, 16, H, D)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(H, D)) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    y_full, s_full = _wkv_chunk(r, k, v, lw, u, s0)
    y_a, s_a = _wkv_chunk(r[:, :8], k[:, :8], v[:, :8], lw[:, :8], u, s0)
    y_b, s_b = _wkv_chunk(r[:, 8:], k[:, 8:], v[:, 8:], lw[:, 8:], u, s_a)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y_a, y_b], axis=1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_b),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_matches_sequential():
    B, C, H, N, P = 2, 24, 3, 8, 4
    xh = jnp.asarray(RNG.normal(size=(B, C, H, P)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, C, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, C, N)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, size=(B, C, H)), jnp.float32)
    la = jnp.asarray(-RNG.uniform(0.01, 1.5, size=(B, C, H)), jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(B, H, N, P)) * 0.1, jnp.float32)
    y1, s1 = _ssd_chunk(xh, Bm, Cm, dt, la, s0)
    y2, s2 = ssd_sequential(xh, Bm, Cm, dt, la, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window", [None, 48])
def test_attention_impls_agree(window):
    B, H, S, D = 1, 2, 128, 16
    chunk = 32
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    base = L.attention(q, k, v, causal=True, window=window, impl="direct",
                       chunk=chunk)
    impls = ["rect"] + (["banded"] if window else ["tri"])
    for impl in impls:
        out = L.attention(q, k, v, causal=True, window=window, impl=impl,
                          chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"impl={impl} window={window}")


def test_attention_decode_alignment():
    """One-query attention must equal the last row of full attention."""
    B, H, S, D = 2, 2, 40, 16
    q = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, H, S, D)), jnp.float32)
    full = L.attention(q, k, v, causal=True, impl="direct")
    one = L.attention(q[:, :, -1:], k, v, causal=True, impl="direct")
    np.testing.assert_allclose(np.asarray(one[:, :, 0]),
                               np.asarray(full[:, :, -1]),
                               rtol=1e-5, atol=1e-5)


def test_rope_rotation_preserves_norm():
    x = jnp.asarray(RNG.normal(size=(2, 8, 4, 16)), jnp.float32)
    freqs = 1.0 / (100.0 ** (jnp.arange(0, 16, 2) / 16))
    y = L.apply_rope(x, jnp.arange(8), freqs)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
