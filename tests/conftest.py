"""Shared fixtures + markers for the test suite.

Markers
-------
``slow``: heavyweight device/distributed/model-zoo cases.  The default
tier-1 run excludes them (``addopts = -m "not slow"`` in pytest.ini) to
keep ``pytest -x -q`` fast (~1-2 min CPU, load-dependent); the nightly
CI job runs ``-m "slow or not slow"`` to cover everything.
"""

import numpy as np
import pytest


@pytest.fixture
def rng() -> np.random.Generator:
    """Deterministic per-test RNG (seed 0)."""
    return np.random.default_rng(0)


@pytest.fixture
def make_rng():
    """Factory for deterministic RNGs with explicit seeds."""
    def factory(seed: int = 0) -> np.random.Generator:
        return np.random.default_rng(seed)
    return factory


@pytest.fixture(scope="session")
def oracle_cache():
    """Session-wide memo for expensive O(n^2) oracle labelings, keyed by
    (scenario name, seed).  Used by the conformance matrix so every
    engine parametrization shares one brute_dbscan run per scenario."""
    return {}
