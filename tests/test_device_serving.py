"""Differential device-vs-host test plane for the serving hot path.

The device-resident path (``GritIndex.ensure_device_state``) must be
**bit-identical** to host serving -- not approximately: the guard-band
discipline (``repro.index.device_state``) only lets the float32 kernels
decide provably-certain cases and re-runs the uncertain band through
the same host float64 code, so every observable output -- predict
labels *and* squared distances, ``labels_arrival`` / ``core_arrival``,
the merge-edge set, and the semantic mutation-stats counters -- must
match the host run exactly, across the whole serving scenario
catalogue (query-heavy, drift, churn-split with delete-triggered
cluster splits, ttl-drift).

The donation stress test drives seeded random insert/delete/predict
streams through the donated resident buffers and pins the mirror to
the host arrays after every mutation (a stale donated alias fails
immediately), then round-trips ``snapshot()``/``restore()`` -- the
device index must serialize exactly the host state.
"""

import io
import zlib

import numpy as np
import pytest

from repro.core.dbscan import grit_dbscan
from repro.data.scenarios import (get_churn_scenario,
                                  get_serving_scenario)
from repro.index import GritIndex, device_state

_DEFAULT_GATES = (device_state.MIN_FLAT_T, device_state.EDGE_MIN_FLAT_T)


@pytest.fixture(autouse=True)
def _force_kernel_path(monkeypatch):
    """Catalogue scenarios are CI-small, so under the production
    adaptive gates every delta stage would route to its host twin and
    the kernel path would go silently untested -- pin the gates to 0 so
    every stage dispatches.  ``test_adaptive_gates_differential``
    restores the defaults to cover the gated routing itself."""
    monkeypatch.setattr(device_state, "MIN_FLAT_T", 0)
    monkeypatch.setattr(device_state, "EDGE_MIN_FLAT_T", 0)

SERVING = ["query-heavy-3d", "drift-2d"]
CHURN = ["churn-split-2d", "ttl-drift-3d"]

# keys whose values are timing / device-internal telemetry, not
# semantics: everything else in a mutation stats dict must match the
# host run bit for bit (dist_evals differs because the device path
# spends float64 evals only on the uncertain band)
NONSEMANTIC = {"dist_evals", "t_total", "t_pack", "t_kernel",
               "band_fallback"}


def _seed(*key) -> int:
    return zlib.crc32("/".join(map(str, key)).encode())


def _fit_pair(pts, eps, min_pts, interpret=None):
    """The same fit twice: one host-serving index, one device-resident."""
    res = grit_dbscan(pts, eps, min_pts)
    host = GritIndex.from_fit(pts, eps, min_pts, res.labels,
                              core=res.core)
    dev = GritIndex.from_fit(pts, eps, min_pts, res.labels,
                             core=res.core)
    dev.ensure_device_state(interpret=interpret)
    return host, dev


def _assert_stats_match(sh, sd, where):
    for k in set(sh) | set(sd):
        if k in NONSEMANTIC:
            continue
        assert k in sh and k in sd, (where, k)
        assert np.array_equal(sh[k], sd[k]), (where, k, sh[k], sd[k])


def _assert_state_match(host, dev, where):
    assert np.array_equal(host.labels_arrival(), dev.labels_arrival()), where
    assert np.array_equal(host.core_arrival(), dev.core_arrival()), where
    he, de = host.merge_edges, dev.merge_edges
    if he is not None or de is not None:
        assert he is not None and de is not None, where
        assert np.array_equal(he, de), where
    mm = dev.device_state.mirror_matches(dev)
    assert all(mm.values()), (where, mm)


def _probe_queries(ss, pts, eps, seed):
    """Scenario queries + the adversarial cases the docstring promises:
    exact-eps boundary queries off real points, far out-of-bbox
    queries, and empty-cell queries between clusters."""
    rng = np.random.default_rng(seed)
    q = ss.query_batch(0, 64)
    d = pts.shape[1]
    base = pts[rng.integers(0, len(pts), 8)]
    axis = np.zeros((8, d))
    axis[:, 0] = eps                      # exactly eps along one axis
    boundary = base + axis
    span = pts.max(0) - pts.min(0)
    outside = pts.max(0)[None, :] + span[None, :] * (
        1.0 + rng.random((8, d)))         # far beyond the fitted bbox
    between = (pts.min(0) + pts.max(0))[None, :] / 2 + rng.normal(
        scale=span / 50, size=(8, d))     # likely-empty interior cells
    return np.concatenate([q, boundary, outside, between])


@pytest.mark.parametrize("name", SERVING)
def test_predict_differential(name):
    """Device predict == host predict, labels and d2 bit-identical,
    including eps-boundary / out-of-bbox / empty-cell queries."""
    ss = get_serving_scenario(name)
    pts = ss.fit_points()
    eps, mp = ss.base.eps, ss.base.min_pts
    host, dev = _fit_pair(pts, eps, mp)
    q = _probe_queries(ss, pts, eps, _seed("predict", name))
    lh, dh = host.predict(q, mode="host", return_d2=True)
    stats = {}
    ld, dd = dev.predict(q, mode="device", return_d2=True, stats=stats)
    assert np.array_equal(lh, ld)
    assert np.array_equal(dh, dd)                 # bitwise, inf included
    assert stats["mode"] == "device"
    assert stats["chunks"] >= 1
    # auto mode routes through the resident state once attached
    stats2 = {}
    la = dev.predict(q, stats=stats2)
    assert stats2["mode"] == "device"
    assert np.array_equal(la, lh)


@pytest.mark.parametrize("name", SERVING)
def test_serving_stream_differential(name):
    """Insert stream + interleaved predicts: states, stats and answers
    stay bit-identical step for step."""
    ss = get_serving_scenario(name)
    pts = ss.fit_points()
    eps, mp = ss.base.eps, ss.base.min_pts
    host, dev = _fit_pair(pts, eps, mp)
    for i, batch in enumerate(ss.insert_batches(0, 3)):
        sh = host.insert(batch)
        sd = dev.insert(batch)
        _assert_stats_match(sh, sd, (name, "insert", i))
        _assert_state_match(host, dev, (name, "insert", i))
        q = ss.query_batch(i, 32)
        lh, dh = host.predict(q, mode="host", return_d2=True)
        ld, dd = dev.predict(q, mode="device", return_d2=True)
        assert np.array_equal(lh, ld), (name, i)
        assert np.array_equal(dh, dd), (name, i)


@pytest.mark.parametrize("name", CHURN)
def test_churn_differential(name):
    """The churn catalogue (insert/delete plans incl. delete-triggered
    cluster splits and TTL expiry) through the device path: every op's
    stats and the full state match the host run exactly."""
    sc = get_churn_scenario(name)
    pts = sc.fit_points()
    eps, mp = sc.base.eps, sc.base.min_pts
    host, dev = _fit_pair(pts, eps, mp)
    for i, (op, arg) in enumerate(sc.ops(0)):
        if op == "insert":
            sh, sd = host.insert(arg), dev.insert(arg)
        else:
            sh, sd = host.delete(arg), dev.delete(arg)
        _assert_stats_match(sh, sd, (name, op, i))
        _assert_state_match(host, dev, (name, op, i))
    # merge graphs (built or maintained) agree at the end as well
    assert np.array_equal(host.ensure_merge_graph(),
                          dev.ensure_merge_graph())


def test_adaptive_gates_differential():
    """The production gate values route small delta stages to their
    host twins (``MIN_FLAT_T`` / ``EDGE_MIN_FLAT_T``); the gated mix of
    kernel and host stages must stay bit-identical too -- including the
    resident-flag sync the recompute gate performs after its host
    twin."""
    device_state.MIN_FLAT_T = _DEFAULT_GATES[0]
    device_state.EDGE_MIN_FLAT_T = _DEFAULT_GATES[1]
    sc = get_churn_scenario("churn-split-2d")
    pts = sc.fit_points()
    host, dev = _fit_pair(pts, sc.base.eps, sc.base.min_pts)
    for i, (op, arg) in enumerate(sc.ops(0)):
        sh, sd = (host.insert(arg), dev.insert(arg)) if op == "insert" \
            else (host.delete(arg), dev.delete(arg))
        _assert_stats_match(sh, sd, ("gated", op, i))
        _assert_state_match(host, dev, ("gated", op, i))
    q = sc.query_batch(0, 64) if hasattr(sc, "query_batch") else pts[:64]
    lh, dh = host.predict(q, mode="host", return_d2=True)
    ld, dd = dev.predict(q, mode="device", return_d2=True)
    assert np.array_equal(lh, ld) and np.array_equal(dh, dd)


def test_delete_split_differential():
    """An explicit bridge-cut: deleting the bridge points must split
    the cluster identically on both paths (the non-monotone case the
    persistent merge graph exists for)."""
    rng = np.random.default_rng(_seed("split"))
    eps, mp = 0.5, 4
    left = rng.normal(size=(60, 2), scale=0.3)
    right = rng.normal(size=(60, 2), scale=0.3) + [6.0, 0.0]
    bridge = np.stack([np.linspace(0.8, 5.2, 24),
                       np.zeros(24)], axis=1)
    bridge += rng.normal(scale=0.02, size=bridge.shape)
    pts = np.concatenate([left, right, bridge])
    host, dev = _fit_pair(pts, eps, mp)
    assert len(np.unique(host.labels[host.labels >= 0])) == 1
    bridge_ids = np.arange(120, 144)
    sh, sd = host.delete(bridge_ids), dev.delete(bridge_ids)
    _assert_stats_match(sh, sd, "split-delete")
    _assert_state_match(host, dev, "split-delete")
    lab = host.labels_arrival()
    assert len(np.unique(lab[lab >= 0])) == 2     # it really split


def _interleave(host, dev, pts, eps, steps, seed):
    """Seeded random insert/delete/predict stream applied to both
    indexes; asserts bit-equality after every op."""
    rng = np.random.default_rng(seed)
    d = pts.shape[1]
    lo, hi = pts.min(0), pts.max(0)
    for i in range(steps):
        op = rng.choice(["insert", "delete", "predict"],
                        p=[0.4, 0.3, 0.3])
        if op == "insert":
            m = int(rng.integers(3, 24))
            b = rng.uniform(lo - 2 * eps, hi + 2 * eps, size=(m, d))
            sh, sd = host.insert(b), dev.insert(b)
            _assert_stats_match(sh, sd, ("interleave", i))
        elif op == "delete":
            live = host.arrival_live()
            k = min(len(live), int(rng.integers(1, 16)))
            ids = rng.choice(live, k, replace=False)
            ids = np.concatenate([ids, [10 ** 9]])   # one bogus id
            sh, sd = host.delete(ids), dev.delete(ids)
            _assert_stats_match(sh, sd, ("interleave", i))
        else:
            m = int(rng.integers(4, 48))
            q = rng.uniform(lo - eps, hi + eps, size=(m, d))
            lh, dh = host.predict(q, mode="host", return_d2=True)
            ld, dd = dev.predict(q, mode="device", return_d2=True)
            assert np.array_equal(lh, ld), ("interleave", i)
            assert np.array_equal(dh, dd), ("interleave", i)
            continue
        _assert_state_match(host, dev, ("interleave", i))


def _stress_roundtrip(n, steps, seed):
    rng = np.random.default_rng(seed)
    pts = np.concatenate([
        rng.normal(size=(n // 2, 2), scale=0.4),
        rng.normal(size=(n // 2, 2), scale=0.4) + [3.0, 1.0]])
    eps, mp = 0.35, 4
    host, dev = _fit_pair(pts, eps, mp)
    _interleave(host, dev, pts, eps, steps, seed + 1)
    # snapshot/restore: the device index serializes exactly the host
    # state (resident buffers are derived, never snapshotted)
    sh, sd = host.snapshot(), dev.snapshot()
    assert set(sh) == set(sd)
    for k in sh:
        assert np.array_equal(sh[k], sd[k]), k
    buf = io.BytesIO()
    dev.save(buf)
    buf.seek(0)
    back = GritIndex.load(buf)
    assert back.device_state is None          # mirror is not shipped
    assert np.array_equal(back.labels_arrival(), host.labels_arrival())
    q = rng.uniform(-1, 4, size=(64, 2))
    assert np.array_equal(back.predict(q, mode="host"),
                          host.predict(q, mode="host"))
    # the restored index can re-attach a device state and keep serving
    back.ensure_device_state()
    assert np.array_equal(back.predict(q, mode="device"),
                          host.predict(q, mode="host"))
    return dev


def test_donated_buffer_stress_roundtrip():
    dev = _stress_roundtrip(n=160, steps=25, seed=_seed("stress"))
    ds = dev.device_state
    assert ds.donations > 0                   # scatters actually ran
    assert ds.uploads > 0


@pytest.mark.slow
def test_donated_buffer_stress_roundtrip_long():
    for rep in range(3):
        _stress_roundtrip(n=400, steps=120,
                          seed=_seed("stress-long", rep))


def test_interpret_mode_differential():
    """CPU-only runners: the same differential holds with the Pallas
    kernels forced through interpret mode."""
    ss = get_serving_scenario("drift-2d")
    pts = ss.fit_points()
    eps, mp = ss.base.eps, ss.base.min_pts
    host, dev = _fit_pair(pts, eps, mp, interpret=True)
    q = ss.query_batch(0, 48)
    assert np.array_equal(host.predict(q, mode="host"),
                          dev.predict(q, mode="device"))
    b = ss.insert_batches(0, 1)[0][:16]
    sh, sd = host.insert(b), dev.insert(b)
    _assert_stats_match(sh, sd, "interpret-insert")
    _assert_state_match(host, dev, "interpret-insert")


def test_compaction_refreshes_mirror():
    """Crossing compact_threshold re-packs the row layout: the mirror
    must follow (full re-upload) and serving must stay identical."""
    rng = np.random.default_rng(_seed("compact"))
    pts = rng.normal(size=(200, 2))
    host, dev = _fit_pair(pts, 0.4, 4)
    host.compact_threshold = dev.compact_threshold = 0.15
    ids = np.arange(0, 80)                    # 40% dead: triggers
    sh, sd = host.delete(ids), dev.delete(ids)
    assert sd["compacted"]
    _assert_stats_match(sh, sd, "compact")
    _assert_state_match(host, dev, "compact")
    assert dev.n == dev.n_live                # really re-packed
    q = rng.normal(size=(32, 2))
    assert np.array_equal(host.predict(q, mode="host"),
                          dev.predict(q, mode="device"))


def test_drop_device_state_falls_back():
    ss = get_serving_scenario("drift-2d")
    pts = ss.fit_points()
    host, dev = _fit_pair(pts, ss.base.eps, ss.base.min_pts)
    dev.drop_device_state()
    assert dev.device_state is None
    stats = {}
    q = ss.query_batch(0, 16)
    out = dev.predict(q, stats=stats)         # auto -> host on CPU
    assert stats["mode"] != "device"
    assert np.array_equal(out, host.predict(q, mode="host"))
