"""Sharded serving-plane conformance (single process, host-sharded).

The acceptance bar mirrors the single-shard index suite, on the
distributed-serving scenarios: ``ShardedGritIndex.predict`` must equal
the brute-oracle assignment rule (cut-band queries included -- the ones
routed to two shards), ``insert`` + read-out must be label-conformant
with a from-scratch ``cluster()`` on the union set (canonicalized,
contested borders excepted), and snapshots must round-trip.  The
true-mesh (>= 4 device) path of the same checks lives in
``tests/test_dist_serve.py`` (slow / nightly).
"""

import io

import numpy as np
import pytest

from repro.core.dbscan import brute_dbscan
from repro.core.validate import assert_labels_conformant, core_flags
from repro.data.scenarios import (dist_serving_scenarios,
                                  get_dist_serving_scenario)
from repro.index import GritIndex, ShardedGritIndex, fit_sharded

DIST_SERVING = sorted(s.name for s in dist_serving_scenarios())


@pytest.fixture(scope="module")
def fitted():
    """One sharded index + base fit per scenario (module memo)."""
    cache = {}

    def get(name, n_shards=4):
        key = (name, n_shards)
        if key not in cache:
            ss = get_dist_serving_scenario(name)
            pts = ss.fit_points()
            sidx = fit_sharded(pts, ss.base.eps, ss.base.min_pts,
                               n_shards=n_shards, engine="grit")
            cache[key] = (ss, pts, sidx)
        return cache[key]

    return get


def _oracle_assign(pts, core, labels, queries, eps):
    """Reference assignment: (labels, set-of-valid-labels-per-query)."""
    cpts = pts[core]
    clab = np.asarray(labels)[core]
    eps2 = float(eps) ** 2
    out = np.full(len(queries), -1, np.int64)
    valid = []
    for i, q in enumerate(queries):
        d2 = ((cpts - q) ** 2).sum(axis=1)
        j = d2.argmin()
        if d2[j] <= eps2:
            out[i] = clab[j]
            valid.append(set(clab[d2 == d2[j]].tolist()))
        else:
            valid.append({-1})
    return out, valid


# --------------------------------------------------------------------------
# fit: sharded read-out == global fit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", DIST_SERVING)
def test_fit_readout_conformant(name, fitted):
    ss, pts, sidx = fitted(name)
    ref = brute_dbscan(pts, ss.base.eps, ss.base.min_pts)
    assert_labels_conformant(pts, ss.base.eps, ss.base.min_pts, ref,
                             sidx.labels_arrival())
    np.testing.assert_array_equal(
        sidx.core_arrival(),
        core_flags(pts, ss.base.eps, ss.base.min_pts))


def test_slabs_are_nonempty_and_ordered(fitted):
    _, pts, sidx = fitted("slab-serve-2d")
    assert sidx.num_shards >= 2
    assert (np.diff(sidx.cuts) > 0).all()
    for k in range(sidx.num_shards):
        assert len(sidx.own_rows[k]) > 0
    # every point owned exactly once
    all_gids = np.concatenate(sidx.own_gids)
    assert len(all_gids) == len(pts)
    assert len(np.unique(all_gids)) == len(pts)


# --------------------------------------------------------------------------
# predict
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", DIST_SERVING)
def test_predict_matches_oracle_rule(name, fitted):
    """Acceptance: slab-routed predict == brute-oracle assignment for
    the full query mix, cut-band queries included."""
    ss, pts, sidx = fitted(name)
    q = ss.query_batch()
    stats = {}
    got = sidx.predict(q, mode="host", stats=stats)
    core = core_flags(pts, ss.base.eps, ss.base.min_pts)
    ref, valid = _oracle_assign(pts, core, sidx.labels_arrival(), q,
                                ss.base.eps)
    for i in range(len(q)):
        assert got[i] in valid[i], \
            f"query {i}: predicted {got[i]}, oracle allows {valid[i]}"
    np.testing.assert_array_equal(got == -1, ref == -1)
    # the slab-band half of the mix must actually exercise the
    # consult-both-neighbors routing
    assert stats["multi_routed"] > 0
    assert stats["consulted"] == sum(stats["per_shard"])


def test_predict_owner_only_away_from_cuts(fitted):
    """Queries far from every cut are served by exactly one shard."""
    ss, pts, sidx = fitted("slab-serve-2d")
    eps = ss.base.eps
    mid = (np.concatenate([[pts[:, 0].min()], sidx.cuts])
           + np.concatenate([sidx.cuts, [pts[:, 0].max()]])) / 2
    ok = [m for m in mid
          if (np.abs(sidx.cuts - m) > 2.5 * eps).all()]
    assert ok, "slabs too narrow for this scenario's eps"
    q = np.column_stack([np.repeat(ok, 3),
                         np.tile(pts[:3, 1], len(ok))])
    stats = {}
    sidx.predict(q, mode="host", stats=stats)
    assert stats["multi_routed"] == 0
    assert stats["consulted"] == len(q)


def test_predict_outside_slab_range(fitted):
    """Queries beyond the first/last cut route to the end slabs; far
    away they are noise, within eps of edge points they are labeled."""
    ss, pts, sidx = fitted("slab-serve-2d")
    rng = np.random.default_rng(5)
    far = rng.uniform(-7e5, -5e5, size=(12, sidx.d))
    np.testing.assert_array_equal(sidx.predict(far, mode="host"),
                                  np.full(12, -1))
    core = core_flags(pts, ss.base.eps, ss.base.min_pts)
    ci = int(np.flatnonzero(core)[0])
    assert sidx.predict(pts[ci:ci + 1], mode="host")[0] == \
        sidx.labels_arrival()[ci]


def test_predict_kernel_mode_matches_host(fitted):
    """The kernel predict path routes per shard exactly like host mode
    (f32 knife-edge queries excluded, as in the single-shard suite)."""
    ss, pts, sidx = fitted("slab-serve-2d")
    q = ss.query_batch()
    host = sidx.predict(q, mode="host")
    kern = sidx.predict(q, mode="kernel")
    core = core_flags(pts, ss.base.eps, ss.base.min_pts)
    cpts = pts[core]
    eps = ss.base.eps
    decidable = np.ones(len(q), bool)
    for i, qq in enumerate(q):
        dmin = np.sqrt(((cpts - qq) ** 2).sum(axis=1).min())
        decidable[i] = abs(dmin - eps) > 1e-5 * eps
    np.testing.assert_array_equal(host[decidable], kern[decidable])


def test_predict_validates_inputs(fitted):
    _, _, sidx = fitted("slab-serve-2d")
    with pytest.raises(ValueError, match="queries must be"):
        sidx.predict(np.zeros((3, sidx.d + 2)))
    bad = np.zeros((2, sidx.d))
    bad[1, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        sidx.predict(bad)
    assert sidx.predict(np.zeros((0, sidx.d))).shape == (0,)


# --------------------------------------------------------------------------
# insert + re-reconciliation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", DIST_SERVING)
def test_insert_matches_from_scratch_recluster(name, fitted):
    """Acceptance: insert + read-out ≡ cluster() on the union set, with
    batches engineered to straddle cuts (cross-shard merges)."""
    ss, pts, _ = fitted(name)
    sidx = fit_sharded(pts, ss.base.eps, ss.base.min_pts, n_shards=4,
                       engine="grit")      # fresh: do not mutate fixture
    batches = ss.insert_batches()
    for b in batches:
        st = sidx.insert(b)
        assert st["inserted"] == len(b)
    union = np.concatenate([pts] + batches)
    assert sidx.n == len(union)
    ref = brute_dbscan(union, ss.base.eps, ss.base.min_pts)
    assert_labels_conformant(union, ss.base.eps, ss.base.min_pts, ref,
                             sidx.labels_arrival())
    np.testing.assert_array_equal(
        sidx.core_arrival(),
        core_flags(union, ss.base.eps, ss.base.min_pts))


def test_insert_bridge_across_cut_merges_labels(fitted):
    """A dense bridge laid across a cut must merge the two sides'
    cluster ids in the global read-out.  (The mechanism is not pinned:
    when both shards' coverage sees the whole bridge, the delta
    engine's component relabel converges on one raw id locally and the
    label map has nothing left to union; a merge invisible to one
    neighbor goes through the witness-edge reconciliation instead.)"""
    ss, pts, _ = fitted("slab-serve-2d")
    eps, min_pts = ss.base.eps, ss.base.min_pts
    sidx = fit_sharded(pts, eps, min_pts, n_shards=4, engine="grit")
    cut = sidx.cuts[1]
    # two dense blobs straddling the cut, linked by a chain across it
    rng = np.random.default_rng(9)
    y = float(pts[:, 1].mean())
    left = np.column_stack([
        rng.uniform(cut - 6 * eps, cut - 5 * eps, 4 * min_pts),
        rng.uniform(y - 0.2 * eps, y + 0.2 * eps, 4 * min_pts)])
    right = np.column_stack([
        rng.uniform(cut + 5 * eps, cut + 6 * eps, 4 * min_pts),
        rng.uniform(y - 0.2 * eps, y + 0.2 * eps, 4 * min_pts)])
    xs = np.arange(cut - 5 * eps, cut + 5 * eps, 0.5 * eps)
    chain = np.column_stack([xs, np.full(len(xs), y)])
    chain = np.repeat(chain, min_pts, axis=0) + rng.normal(
        scale=0.05 * eps, size=(len(xs) * min_pts, 2))
    sidx.insert(np.concatenate([left, right]))
    la = sidx.labels_arrival()
    l_left = la[len(pts):len(pts) + len(left)]
    l_right = la[len(pts) + len(left):]
    assert (l_left >= 0).all() and (l_right >= 0).all()
    st = sidx.insert(chain)
    assert st["newly_core"] > 0
    la = sidx.labels_arrival()
    merged = set(la[len(pts):len(pts) + len(left) + len(right)].tolist())
    assert len(merged) == 1, f"bridge left {merged} distinct labels"
    # and the full state is still exactly a from-scratch clustering
    union = np.concatenate([pts, left, right, chain])
    ref = brute_dbscan(union, eps, min_pts)
    assert_labels_conformant(union, eps, min_pts, ref,
                             sidx.labels_arrival())


def test_insert_confined_to_touched_shards(fitted):
    """A batch deep inside one slab must touch only that shard."""
    ss, pts, _ = fitted("slab-serve-2d")
    eps = ss.base.eps
    sidx = fit_sharded(pts, eps, ss.base.min_pts, n_shards=4,
                       engine="grit")
    lo = sidx.cuts[0] + 3 * eps
    hi = sidx.cuts[1] - 3 * eps
    assert hi > lo, "slab too narrow for a deep-interior batch"
    rng = np.random.default_rng(3)
    batch = np.column_stack([
        rng.uniform(lo, hi, 12),
        rng.uniform(pts[:, 1].min(), pts[:, 1].max(), 12)])
    before = [s.n for s in sidx.shards]
    st = sidx.insert(batch)
    assert st["shards_touched"] == [1]
    after = [s.n for s in sidx.shards]
    assert after[1] == before[1] + 12
    assert [a for i, a in enumerate(after) if i != 1] == \
        [b for i, b in enumerate(before) if i != 1]


def test_insert_outside_slab_range_extends_end_slabs(fitted):
    ss, pts, _ = fitted("slab-serve-2d")
    eps, min_pts = ss.base.eps, ss.base.min_pts
    sidx = fit_sharded(pts, eps, min_pts, n_shards=3, engine="grit")
    rng = np.random.default_rng(11)
    below = pts.min(axis=0) - 8 * eps
    above = pts.max(axis=0) + 8 * eps
    batch = np.concatenate([
        below[None, :] + rng.uniform(0, eps, size=(6, sidx.d)),
        above[None, :] + rng.uniform(0, eps, size=(6, sidx.d))])
    st = sidx.insert(batch)
    assert set(st["shards_touched"]) == {0, sidx.num_shards - 1}
    union = np.concatenate([pts, batch])
    ref = brute_dbscan(union, eps, min_pts)
    assert_labels_conformant(union, eps, min_pts, ref,
                             sidx.labels_arrival())


def test_insert_validates_inputs(fitted):
    _, _, sidx0 = fitted("slab-serve-2d")
    sidx = ShardedGritIndex.restore(sidx0.snapshot())
    with pytest.raises(ValueError, match="insert batch"):
        sidx.insert(np.zeros((3, sidx.d + 1)))
    bad = np.zeros((2, sidx.d))
    bad[0, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        sidx.insert(bad)
    st = sidx.insert(np.zeros((0, sidx.d)))
    assert st["inserted"] == 0 and st["newly_core"] == 0
    assert st["shards_touched"] == [] and "t_total" in st


# --------------------------------------------------------------------------
# snapshot / restore
# --------------------------------------------------------------------------

def test_snapshot_roundtrip(fitted):
    ss, pts, sidx = fitted("slab-serve-3d")
    snap = sidx.snapshot()
    assert all(isinstance(v, np.ndarray) for v in snap.values()), \
        "sharded snapshot must be flat numpy arrays (savez-able)"
    buf = io.BytesIO()
    sidx.save(buf)
    buf.seek(0)
    sidx2 = ShardedGritIndex.load(buf)
    assert sidx2.num_shards == sidx.num_shards
    np.testing.assert_array_equal(sidx2.cuts, sidx.cuts)
    np.testing.assert_array_equal(sidx2.labels_arrival(),
                                  sidx.labels_arrival())
    q = ss.query_batch()
    np.testing.assert_array_equal(sidx.predict(q, mode="host"),
                                  sidx2.predict(q, mode="host"))
    # a restored index must keep serving inserts exactly
    b = ss.insert_batches()[0]
    sidx2.insert(b)
    union = np.concatenate([pts, b])
    ref = brute_dbscan(union, ss.base.eps, ss.base.min_pts)
    assert_labels_conformant(union, ss.base.eps, ss.base.min_pts, ref,
                             sidx2.labels_arrival())


def test_snapshot_version_checked(fitted):
    _, _, sidx = fitted("slab-serve-2d")
    snap = sidx.snapshot()
    snap["sharded_version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match="sharded snapshot version"):
        ShardedGritIndex.restore(snap)


# --------------------------------------------------------------------------
# construction edge cases
# --------------------------------------------------------------------------

def test_single_shard_degenerates_to_plain_index_semantics():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 100, size=(150, 2))
    sidx = fit_sharded(pts, 5.0, 4, n_shards=1)
    assert sidx.num_shards == 1 and len(sidx.cuts) == 0
    ref = brute_dbscan(pts, 5.0, 4)
    assert_labels_conformant(pts, 5.0, 4, ref, sidx.labels_arrival())


def test_empty_slabs_coalesce():
    """Data concentrated in a narrow dim-0 range cannot fill many
    slabs; empty ones must coalesce rather than produce empty shards."""
    rng = np.random.default_rng(2)
    pts = np.column_stack([rng.uniform(50, 52, 120),
                           rng.uniform(0, 100, 120)])
    sidx = fit_sharded(pts, 8.0, 4, n_shards=6)
    assert sidx.num_shards >= 1
    for k in range(sidx.num_shards):
        assert len(sidx.own_rows[k]) > 0
    ref = brute_dbscan(pts, 8.0, 4)
    assert_labels_conformant(pts, 8.0, 4, ref, sidx.labels_arrival())


def test_fit_sharded_from_device_engine():
    """The sharded build consumes any engine's global fit (core flags
    ride on the result; the device engine exercises the non-host path)."""
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 100, size=(200, 2))
    sidx = fit_sharded(pts, 6.0, 4, n_shards=3, engine="device")
    ref = brute_dbscan(pts, 6.0, 4)
    assert_labels_conformant(pts, 6.0, 4, ref, sidx.labels_arrival())


# --------------------------------------------------------------------------
# satellite: GritIndex fallback core identification (no core flags)
# --------------------------------------------------------------------------

def test_from_fit_without_core_flags_identifies_cores():
    """A result arriving without core flags (core=None) triggers the
    grid-based identification path; it must reproduce the O(n^2)
    oracle's flags exactly and leave predict unchanged."""
    rng = np.random.default_rng(7)
    pts = np.concatenate([
        rng.normal(50, 3.0, size=(120, 2)),
        rng.uniform(0, 100, size=(40, 2))])
    eps, min_pts = 4.0, 5
    ref = brute_dbscan(pts, eps, min_pts)
    idx = GritIndex.from_fit(pts, eps, min_pts, labels=ref, core=None)
    np.testing.assert_array_equal(idx.core_arrival(),
                                  core_flags(pts, eps, min_pts))
    # and the sharded build accepts core=None the same way
    sidx = ShardedGritIndex.from_global_fit(pts, eps, min_pts,
                                            labels=ref, core=None,
                                            n_shards=3)
    np.testing.assert_array_equal(sidx.core_arrival(),
                                  core_flags(pts, eps, min_pts))
    q = pts[:16] + rng.normal(scale=0.1 * eps, size=(16, 2))
    with_core = GritIndex.from_fit(pts, eps, min_pts, labels=ref,
                                   core=core_flags(pts, eps, min_pts))
    np.testing.assert_array_equal(idx.predict(q, mode="host"),
                                  with_core.predict(q, mode="host"))
