"""Unit tests for the dist package plumbing (single device, fast).

The SPMD program itself needs >1 device and lives in
``tests/test_distributed.py`` / ``tests/test_dist_serve.py`` (slow);
everything here is host logic or per-shard device code that runs fine
on one CPU device: slab cuts / vectorized pack+unpack, the halo buffer
(including ``halo_cap > n_points_shard``), and the step cache's
oldest-entry eviction.
"""

import numpy as np
import pytest

import repro.dist.step as dist_step
from repro.dist import (halo_bound, halo_buffer, owner_of_slab,
                        shard_points_by_slab, slab_cuts)
from repro.dist.sharding import unshard_by_perm


# --------------------------------------------------------------------------
# slab cuts + pack/unpack
# --------------------------------------------------------------------------

def _reference_cuts(points, eps, n_shards):
    """The original per-shard loop (pre-vectorization), as the oracle."""
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    side = eps / np.sqrt(d)
    key = np.floor((pts[:, 0] - pts[:, 0].min()) / side).astype(np.int64)
    order = np.argsort(key, kind="stable")
    cuts = [0]
    for s in range(1, n_shards):
        tgt = s * n // n_shards
        while tgt < n and tgt > cuts[-1] and \
                key[order[tgt]] == key[order[tgt - 1]]:
            tgt += 1
        cuts.append(min(tgt, n))
    return order, cuts[1:]


@pytest.mark.parametrize("n_shards", [2, 3, 4, 7])
@pytest.mark.parametrize("seed", [0, 1])
def test_slab_cuts_match_loop_reference(n_shards, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, size=(257, 3))
    eps = 40.0
    order, cut_idx, cut_coords = slab_cuts(pts, eps, n_shards)
    ref_order, ref_cuts = _reference_cuts(pts, eps, n_shards)
    np.testing.assert_array_equal(order, ref_order)
    np.testing.assert_array_equal(cut_idx, ref_cuts)
    # coordinate routing agrees with index-based slab membership
    owner = owner_of_slab(pts[:, 0], cut_coords[np.isfinite(cut_coords)])
    starts = np.concatenate([[0], cut_idx])
    ends = np.concatenate([cut_idx, [len(pts)]])
    ref_owner = np.empty(len(pts), np.int64)
    for s in range(n_shards):
        ref_owner[order[starts[s]:ends[s]]] = s
    np.testing.assert_array_equal(owner, ref_owner)


def test_slab_cuts_duplicate_keys_stay_on_grid_lines():
    """Many points sharing one grid column: a cut may never split a
    column, even when that forces unbalanced (or empty) slabs."""
    pts = np.zeros((60, 2))
    pts[:30, 0] = 10.0       # one dense column
    pts[30:, 0] = 500.0      # another
    _, cut_idx, _ = slab_cuts(pts, 20.0, 4)
    assert set(cut_idx.tolist()) <= {0, 30, 60}


def test_shard_points_roundtrip():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 500, size=(123, 2))
    sh, valid, perm = shard_points_by_slab(pts, 25.0, 4)
    assert sh.shape[0] == 4 and valid.shape == sh.shape[:2]
    # every point appears exactly once, at its permuted slot
    got = unshard_by_perm(sh.astype(np.float64), perm, len(pts))
    np.testing.assert_allclose(got, pts, rtol=1e-6)
    assert valid.sum() == len(pts)
    # pad_to smaller than the largest slab must raise, larger must pad
    with pytest.raises(ValueError, match="pad_to"):
        shard_points_by_slab(pts, 25.0, 4, pad_to=2)
    sh2, valid2, _ = shard_points_by_slab(pts, 25.0, 4, pad_to=64)
    assert sh2.shape[1] == 64 and valid2.sum() == len(pts)


def test_halo_bound_is_window_maximum():
    pts = np.array([[0.0], [1.0], [1.5], [10.0], [10.4], [10.8], [30.0]])
    # densest [x, x + 2*eps] window: {0.0, 1.0, 1.5} (and {10.0..10.8})
    assert halo_bound(pts, 1.0) == 3
    # 2*eps=10: [1.0, 11.0] spans {1.0, 1.5, 10.0, 10.4, 10.8}
    assert halo_bound(pts, 5.0) == 5


# --------------------------------------------------------------------------
# halo buffer (device helper, runs on 1 CPU device)
# --------------------------------------------------------------------------

def _halo_case(n, cap, eps=1.0):
    rng = np.random.default_rng(0)
    pts = np.sort(rng.uniform(0, 10, size=(n, 1)), axis=0)
    pts = np.concatenate([pts, np.full((n, 1), 5.0)], axis=1)
    valid = np.ones(n, bool)
    buf, idx, ovf = halo_buffer(np.asarray(pts, np.float32), valid, eps,
                                "lo", cap)
    want = np.flatnonzero(pts[:, 0] <= pts[:, 0].min() + 2 * eps)
    return np.asarray(buf), np.asarray(idx), bool(ovf), want


def test_halo_buffer_selects_boundary_points():
    buf, idx, ovf, want = _halo_case(n=32, cap=16)
    got = np.sort(idx[idx >= 0])
    np.testing.assert_array_equal(got, want)
    assert not ovf


def test_halo_buffer_cap_exceeding_shard_size():
    """Satellite: ``halo_cap > n_points_shard`` pads the tail instead
    of reading out of bounds, and can never report overflow."""
    from repro.core.device_dbscan import PAD_COORD

    buf, idx, ovf, want = _halo_case(n=12, cap=64)
    assert buf.shape == (64, 2) and idx.shape == (64,)
    got = np.sort(idx[idx >= 0])
    np.testing.assert_array_equal(got, want)
    assert not ovf
    # the tail beyond any selectable point is explicit padding
    assert (idx[len(want):] == -1).all()
    assert (buf[len(want):] >= PAD_COORD / 2).all()


def test_halo_buffer_overflow_flag():
    buf, idx, ovf, want = _halo_case(n=32, cap=2)
    assert len(want) > 2
    assert ovf
    assert (idx >= 0).sum() == 2     # compacted front, fixed cap


# --------------------------------------------------------------------------
# step cache: oldest-entry eviction (satellite)
# --------------------------------------------------------------------------

def test_step_cache_evicts_oldest_not_everything(monkeypatch):
    """An adaptive-cap retry alternates between at most two step keys;
    eviction at capacity must drop the *oldest* entry (wholesale
    clear() used to evict the step the retry was about to reuse)."""
    built = []

    monkeypatch.setattr(dist_step, "_STEP_CACHE", {})
    monkeypatch.setattr(dist_step, "_STEP_CACHE_MAX", 4)
    monkeypatch.setattr(
        dist_step, "make_cluster_step",
        lambda mesh, eps, min_pts, caps, n, d:
        built.append((mesh, eps)) or (lambda *a: ("step", mesh, eps)))
    monkeypatch.setattr(dist_step.jax, "jit", lambda fn: fn)

    def get(i):
        return dist_step.cached_cluster_step(f"mesh{i}", float(i), 5,
                                             ("caps",), 128, 2)

    for i in range(4):
        get(i)
    assert len(built) == 4
    get(0)                       # touch the oldest: now newest again
    get(4)                       # at capacity: evicts mesh1 (oldest)
    assert len(built) == 5
    get(0)                       # still cached (no rebuild)
    get(4)                       # still cached
    assert len(built) == 5
    get(1)                       # evicted: rebuilds
    assert len(built) == 6
    # capacity is respected
    assert len(dist_step._STEP_CACHE) <= 4
