"""Train substrate: optimizers, schedule, compression, checkpointing."""

import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.train import (TrainCfg, make_train_step, init_state,
                         get_optimizer, warmup_cosine, clip_by_global_norm,
                         global_norm)
from repro.train.compress import quantize, dequantize, ef_compress_tree, \
    ef_init
from repro.train import checkpoint as ckpt
from repro.data.tokens import TokenPipeline


@pytest.mark.parametrize("name,lr", [
    ("adamw", 0.05), ("adafactor", 0.05), ("lion", 0.05)])
def test_optimizer_quadratic_convergence(name, lr):
    t = jnp.asarray(np.random.default_rng(0).normal(size=(16, 8)),
                    jnp.float32)
    opt = get_optimizer(name, weight_decay=0.0) if name != "adafactor" \
        else get_optimizer(name)
    params = {"x": jnp.zeros((16, 8), jnp.float32)}
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["x"] - t) ** 2)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        return opt.update(g, state, params, lr)

    for _ in range(200):
        params, state = step(params, state)
    assert float(loss(params)) < 0.5


def test_lm_training_descends():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainCfg(optimizer="adamw", peak_lr=1e-2, warmup_steps=2,
                    total_steps=40)
    opt = get_optimizer("adamw")
    lr_fn = warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps)
    step = jax.jit(make_train_step(cfg, tcfg, opt, lr_fn))
    state = init_state(cfg, tcfg, opt, params)
    pipe = TokenPipeline(cfg.vocab_size, 32, 4, seed=1)
    losses = []
    for _ in range(12):
        b = pipe.next_batch()
        state, m = step(state, {"tokens": jnp.asarray(b["tokens"])})
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen1.5-0.5b", smoke=True).with_overrides(
        dtype="float32", remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = get_optimizer("adamw", weight_decay=0.0)
    lr_fn = lambda s: 1e-3
    pipe = TokenPipeline(cfg.vocab_size, 16, 8, seed=2)
    batch = {"tokens": jnp.asarray(pipe.next_batch()["tokens"])}
    outs = {}
    for mb in (1, 2, 4):
        tcfg = TrainCfg(microbatches=mb)
        step = jax.jit(make_train_step(cfg, tcfg, opt, lr_fn))
        state = init_state(cfg, tcfg, opt, params)
        new, m = step(state, batch)
        outs[mb] = (float(m["loss"]), new["params"])
    for mb in (2, 4):
        assert abs(outs[mb][0] - outs[1][0]) < 1e-3
        for a, b in zip(jax.tree_util.tree_leaves(outs[1][1]),
                        jax.tree_util.tree_leaves(outs[mb][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 64)) * 5, jnp.float32)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    total_true = np.zeros((8, 8), np.float32)
    total_comp = np.zeros((8, 8), np.float32)
    res = ef_init({"g": jnp.zeros((8, 8), jnp.float32)})
    for i in range(50):
        g = {"g": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
        deq, res = ef_compress_tree(g, res)
        total_true += np.asarray(g["g"])
        total_comp += np.asarray(deq["g"])
    # residual carries the outstanding error; totals match within one scale
    gap = np.abs(total_true - (total_comp + np.asarray(res["g"]))).max()
    assert gap < 1e-3


def test_checkpoint_roundtrip_and_gc():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(cfg, TrainCfg(), get_optimizer("adamw"), params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, state, extra={"cursor": 7})
        t = ckpt.save_async(d, 9, state, extra={"cursor": 11})
        t.join()
        assert ckpt.latest_step(d) == 9
        restored, extra = ckpt.restore(d, state)
        assert extra == {"cursor": 11}
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        ckpt.gc_checkpoints(d, keep=1)
        assert ckpt.latest_step(d) == 9
        assert not os.path.exists(os.path.join(d, "step_000000005"))


def test_checkpoint_atomicity_partial_write_ignored():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(cfg, TrainCfg(), get_optimizer("adamw"), params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, state)
        # simulate a crashed write: tmp dir without manifest
        os.makedirs(os.path.join(d, "step_000000007.tmp", "arrays"))
        assert ckpt.latest_step(d) == 3
        restored, _ = ckpt.restore(d, state)


def test_schedule_shape():
    lr = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1)
    assert float(lr(jnp.int32(55))) < 1.0


def test_token_pipeline_determinism_and_resume():
    p1 = TokenPipeline(1024, 16, 4, seed=3)
    a = p1.next_batch()["tokens"]
    b = p1.next_batch()["tokens"]
    p2 = TokenPipeline.from_state(1024, 16, 4, p1.state())
    c = p1.next_batch()["tokens"]
    c2 = p2.next_batch()["tokens"]
    np.testing.assert_array_equal(c, c2)
    p3 = TokenPipeline(1024, 16, 4, seed=3)
    np.testing.assert_array_equal(a, p3.next_batch()["tokens"])
    # different hosts draw disjoint streams
    h0 = TokenPipeline(1024, 16, 4, seed=3, host_id=0, num_hosts=2)
    h1 = TokenPipeline(1024, 16, 4, seed=3, host_id=1, num_hosts=2)
    assert not np.array_equal(h0.next_batch()["tokens"],
                              h1.next_batch()["tokens"])
