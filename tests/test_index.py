"""Fitted-index conformance: ``GritIndex.predict`` must equal the
brute-oracle assignment rule on every serving scenario, ``insert``
followed by a label read-out must be label-equivalent (canonicalized,
contested borders excepted) to a from-scratch ``cluster()`` on the
union set, and ``snapshot``/``restore`` must round-trip bit-exactly.

The oracle assignment rule: a query is noise iff no core point of the
fitted set lies within eps; otherwise it takes the label of the nearest
core point (ties: any label at the minimal distance is accepted --
engines may break exact-distance ties either way).
"""

import io

import numpy as np
import pytest

from repro.core.dbscan import brute_dbscan
from repro.core.grids import GridIndex, identifiers
from repro.core.validate import assert_labels_conformant, core_flags
from repro.data.scenarios import (get_serving_scenario, serving_scenarios,
                                  scenario_map)
from repro.engine import cluster
from repro.index import GritIndex, fit_index

SERVING = sorted(s.name for s in serving_scenarios())


@pytest.fixture(scope="module")
def fitted():
    """One fitted index + oracle per serving scenario (module memo)."""
    cache = {}

    def get(name):
        if name not in cache:
            ss = get_serving_scenario(name)
            pts = ss.fit_points()
            res = cluster(pts, ss.base.eps, ss.base.min_pts, engine="grit",
                          return_index=True)
            cache[name] = (ss, pts, res)
        return cache[name]

    return get


def _oracle_assign(pts, core, labels, queries, eps):
    """Reference assignment: (labels, set-of-valid-labels-per-query)."""
    cpts = pts[core]
    clab = np.asarray(labels)[core]
    eps2 = float(eps) ** 2
    out = np.full(len(queries), -1, np.int64)
    valid = []
    for i, q in enumerate(queries):
        d2 = ((cpts - q) ** 2).sum(axis=1)
        j = d2.argmin()
        if d2[j] <= eps2:
            cand = set(clab[d2 == d2[j]].tolist())
            out[i] = clab[j]
            valid.append(cand)
        else:
            valid.append({-1})
    return out, valid


# --------------------------------------------------------------------------
# predict
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", SERVING)
def test_predict_matches_oracle_rule_host(name, fitted):
    """Acceptance: predict == brute-oracle assignment for every query
    scenario (near-cluster, empty-grid, outside-the-box, exact-eps)."""
    ss, pts, res = fitted(name)
    q = ss.query_batch()
    got = res.index.predict(q, mode="host")
    ref, valid = _oracle_assign(pts, res.core, res.labels, q, ss.base.eps)
    for i in range(len(q)):
        assert got[i] in valid[i], \
            f"query {i}: predicted {got[i]}, oracle allows {valid[i]}"
    # noise sets must agree exactly (no tie ambiguity there)
    np.testing.assert_array_equal(got == -1, ref == -1)


@pytest.mark.parametrize("name", SERVING)
def test_predict_kernel_mode_matches_host(name, fitted):
    """The slot-batched jitted path agrees with the float64 host path
    away from the knife edge (float32 can legitimately flip queries
    within ~1e-6 relative of eps; the scenario places only its
    deliberate exact-boundary queries there)."""
    ss, pts, res = fitted(name)
    q = ss.query_batch()
    idx = res.index
    host = idx.predict(q, mode="host")
    stats = {}
    kern = idx.predict(q, mode="kernel", stats=stats)
    assert stats["mode"] == "kernel" and stats["groups"] >= 1
    # mask out queries at the f32 knife edge of the eps ball
    cpts = pts[np.asarray(res.core)]
    eps = ss.base.eps
    decidable = np.ones(len(q), bool)
    for i, qq in enumerate(q):
        dmin = np.sqrt(((cpts - qq) ** 2).sum(axis=1).min())
        decidable[i] = abs(dmin - eps) > 1e-5 * eps
    np.testing.assert_array_equal(host[decidable], kern[decidable])


def test_predict_empty_grid_and_far_queries(fitted):
    ss, pts, res = fitted("query-heavy-3d")
    idx = res.index
    rng = np.random.default_rng(3)
    far = rng.uniform(-5e5, -2e5, size=(16, idx.d))     # far outside
    np.testing.assert_array_equal(idx.predict(far), np.full(16, -1))
    # empty interior cell: a fitted core point's label must be its own
    core_i = int(np.flatnonzero(res.core)[0])
    assert idx.predict(pts[core_i:core_i + 1])[0] == res.labels[core_i]


def test_predict_exact_eps_boundary_is_inside(fitted):
    """d(q, core) exactly == eps (as f64 evaluates it) must label the
    query (DBSCAN's <=), bit-identically to the oracle formula."""
    ss, pts, res = fitted("drift-2d")
    idx = res.index
    core_idx = np.flatnonzero(res.core)[:8]
    eps = ss.base.eps
    for ci in core_idx:
        q = pts[ci].copy()
        q[0] += eps
        d2 = ((pts[np.asarray(res.core)] - q) ** 2).sum(axis=1).min()
        want = idx.predict(q[None, :], mode="host")[0]
        if d2 <= eps ** 2:
            assert want >= 0
        else:
            # f64 rounding pushed the constructed point just outside;
            # the oracle must agree that it is noise
            assert want == -1


def test_predict_validates_inputs(fitted):
    _, _, res = fitted("drift-2d")
    with pytest.raises(ValueError, match="queries must be"):
        res.index.predict(np.zeros((3, 5)))
    bad = np.zeros((2, 2))
    bad[1, 0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        res.index.predict(bad)
    assert res.index.predict(np.zeros((0, 2))).shape == (0,)


def test_predict_caps_grow_monotonically(fitted):
    ss, pts, res = fitted("drift-2d")
    idx = res.index
    s1, s2 = {}, {}
    idx.predict(ss.query_batch(n=16), mode="kernel", stats=s1)
    caps1 = idx.predict_caps
    idx.predict(ss.query_batch(n=120), mode="kernel", stats=s2)
    caps2 = idx.predict_caps
    assert caps2.group_cap >= caps1.group_cap
    assert caps2.cand_cap >= caps1.cand_cap
    # a third call with the small batch must reuse the grown caps
    idx.predict(ss.query_batch(n=16), mode="kernel", stats=s1)
    assert not s1["caps_grew"]


# --------------------------------------------------------------------------
# insert
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", SERVING)
def test_insert_matches_from_scratch_recluster(name, fitted):
    """Acceptance: insert + read-out ≡ cluster() on the union set
    (canonicalized, contested borders excepted)."""
    ss, pts, res = fitted(name)
    snap = res.index.snapshot()
    idx = GritIndex.restore(snap)          # do not mutate the fixture
    batches = ss.insert_batches()
    for b in batches:
        st = idx.insert(b)
        assert st["inserted"] == len(b)
    union = np.concatenate([pts] + batches)
    assert idx.n == len(union)
    ref = brute_dbscan(union, ss.base.eps, ss.base.min_pts)
    assert_labels_conformant(union, ss.base.eps, ss.base.min_pts, ref,
                             idx.labels_arrival())
    # core flags must match the union oracle exactly
    np.testing.assert_array_equal(
        idx.core_arrival(),
        core_flags(union, ss.base.eps, ss.base.min_pts))


def test_insert_outside_bbox_shifts_identifier_origin(fitted):
    ss, pts, res = fitted("drift-2d")
    idx = GritIndex.restore(res.index.snapshot())
    below = pts.min(axis=0) - 10 * ss.base.eps
    batch = below[None, :] + np.random.default_rng(0).uniform(
        0, ss.base.eps, size=(8, idx.d))
    st = idx.insert(batch)
    assert st["id_shifted"]
    assert (idx.ids >= 0).all()
    assert (idx.id_shift > 0).any()
    # identifiers of OLD points must still resolve to their stored grid
    qids = idx.query_ids(idx.points)
    row_ids = np.repeat(idx.ids, idx.counts, axis=0)
    np.testing.assert_array_equal(qids, row_ids)


def test_insert_then_predict_uses_new_cores(fitted):
    """A dense inserted blob far from the fit set must turn its region
    from noise into a predictable cluster."""
    ss, pts, res = fitted("drift-2d")
    idx = GritIndex.restore(res.index.snapshot())
    rng = np.random.default_rng(7)
    center = pts.max(axis=0) + 50 * ss.base.eps
    blob = center + rng.normal(scale=0.3 * ss.base.eps,
                               size=(4 * ss.base.min_pts, idx.d))
    probe = center[None, :]
    assert idx.predict(probe)[0] == -1
    idx.insert(blob)
    lab = idx.predict(probe)[0]
    assert lab >= 0
    # and the new cluster id is one the fit never used
    assert lab >= res.n_clusters


@pytest.mark.parametrize("seed", range(4))
def test_insert_random_stress(seed):
    """Randomized splice property: blobs + uniform base, then batches
    engineered to bridge clusters (lerp between random base pairs),
    promote borders to core (jittered copies), and open new regions
    (uniform, partly outside the bounding box).  Union labels must stay
    conformant with the brute oracle after every batch."""
    rng = np.random.default_rng(1000 + seed)
    eps, min_pts = 6.0, 4
    centers = rng.uniform(20, 80, size=(3, 2))
    base = np.concatenate([
        centers[rng.integers(0, 3, 90)] + rng.normal(scale=4.0,
                                                     size=(90, 2)),
        rng.uniform(0, 100, size=(20, 2)),
    ])
    idx = cluster(base, eps, min_pts, engine="grit",
                  return_index=True).index
    inserted = []
    for _ in range(3):
        a, b = base[rng.integers(0, len(base), (2, 12))]
        bridge = a + rng.uniform(0, 1, size=(12, 1)) * (b - a)
        batch = np.concatenate([
            bridge,
            base[rng.integers(0, len(base), 8)] + rng.normal(
                scale=0.5 * eps, size=(8, 2)),
            rng.uniform(-15, 115, size=(8, 2)),
        ])
        idx.insert(batch)
        inserted.append(batch)
        union = np.concatenate([base] + inserted)
        ref = brute_dbscan(union, eps, min_pts)
        assert_labels_conformant(union, eps, min_pts, ref,
                                 idx.labels_arrival())


def test_insert_validates_inputs(fitted):
    _, _, res = fitted("drift-2d")
    idx = GritIndex.restore(res.index.snapshot())
    with pytest.raises(ValueError, match="insert batch"):
        idx.insert(np.zeros((3, 7)))
    bad = np.zeros((2, 2))
    bad[0, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        idx.insert(bad)
    # an empty batch is a no-op but returns the full stats shape (a
    # serving loop may log st["newly_core"] etc. unconditionally)
    st = idx.insert(np.zeros((0, 2)))
    assert st["inserted"] == 0 and st["newly_core"] == 0
    assert "t_total" in st and "affected_grids" in st


def test_fit_grid_invariant_survives_id_shift(fitted):
    """fit_grid must keep the GridIndex contract ids == floor((x -
    mins)/side) even after an insert translated the stored lattice."""
    ss, pts, res = fitted("drift-2d")
    idx = GritIndex.restore(res.index.snapshot())
    idx.insert(pts.min(axis=0)[None, :] - 7 * ss.base.eps)
    assert (idx.id_shift > 0).any()
    gi = idx.fit_grid
    order_pts = idx.points[np.argsort(idx.arrival)]
    want = np.floor((order_pts - gi.mins[None, :]) / gi.side)
    np.testing.assert_array_equal(gi.ids[gi.point_grid],
                                  want.astype(np.int64))


# --------------------------------------------------------------------------
# snapshot / restore
# --------------------------------------------------------------------------

def test_snapshot_roundtrip_bitexact(fitted):
    ss, pts, res = fitted("query-heavy-3d")
    idx = res.index
    snap = idx.snapshot()
    assert all(isinstance(v, np.ndarray) for v in snap.values()), \
        "snapshot must be flat numpy arrays (savez-able)"
    buf = io.BytesIO()
    idx.save(buf)
    buf.seek(0)
    idx2 = GritIndex.load(buf)
    for f in ("points", "arrival", "ids", "starts", "counts", "core",
              "labels", "mins", "id_shift"):
        np.testing.assert_array_equal(getattr(idx, f), getattr(idx2, f))
    assert (idx2.eps, idx2.min_pts, idx2.side, idx2.next_label) == \
        (idx.eps, idx.min_pts, idx.side, idx.next_label)
    q = ss.query_batch()
    np.testing.assert_array_equal(idx.predict(q, mode="host"),
                                  idx2.predict(q, mode="host"))
    # a restored index must keep serving inserts
    idx2.insert(ss.insert_batches()[0])


def test_snapshot_version_checked(fitted):
    _, _, res = fitted("drift-2d")
    snap = res.index.snapshot()
    snap["version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match="snapshot version"):
        GritIndex.restore(snap)


def test_snapshot_preserves_device_caps():
    sc = scenario_map()["blobs-2d"]
    pts = sc.points()
    res = cluster(pts, sc.eps, sc.min_pts, engine="device",
                  return_index=True)
    idx = res.index
    assert idx.caps is not None, "device fit must carry its GritCaps"
    idx2 = GritIndex.restore(idx.snapshot())
    assert idx2.caps == idx.caps


# --------------------------------------------------------------------------
# return_index across engines + result provenance
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["brute", "grit", "grit-ldf", "device"])
def test_return_index_for_every_engine(engine):
    sc = scenario_map()["blobs-2d"]
    pts = sc.points()
    res = cluster(pts, sc.eps, sc.min_pts, engine=engine,
                  return_index=True)
    idx = res.index
    assert isinstance(idx, GritIndex)
    np.testing.assert_array_equal(idx.labels_arrival(), res.labels)
    np.testing.assert_array_equal(idx.core_arrival(), res.core)
    # predicting a fitted core point returns its own cluster
    ci = int(np.flatnonzero(res.core)[0])
    assert idx.predict(pts[ci:ci + 1], mode="host")[0] == res.labels[ci]


def test_fit_index_helper():
    sc = scenario_map()["blobs-2d"]
    pts = sc.points()
    idx = fit_index(pts, sc.eps, sc.min_pts, engine="grit")
    assert isinstance(idx, GritIndex) and idx.n == len(pts)


def test_return_index_distributed_engine_carries_core():
    """The distributed engine now reports exact core flags (the SPMD
    step returns them per shard), so return_index must consume them
    directly instead of the grid-based fallback identification."""
    sc = scenario_map()["cross-slab-2d"]
    pts = sc.points()
    res = cluster(pts, sc.eps, sc.min_pts, engine="distributed",
                  return_index=True)
    assert res.core is not None, \
        "distributed result must carry core flags"
    np.testing.assert_array_equal(res.core,
                                  core_flags(pts, sc.eps, sc.min_pts))
    idx = res.index
    np.testing.assert_array_equal(idx.core_arrival(), res.core)
    ci = int(np.flatnonzero(res.core)[0])
    assert idx.predict(pts[ci:ci + 1], mode="host")[0] == res.labels[ci]


def test_cluster_result_carries_provenance():
    """Satellite: core indices + grid provenance ride on ClusterResult
    so downstream tooling does not re-derive them."""
    sc = scenario_map()["blobs-2d"]
    pts = sc.points()
    res = cluster(pts, sc.eps, sc.min_pts, engine="grit")
    np.testing.assert_array_equal(res.core_idx, np.flatnonzero(res.core))
    gi = res.grid
    assert isinstance(gi, GridIndex)
    ids, mins, side = identifiers(pts, sc.eps)
    np.testing.assert_array_equal(gi.ids[gi.point_grid], ids)
    assert gi.side == side
    # brute carries core_idx but no grid machinery
    res_b = cluster(pts, sc.eps, sc.min_pts, engine="brute")
    assert res_b.grid is None and res_b.core_idx is not None
