"""System behaviour: GriT-DBSCAN (all engines) vs the brute oracle."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.data.seed_spreader import seed_spreader
from repro.core.dbscan import grit_dbscan, brute_dbscan
from repro.core.device_dbscan import device_dbscan, GritCaps, PAD_COORD
from repro.core.validate import assert_dbscan_equivalent
from repro.core.grids import build_grids, build_grids_device, PAD_ID


@pytest.mark.parametrize("d", [2, 3, 5, 7])
@pytest.mark.parametrize("variant", ["simden", "varden"])
def test_grit_matches_brute(d, variant):
    pts = seed_spreader(500, d, variant=variant, restarts=4, seed=d)
    eps, min_pts = 4000.0, 8
    ref = brute_dbscan(pts, eps, min_pts)
    r = grit_dbscan(pts, eps, min_pts)
    assert_dbscan_equivalent(pts, eps, min_pts, ref, r.labels)


@pytest.mark.parametrize("variant", ["grit", "ldf"])
@pytest.mark.parametrize("neighbor_engine", ["tree", "stencil"])
@pytest.mark.parametrize("merge_engine", ["fast", "center", "brute"])
def test_engine_matrix_equivalent(variant, neighbor_engine, merge_engine):
    pts = seed_spreader(400, 3, variant="varden", restarts=4, seed=7)
    eps, min_pts = 4000.0, 8
    ref = brute_dbscan(pts, eps, min_pts)
    r = grit_dbscan(pts, eps, min_pts, variant=variant,
                    neighbor_engine=neighbor_engine,
                    merge_engine=merge_engine)
    assert_dbscan_equivalent(pts, eps, min_pts, ref, r.labels)


def test_kappa_small_like_paper():
    """Paper Remark 3: kappa <= 11 in all experiments."""
    pts = seed_spreader(2000, 3, variant="varden", restarts=6, seed=1)
    r = grit_dbscan(pts, 3000.0, 10)
    assert r.stats.get("merge_max_iters", 0) <= 11


# d=3 stays in the default run; the other dims are covered nightly (the
# conformance matrix also exercises the device engine at d in {2, 3})
@pytest.mark.parametrize("d", [
    pytest.param(2, marks=pytest.mark.slow), 3,
    pytest.param(5, marks=pytest.mark.slow)])
def test_device_dbscan_matches_brute(d):
    pts = seed_spreader(512, d, variant="simden", restarts=4, seed=10 + d)
    eps, min_pts = 4000.0, 8
    ref = brute_dbscan(pts, eps, min_pts)
    caps = GritCaps(grid_cap=256, frontier_cap=256, k_cap=48, c_cap=512,
                    m_cap=512, pair_cap=2048, grid_block=64, pair_block=256)
    r = device_dbscan(jnp.asarray(pts, jnp.float32), eps, min_pts, caps)
    assert not bool(r.overflow)
    assert_dbscan_equivalent(pts, eps, min_pts, ref, np.asarray(r.labels))


@pytest.mark.slow
def test_device_dbscan_respects_point_validity():
    pts = seed_spreader(256, 2, variant="simden", restarts=3, seed=3)
    eps, min_pts = 4000.0, 8
    caps = GritCaps(grid_cap=256, frontier_cap=256, k_cap=48, c_cap=512,
                    m_cap=512, pair_cap=2048, grid_block=64, pair_block=256)
    valid = jnp.asarray(np.arange(256) < 200)
    r = device_dbscan(jnp.asarray(pts, jnp.float32), eps, min_pts, caps,
                      point_valid=valid)
    labels = np.asarray(r.labels)
    assert (labels[200:] == -1).all()
    ref = brute_dbscan(pts[:200], eps, min_pts)
    assert_dbscan_equivalent(pts[:200], eps, min_pts, ref, labels[:200])


@pytest.mark.parametrize("use_kernels", [False, True])
def test_padding_points_never_share_a_grid_with_real_ones(use_kernels):
    """Regression: identifiers of PAD_COORD rows used to go through an
    out-of-range f32->int32 cast (implementation-defined in XLA; can
    wrap negative and lex-sort padding *ahead of* real grids, corrupting
    point_grid/starts).  Clamped to the PAD_ID sentinel, every padding
    point must land in the sentinel grid, strictly after all real grids,
    and the pipeline must stay exact under a point_valid mask."""
    pts = seed_spreader(192, 2, variant="simden", restarts=3, seed=7)
    n_valid = 150
    valid = np.arange(192) < n_valid
    padded = np.where(valid[:, None], pts, PAD_COORD)

    dg = build_grids_device(jnp.asarray(padded, jnp.float32), 4000.0,
                            grid_cap=256)
    point_grid = np.asarray(dg.point_grid)
    order = np.asarray(dg.order)
    real_grids = set(point_grid[np.isin(order, np.flatnonzero(valid))])
    pad_grids = set(point_grid[np.isin(order, np.flatnonzero(~valid))])
    assert not (real_grids & pad_grids), \
        f"padding shares grids with real points: {real_grids & pad_grids}"
    # the sentinel grid must sort after every real grid and carry PAD_ID
    ids = np.asarray(dg.ids)
    assert all(g > max(real_grids) for g in pad_grids)
    assert all((ids[g] == int(PAD_ID)).all() for g in pad_grids)

    caps = GritCaps(grid_cap=256, frontier_cap=256, k_cap=48, c_cap=512,
                    m_cap=512, pair_cap=2048, grid_block=64,
                    pair_block=256, use_kernels=use_kernels)
    r = device_dbscan(jnp.asarray(pts, jnp.float32), 4000.0, 8, caps,
                      point_valid=jnp.asarray(valid))
    assert not bool(r.overflow)
    labels = np.asarray(r.labels)
    assert (labels[n_valid:] == -1).all()
    ref = brute_dbscan(pts[:n_valid], 4000.0, 8)
    assert_dbscan_equivalent(pts[:n_valid], 4000.0, 8, ref,
                             labels[:n_valid])


def test_build_grids_empty_raises_cleanly():
    """The n == 0 guard must fire before identifiers() reduces an empty
    array (it used to be unreachable)."""
    with pytest.raises(ValueError, match="empty point set"):
        build_grids(np.zeros((0, 3)), 1.0)


def test_grid_build_host_vs_device():
    pts = seed_spreader(300, 3, variant="simden", restarts=3, seed=5)
    eps = 4000.0
    gi = build_grids(pts, eps)
    dg = build_grids_device(jnp.asarray(pts, jnp.float32), eps, grid_cap=512)
    ng = int(dg.num_grids)
    assert ng == gi.num_grids
    np.testing.assert_array_equal(np.asarray(dg.ids)[:ng], gi.ids)
    np.testing.assert_array_equal(np.asarray(dg.counts)[:ng], gi.counts)


def test_all_points_in_one_ball():
    """The O(n^2)-killer case from the paper's introduction."""
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(400, 3)) * 10.0
    eps = 1e5
    ref = brute_dbscan(pts, eps, 10)
    r = grit_dbscan(pts, eps, 10)
    assert_dbscan_equivalent(pts, eps, 10, ref, r.labels)
    assert r.stats["num_clusters"] == 1


def test_all_noise():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1e6, size=(100, 3))
    r = grit_dbscan(pts, 10.0, 5)
    assert (r.labels == -1).all()
