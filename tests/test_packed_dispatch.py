"""Occupancy-packed dispatch: packed-vs-dense bit-identity + the
work-proportionality regression the packing exists for.

``GritCaps.packed`` compacts live small grids to a candidate-total
sorted prefix and sweeps occupancy-tiered buckets (c_cap/4, c_cap/2,
c_cap sub-caps) instead of ``lax.map``-ing dense ``grid_cap``-wide
blocks; the merge sweeps only the valid-pair prefix and the neighbor
table only the live-grid prefix.  All of it is required to be
*bit-identical* to the dense path -- labels, core flags, grid
provenance, cluster count, and the full ``OverflowReport`` vector --
because the dense path is the in-graph oracle the conformance matrix
pinned.  See ``device_dbscan``'s module docstring for the exactness
argument (tier width bounds candidate total; order-independent
scatters; skipped merge blocks equal their init value).
"""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.data.scenarios import default_scenarios, scenario_map
from repro.core.device_dbscan import GritCaps, device_dbscan
from repro.core.grids import build_grids_device
from repro.core.grid_tree import device_neighbor_table
from repro.engine import (adaptive_device_dbscan, candidate_census,
                          cluster, estimate_caps, estimate_shard_caps)

SCENARIOS = scenario_map()
QUICK = sorted(s.name for s in default_scenarios() if s.has("quick"))
NOT_QUICK = sorted(set(SCENARIOS) - set(QUICK))


def _both_paths(pts, eps, min_pts, caps):
    pts = jnp.asarray(np.asarray(pts, np.float32))
    dense = device_dbscan(pts, eps, min_pts,
                          caps=dataclasses.replace(caps, packed=False))
    packed = device_dbscan(pts, eps, min_pts,
                           caps=dataclasses.replace(caps, packed=True))
    return dense, packed


def _assert_bit_identical(dense, packed):
    np.testing.assert_array_equal(np.asarray(dense.labels),
                                  np.asarray(packed.labels))
    np.testing.assert_array_equal(np.asarray(dense.core),
                                  np.asarray(packed.core))
    np.testing.assert_array_equal(np.asarray(dense.point_grid),
                                  np.asarray(packed.point_grid))
    assert int(dense.num_clusters) == int(packed.num_clusters)
    assert bool(dense.overflow) == bool(packed.overflow)
    np.testing.assert_array_equal(np.asarray(dense.report.as_vector()),
                                  np.asarray(packed.report.as_vector()))


# ---------------------------------------------------------------------------
# parity: scenario catalogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", QUICK)
@pytest.mark.parametrize("use_kernels", [False, True],
                         ids=["naive", "kernels"])
def test_packed_parity_quick(name, use_kernels):
    sc = SCENARIOS[name]
    pts = sc.points()
    caps = estimate_caps(np.asarray(pts, np.float32), sc.eps, sc.min_pts,
                         use_kernels=use_kernels)
    _assert_bit_identical(*_both_paths(pts, sc.eps, sc.min_pts, caps))


@pytest.mark.slow
@pytest.mark.parametrize("name", NOT_QUICK)
def test_packed_parity_full_catalogue(name):
    sc = SCENARIOS[name]
    pts = sc.points()
    caps = estimate_caps(np.asarray(pts, np.float32), sc.eps, sc.min_pts)
    _assert_bit_identical(*_both_paths(pts, sc.eps, sc.min_pts, caps))


# ---------------------------------------------------------------------------
# parity: adversarial occupancy skew
# ---------------------------------------------------------------------------

def test_packed_parity_one_huge_grid_many_singletons():
    """Worst tier skew: one grid holding half the points (all-core
    shortcut) surrounded by a sea of singleton grids (all tier 1)."""
    rng = np.random.default_rng(7)
    eps, min_pts = 4.0, 5
    dense_blob = rng.uniform(0, 1.0, size=(400, 2))
    singles = np.stack([rng.permutation(300) * 50.0 + 500.0,
                        rng.uniform(0, 1e4, 300)], axis=1)
    pts = np.concatenate([dense_blob, singles]).astype(np.float32)
    caps = estimate_caps(pts, eps, min_pts)
    _assert_bit_identical(*_both_paths(pts, eps, min_pts, caps))


def test_packed_parity_all_grids_at_min_pts_minus_one():
    """Every grid exactly at occupancy min_pts - 1: no all-core
    shortcut fires anywhere, every live grid goes through the tiered
    candidate sweep, and core status hinges on cross-grid counts."""
    rng = np.random.default_rng(11)
    eps, min_pts = 3.0, 4
    side = eps / np.sqrt(2.0)
    cells = np.stack(np.meshgrid(np.arange(12), np.arange(12)),
                     -1).reshape(-1, 2) * side
    pts = np.concatenate([
        c + rng.uniform(0.1 * side, 0.9 * side, size=(min_pts - 1, 2))
        for c in cells]).astype(np.float32)
    caps = estimate_caps(pts, eps, min_pts)
    _assert_bit_identical(*_both_paths(pts, eps, min_pts, caps))


def test_packed_parity_on_candidate_overflow():
    """A grid whose candidate total exceeds c_cap must raise the same
    candidates flag on both paths (the packed path derives it from the
    global totals, not from the widest tier's truncation)."""
    rng = np.random.default_rng(3)
    pts = np.asarray(rng.uniform(0, 4.0, size=(300, 2)), np.float32)
    eps, min_pts = 1.5, 200
    caps = estimate_caps(pts, eps, min_pts)
    caps = dataclasses.replace(caps, c_cap=32)   # force truncation
    dense, packed = _both_paths(pts, eps, min_pts, caps)
    assert bool(dense.report.candidates)
    _assert_bit_identical(dense, packed)


def test_packed_parity_pair_cap_exceeding_pair_universe():
    """pair_cap > grid_cap * k_cap pads the compacted pair prefix back
    up to the cap instead of crashing the block reshape."""
    rng = np.random.default_rng(5)
    pts = np.asarray(rng.uniform(0, 30.0, size=(120, 2)), np.float32)
    eps, min_pts = 4.0, 3
    caps = estimate_caps(pts, eps, min_pts)
    caps = dataclasses.replace(
        caps, grid_cap=64, grid_block=8, k_cap=8, pair_cap=1024,
        pair_block=256)
    _assert_bit_identical(*_both_paths(pts, eps, min_pts, caps))


def test_neighbor_table_packed_parity():
    rng = np.random.default_rng(13)
    pts = jnp.asarray(rng.uniform(0, 200.0, (500, 3)), jnp.float32)
    dg = build_grids_device(pts, 9.0, 1024)
    dense = device_neighbor_table(dg.ids, dg.num_grids, frontier_cap=64,
                                  k_cap=64, packed=False)
    packed = device_neighbor_table(dg.ids, dg.num_grids, frontier_cap=64,
                                   k_cap=64, packed=True)
    for a, b in zip(dense, packed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# work proportionality: dispatch must scale with live grids, not caps
# ---------------------------------------------------------------------------

def test_dispatch_scales_with_live_grids_not_grid_cap():
    """The regression the packing exists for: with grid_cap >> live
    grids, the packed sweep must visit ~num_grids rows, not grid_cap
    slots.  Asserted through the repro.obs dispatch gauges (recorded
    even with tracing off)."""
    rng = np.random.default_rng(17)
    pts = np.asarray(rng.uniform(0, 100.0, size=(400, 2)), np.float32)
    eps, min_pts = 5.0, 4
    caps = estimate_caps(pts, eps, min_pts)
    big = dataclasses.replace(caps, grid_cap=4096, grid_block=64,
                              pair_cap=65536)
    adaptive_device_dbscan(jnp.asarray(pts), eps, min_pts, big)
    snap = obs.registry().snapshot()
    swept = snap["device.dispatch.grids_swept"]["value"]
    cap = snap["device.dispatch.grid_cap"]["value"]
    assert cap == 4096.0
    assert snap["device.dispatch.dense_slots"]["value"] == 0.0
    # every live small grid is swept exactly once; the dead ~3700 slots
    # are never dispatched
    assert 0 < swept <= 400
    assert swept < cap / 4


def test_dense_path_reports_dense_slots():
    rng = np.random.default_rng(19)
    pts = np.asarray(rng.uniform(0, 100.0, size=(200, 2)), np.float32)
    caps = estimate_caps(pts, 5.0, 4)
    caps = dataclasses.replace(caps, packed=False)
    adaptive_device_dbscan(jnp.asarray(pts), 5.0, 4, caps)
    snap = obs.registry().snapshot()
    assert snap["device.dispatch.dense_slots"]["value"] == caps.grid_cap
    assert snap["device.dispatch.grids_swept"]["value"] == caps.grid_cap


# ---------------------------------------------------------------------------
# caps validation + snapshot round-trip of the packed flag
# ---------------------------------------------------------------------------

def test_grid_block_divisibility_validated():
    with pytest.raises(ValueError, match=r"grid_cap \(100\).*grid_block"):
        GritCaps(grid_cap=100, grid_block=64)
    with pytest.raises(ValueError, match=r"grid_block"):
        GritCaps(grid_block=0)


def test_pair_block_divisibility_validated():
    with pytest.raises(ValueError, match=r"pair_cap \(1000\).*pair_block"):
        GritCaps(pair_cap=1000, pair_block=256)
    with pytest.raises(ValueError, match=r"pair_block"):
        GritCaps(pair_block=-8)


def test_snapshot_round_trips_packed_flag():
    from repro.index import GritIndex, fit_index
    rng = np.random.default_rng(23)
    pts = rng.uniform(0, 50.0, size=(150, 2))
    for packed in (True, False):
        caps = dataclasses.replace(
            estimate_caps(np.asarray(pts, np.float32), 4.0, 4),
            packed=packed)
        idx = fit_index(pts, 4.0, 4, engine="device", caps=caps)
        restored = GritIndex.restore(idx.snapshot())
        assert restored.caps.packed is packed


def test_restore_accepts_pre_packed_snapshots():
    """10-slot caps arrays (pre-packed-dispatch snapshots) restore with
    packed defaulting on."""
    from repro.index import GritIndex, fit_index
    rng = np.random.default_rng(29)
    pts = rng.uniform(0, 50.0, size=(150, 2))
    caps = estimate_caps(np.asarray(pts, np.float32), 4.0, 4)
    idx = fit_index(pts, 4.0, 4, engine="device", caps=caps)
    snap = dict(idx.snapshot())
    assert len(snap["caps"]) == 11
    snap["caps"] = snap["caps"][:10]
    assert GritIndex.restore(snap).caps.packed is True


# ---------------------------------------------------------------------------
# census-sized caps (tentpole b): exactness of the host-side bounds
# ---------------------------------------------------------------------------

def test_candidate_census_bounds_device_totals():
    """The census is the stencil occupancy sum -- an upper bound on the
    device's (MinDist-pruned) per-grid candidate totals, so census-sized
    c_cap can never overflow on the fit that sized it."""
    rng = np.random.default_rng(31)
    pts = np.asarray(rng.uniform(0, 60.0, size=(600, 2)), np.float32)
    eps, min_pts = 4.0, 6
    cmax = candidate_census(pts, eps, min_pts)
    caps = estimate_caps(pts, eps, min_pts)
    assert caps.c_cap >= cmax
    res = device_dbscan(jnp.asarray(pts), eps, min_pts, caps)
    assert not bool(res.report.candidates)


def test_estimate_shard_caps_not_inflated_to_global():
    """On spread-out data the per-shard caps must come in under the
    global ones (the point of sizing per shard), while single-shard
    estimation degenerates to the global estimate."""
    rng = np.random.default_rng(37)
    pts = rng.uniform(0, 4000.0, size=(4000, 2))
    eps, min_pts = 20.0, 5
    g = estimate_caps(np.asarray(pts, np.float32), eps, min_pts)
    s = estimate_shard_caps(pts, eps, min_pts, n_shards=4)
    assert s.grid_cap <= g.grid_cap
    assert s.pair_cap <= g.pair_cap
    assert estimate_shard_caps(pts, eps, min_pts, n_shards=1) == g


def test_boundary_census_bounds_halo_cap():
    from repro.dist import boundary_census, census_halo_cap
    rng = np.random.default_rng(41)
    pts = rng.uniform(0, 1000.0, size=(3000, 2))
    worst = boundary_census(pts, 15.0, 4)
    cap = census_halo_cap(pts, 15.0, 4)
    assert cap >= worst
    # quarter-pow2 ladder: over-provisioning bounded at 25% (the
    # BENCH_8 halo padding-waste gate)
    assert cap <= max(1.25 * worst, 32)


def test_quarter_pow2_ladder():
    from repro.dist.halo import _quarter_pow2_at_least
    for x in (1, 8, 9, 100, 545, 1000, 4097):
        v = _quarter_pow2_at_least(x)
        assert v >= max(x, 8)
        # over-provisioning bounded at 25% of the (floor-clamped) census
        assert v <= 1.25 * max(x, 8)
    assert _quarter_pow2_at_least(545) == 640


# ---------------------------------------------------------------------------
# deprecation shim
# ---------------------------------------------------------------------------

def test_core_distributed_shim_warns():
    """The pre-dist-package home stays importable behind a
    DeprecationWarning pointing at repro.dist (the repro.index.insert
    treatment)."""
    import importlib
    import sys

    sys.modules.pop("repro.core.distributed", None)
    with pytest.warns(DeprecationWarning, match=r"repro\.dist"):
        shim = importlib.import_module("repro.core.distributed")
    import repro.dist as dist
    assert shim.distributed_fit is dist.distributed_fit
    assert shim.ClusterCaps is dist.ClusterCaps


def test_packed_is_default_and_matches_dense_end_to_end():
    """``packed`` defaults on, and the public engine entry point yields
    dense-path labels bit-for-bit under either strategy."""
    assert GritCaps().packed is True
    rng = np.random.default_rng(43)
    pts = rng.uniform(0, 80.0, size=(500, 2))
    eps, min_pts = 5.0, 5
    caps = estimate_caps(np.asarray(pts, np.float32), eps, min_pts)
    res = cluster(pts, eps, min_pts, engine="device", caps=caps)
    snap = obs.registry().snapshot()
    assert snap["device.dispatch.dense_slots"]["value"] == 0.0
    ref = cluster(pts, eps, min_pts, engine="device",
                  caps=dataclasses.replace(caps, packed=False))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels))
