"""Distributed serving conformance on a real (>= 4-way) mesh.

The acceptance bar of the sharded serving plane, exercised through the
actual SPMD fit: ``fit_sharded(mesh=...)`` runs the distributed engine
(shard_map + halo exchange + reconciliation) and shards the fitted
state; then ``predict`` must equal the brute-oracle assignment rule and
``insert`` + read-out must be label-conformant with a from-scratch
``cluster()`` on the union set, on every distributed-serving scenario.

Multi-device means subprocesses with
``--xla_force_host_platform_device_count`` (the main pytest process
must keep seeing exactly 1 device); all slow / nightly, like
``tests/test_distributed.py``.  The single-process (host-sharded)
equivalents run in tier-1 via ``tests/test_sharded_index.py``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(snippet: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         env=env, capture_output=True, text=True,
                         timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_spmd_fit_returns_exact_core_flags_and_provenance():
    """The SPMD step's per-shard core flags (unpermuted) must equal the
    O(n^2) oracle, and the grid provenance must cover every point."""
    out = _run("""
        import numpy as np, jax
        from repro.data.scenarios import get_scenario
        from repro.dist import distributed_fit, ClusterCaps
        from repro.engine import estimate_caps
        from repro.core.validate import core_flags

        mesh = jax.make_mesh((4,), ("shard",))
        for name in ("cross-slab-2d", "cross-slab-3d"):
            sc = get_scenario(name)
            pts = sc.points()
            caps = ClusterCaps(grit=estimate_caps(pts, sc.eps, sc.min_pts),
                               halo_cap=512)
            r = distributed_fit(pts, sc.eps, sc.min_pts, mesh, caps)
            assert not r.report
            np.testing.assert_array_equal(
                r.core, core_flags(pts, sc.eps, sc.min_pts))
            assert (r.point_grid >= 0).all()
            assert set(np.unique(r.shard_of)) <= set(range(4))
            assert len(r.cut_coords) == 3
            print(name, "CORE OK")
    """)
    assert out.count("CORE OK") == 2


def test_mesh_fit_sharded_predict_matches_oracle_rule():
    """Acceptance: ShardedGritIndex.predict ≡ the brute-oracle
    assignment rule on every distributed-serving scenario, fitted on a
    4-way mesh."""
    out = _run("""
        import numpy as np, jax
        from repro.data.scenarios import dist_serving_scenarios
        from repro.index import fit_sharded
        from repro.core.validate import core_flags

        mesh = jax.make_mesh((4,), ("shard",))
        for ss in dist_serving_scenarios():
            pts = ss.fit_points()
            eps, mp = ss.base.eps, ss.base.min_pts
            sidx = fit_sharded(pts, eps, mp, mesh=mesh)
            assert sidx.num_shards >= 2
            q = ss.query_batch()
            got = sidx.predict(q, mode="host")
            core = core_flags(pts, eps, mp)
            cpts = pts[core]
            clab = sidx.labels_arrival()[core]
            eps2 = eps * eps
            for i, qq in enumerate(q):
                d2 = ((cpts - qq) ** 2).sum(1)
                j = d2.argmin()
                if d2[j] <= eps2:
                    valid = set(clab[d2 == d2[j]].tolist())
                    assert got[i] in valid, (ss.name, i, got[i], valid)
                else:
                    assert got[i] == -1, (ss.name, i, got[i])
            print(ss.name, "PREDICT OK")
    """)
    assert out.count("PREDICT OK") == 3


def test_mesh_fit_sharded_insert_matches_recluster():
    """Acceptance: insert + read-out ≡ from-scratch cluster() on the
    union set (canonicalized, contested borders excepted) after every
    micro-batch, fitted on a 4-way mesh."""
    out = _run("""
        import numpy as np, jax
        from repro.data.scenarios import dist_serving_scenarios
        from repro.index import fit_sharded
        from repro.core.dbscan import brute_dbscan
        from repro.core.validate import assert_labels_conformant, core_flags

        mesh = jax.make_mesh((4,), ("shard",))
        for ss in dist_serving_scenarios():
            pts = ss.fit_points()
            eps, mp = ss.base.eps, ss.base.min_pts
            sidx = fit_sharded(pts, eps, mp, mesh=mesh)
            done = []
            for b in ss.insert_batches():
                sidx.insert(b)
                done.append(b)
                union = np.concatenate([pts] + done)
                ref = brute_dbscan(union, eps, mp)
                assert_labels_conformant(union, eps, mp, ref,
                                         sidx.labels_arrival())
                np.testing.assert_array_equal(
                    sidx.core_arrival(), core_flags(union, eps, mp))
            print(ss.name, "INSERT OK")
    """)
    assert out.count("INSERT OK") == 3


def test_mesh_fit_snapshot_serves_in_fresh_process_shape():
    """Distributed fit -> snapshot -> restore -> serve: the restored
    index must answer exactly like the fitted one and keep accepting
    inserts (the ship-between-processes story)."""
    out = _run("""
        import io
        import numpy as np, jax
        from repro.data.scenarios import get_dist_serving_scenario
        from repro.index import ShardedGritIndex, fit_sharded

        mesh = jax.make_mesh((4,), ("shard",))
        ss = get_dist_serving_scenario("slab-serve-2d")
        pts = ss.fit_points()
        sidx = fit_sharded(pts, ss.base.eps, ss.base.min_pts, mesh=mesh)
        buf = io.BytesIO()
        sidx.save(buf)
        buf.seek(0)
        sidx2 = ShardedGritIndex.load(buf)
        q = ss.query_batch()
        np.testing.assert_array_equal(sidx.predict(q, mode="host"),
                                      sidx2.predict(q, mode="host"))
        sidx2.insert(ss.insert_batches()[0])
        print("SNAPSHOT OK")
    """)
    assert "SNAPSHOT OK" in out


def test_distributed_engine_kernel_plane_on_mesh():
    """use_kernels=True threads through ClusterCaps into every shard's
    local pipeline (the tiled non-TPU fast path here) and stays exact."""
    out = _run("""
        import numpy as np, jax
        from repro.data.scenarios import get_scenario
        from repro.engine import cluster
        from repro.core.dbscan import brute_dbscan
        from repro.core.validate import assert_dbscan_equivalent

        sc = get_scenario("cross-slab-2d")
        pts = sc.points()
        res = cluster(pts, sc.eps, sc.min_pts, engine="distributed",
                      use_kernels=True)
        assert res.stats["use_kernels"] is True
        assert res.stats["n_shards"] == 4
        ref = brute_dbscan(pts, sc.eps, sc.min_pts)
        assert_dbscan_equivalent(pts, sc.eps, sc.min_pts, ref, res.labels)
        print("KERNEL PLANE OK")
    """)
    assert "KERNEL PLANE OK" in out
