"""Distributed DBSCAN + multi-device parity.

These need >1 device, so they run in a subprocess with
``--xla_force_host_platform_device_count`` (the main pytest process must
keep seeing exactly 1 device for all other tests).

All heavyweight (subprocess + multi-device compile): marked ``slow``,
covered by the nightly CI job.  The default run keeps a single-shard
distributed conformance case in tests/test_conformance.py.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(snippet: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         env=env, capture_output=True, text=True,
                         timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_distributed_dbscan_exact_vs_brute():
    out = _run("""
        import numpy as np, jax
        from repro.data.seed_spreader import seed_spreader
        from repro.core.dbscan import brute_dbscan
        from repro.dist import distributed_dbscan, ClusterCaps
        from repro.core.device_dbscan import GritCaps
        from repro.core.validate import assert_dbscan_equivalent

        mesh = jax.make_mesh((4,), ("data",))
        caps = ClusterCaps(grit=GritCaps(grid_cap=512, frontier_cap=256,
                                         k_cap=64, c_cap=2048, m_cap=1024,
                                         pair_cap=4096, grid_block=64,
                                         pair_block=256),
                           halo_cap=512)
        for d, seed in [(2, 0), (3, 1), (5, 2)]:
            pts = seed_spreader(800, d, variant="simden", restarts=5,
                                seed=seed)
            eps, min_pts = 4000.0, 8
            labels, ovf = distributed_dbscan(pts, eps, min_pts, mesh, caps)
            assert not ovf
            ref = brute_dbscan(pts, eps, min_pts)
            assert_dbscan_equivalent(pts, eps, min_pts, ref, labels)
            print(f"d={d} OK")
    """)
    assert out.count("OK") == 3


def test_cluster_spanning_all_shards():
    """One long snake cluster crossing every slab boundary."""
    out = _run("""
        import numpy as np, jax
        from repro.core.dbscan import brute_dbscan
        from repro.dist import distributed_dbscan, ClusterCaps
        from repro.core.device_dbscan import GritCaps
        from repro.core.validate import assert_dbscan_equivalent

        rng = np.random.default_rng(0)
        t = np.linspace(0, 1, 600)
        snake = np.stack([t * 1e5, 5e4 + 1e4 * np.sin(6 * t)], 1)
        snake += rng.normal(scale=300.0, size=snake.shape)
        noise = rng.uniform(0, 1e5, size=(60, 2))
        pts = np.concatenate([snake, noise])
        mesh = jax.make_mesh((4,), ("data",))
        caps = ClusterCaps(grit=GritCaps(grid_cap=512, frontier_cap=256,
                                         k_cap=64, c_cap=2048, m_cap=1024,
                                         pair_cap=4096, grid_block=64,
                                         pair_block=256),
                           halo_cap=512)
        eps, min_pts = 2500.0, 5
        labels, ovf = distributed_dbscan(pts, eps, min_pts, mesh, caps)
        assert not ovf
        ref = brute_dbscan(pts, eps, min_pts)
        assert_dbscan_equivalent(pts, eps, min_pts, ref, labels)
        # the snake is one cluster even though it crosses all 4 slabs
        snake_labels = set(labels[:600]) - {-1}
        assert len(snake_labels) == 1, snake_labels
        print("SNAKE OK")
    """)
    assert "SNAKE OK" in out


def test_data_parallel_train_parity_with_single_device():
    """2-device data-parallel step == single-device step (same batch)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params
        from repro.train import (TrainCfg, make_train_step, init_state,
                                 get_optimizer)
        from repro.data.tokens import TokenPipeline

        cfg = get_config("qwen1.5-0.5b", smoke=True).with_overrides(
            dtype="float32", remat=False)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = get_optimizer("adamw", weight_decay=0.0)
        tcfg = TrainCfg()
        step = make_train_step(cfg, tcfg, opt, lambda s: 1e-3)
        pipe = TokenPipeline(cfg.vocab_size, 16, 8, seed=0)
        batch = {"tokens": jnp.asarray(pipe.next_batch()["tokens"])}

        state = init_state(cfg, tcfg, opt, params)
        ref_state, ref_m = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((2,), ("data",))
        sb = jax.device_put(batch["tokens"],
                            NamedSharding(mesh, P("data", None)))
        state2 = init_state(cfg, tcfg, opt, params)
        dp_state, dp_m = jax.jit(step)(state2, {"tokens": sb})
        assert abs(float(ref_m["loss"]) - float(dp_m["loss"])) < 1e-4
        for a, b in zip(jax.tree_util.tree_leaves(ref_state["params"]),
                        jax.tree_util.tree_leaves(dp_state["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)
        print("PARITY OK")
    """, devices=2)
    assert "PARITY OK" in out


def test_cluster_step_lowers_on_production_mesh():
    """The shard_map cluster step must lower+compile on 16x16 (the same
    artifact the multi-pod dry-run exercises)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_production_mesh
        from repro.dist import make_cluster_step, ClusterCaps
        from repro.core.device_dbscan import GritCaps
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_production_mesh()          # 16 x 16 = 256 shards
        caps = ClusterCaps(grit=GritCaps(grid_cap=256, frontier_cap=128,
                                         k_cap=32, c_cap=512, m_cap=256,
                                         pair_cap=1024, grid_block=64,
                                         pair_block=256),
                           halo_cap=128)
        n_shard, d = 4096, 3
        step = make_cluster_step(mesh, 3000.0, 10, caps, n_shard, d)
        N = 256 * n_shard
        pts = jax.ShapeDtypeStruct(
            (N, d), jnp.float32,
            sharding=NamedSharding(mesh, P(("data", "model"), None)))
        valid = jax.ShapeDtypeStruct(
            (N,), jnp.bool_,
            sharding=NamedSharding(mesh, P(("data", "model"))))
        compiled = jax.jit(step).lower(pts, valid).compile()
        assert compiled is not None
        print("LOWERED OK")
    """, devices=512)
    assert "LOWERED OK" in out


def test_shardmap_moe_matches_reference():
    """Manual-SPMD MoE paths (model-local and expert-parallel all-to-all)
    vs the dense oracle, on a 2x2 fake mesh."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.config import LMConfig, MoECfg
        from repro.models import moe as M

        def check(E, mesh_shape, fn_name):
            cfg = LMConfig(name="t", family="moe", num_layers=1, d_model=32,
                           num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                           vocab_size=64, dtype="float32",
                           moe=MoECfg(num_experts=E, top_k=2, d_ff=64,
                                      capacity_factor=16.0))
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
            p = M.moe_params(cfg, jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32),
                                  jnp.float32)
            y_ref, _ = M.moe_forward_dense_fallback(cfg, p, x)
            fn = getattr(M, fn_name)
            y, aux = jax.jit(lambda p, x: fn(cfg, p, x, mesh, ("data",),
                                             "model"))(p, x)
            err = float(jnp.abs(y - y_ref).max())
            assert err < 1e-4, (fn_name, E, err)
            print(fn_name, E, "OK")

        check(4, (2, 2), "moe_forward_shardmap")    # experts over model
        check(2, (1, 4), "moe_forward_shardmap")    # ff-split virtual experts
        check(4, (2, 2), "moe_forward_shardmap_ep") # expert-parallel a2a
        check(8, (2, 2), "moe_forward_shardmap_ep")
    """)
    assert out.count("OK") == 4


def test_traced_fit_staged_matches_fused():
    """The staged SPMD step (halo / local / reconcile as separate
    programs, used by the tracer for stage-boundary timing) must be
    bit-identical to the fused default, and the traced fit's stage
    spans must account for >= 90% of the dist.fit wall-clock."""
    out = _run("""
        import numpy as np, jax
        from repro import obs
        from repro.obs import view as obs_view
        from repro.data.scenarios import get_scenario
        from repro.engine import cluster
        from repro.dist.api import distributed_fit

        mesh = jax.make_mesh((4,), ("shard",))
        sc = get_scenario("blobs-2d")
        n = 4000
        eps = sc.eps * (sc.n / n) ** (1.0 / sc.d)
        pts = sc.points(n=n)

        fused = distributed_fit(pts, eps, sc.min_pts, mesh, traced=False)
        obs.enable(clear=True)
        staged = distributed_fit(pts, eps, sc.min_pts, mesh, traced=True)
        events = obs.get_tracer().snapshot_events()
        obs.disable()

        assert np.array_equal(fused.labels, staged.labels)
        assert np.array_equal(fused.core, staged.core)
        assert bool(fused.report) == bool(staged.report)
        print("PARITY OK")

        att = obs_view.attribution(events, root="dist.fit")
        stages = {k.rsplit(".", 1)[-1] for k in att["children"]}
        assert {"pack", "halo_exchange", "local_cluster",
                "reconcile"} <= stages, stages
        assert att["coverage"] >= 0.9, att["coverage"]
        print(f"COVERAGE OK {att['coverage']:.3f}")
    """)
    assert "PARITY OK" in out and "COVERAGE OK" in out
