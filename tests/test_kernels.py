"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles.

A representative subset of each sweep runs in the default tier-1 pass;
the full shape matrix is nightly (``slow``) -- on CPU every distinct
shape is a fresh interpret-mode compile at ~1s apiece.
"""

import zlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

slow = pytest.mark.slow


def _rng(*key) -> np.random.Generator:
    """Per-test RNG seeded from the param tuple, so a test id sees the
    same data regardless of which other params the -m selection runs
    (crc32, not hash(): str hashing is salted per process)."""
    return np.random.default_rng(zlib.crc32(repr(key).encode()))


def _pts(rng, m, d, dtype):
    return jnp.asarray(rng.normal(size=(m, d)) * 10, dtype)


@pytest.mark.parametrize("m,n,d", [
    (1, 1, 1), (5, 7, 2),
    pytest.param(127, 129, 3, marks=slow),
    pytest.param(128, 128, 7, marks=slow),
    pytest.param(200, 64, 5, marks=slow),
    pytest.param(64, 300, 4, marks=slow),
    (256, 256, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_eps_count_sweep(m, n, d, dtype):
    rng = _rng("eps_count", m, n, d, str(dtype))
    a, b = _pts(rng, m, d, dtype), _pts(rng, n, d, dtype)
    vb = jnp.asarray(rng.uniform(size=n) > 0.3)
    eps = 6.0
    got = ops.eps_count(a, b, eps, vb)
    want = ref.eps_count(a, b, eps, vb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,d", [
    (3, 9, 2), (130, 257, 3),
    pytest.param(128, 128, 5, marks=slow),
    pytest.param(64, 512, 7, marks=slow),
])
def test_row_min_sweep(m, n, d):
    rng = _rng("row_min", m, n, d)
    a, b = _pts(rng, m, d, jnp.float32), _pts(rng, n, d, jnp.float32)
    vb = jnp.asarray(rng.uniform(size=n) > 0.2)
    got_m, got_i = ops.row_min(a, b, vb)
    want_m, want_i = ref.row_min(a, b, vb)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


@pytest.mark.parametrize("b,h,sq,sk,dh,causal,window,cap", [
    (2, 3, 64, 64, 32, True, None, None),
    pytest.param(1, 2, 128, 128, 64, True, 32, None, marks=slow),
    pytest.param(1, 2, 100, 100, 64, True, None, 50.0, marks=slow),
    (2, 1, 1, 96, 32, True, None, None),        # decode
    pytest.param(1, 2, 80, 80, 64, False, None, None,  # encoder
                 marks=slow),
    pytest.param(1, 1, 64, 192, 32, True, None, None,  # chunked prefix
                 marks=slow),
    (1, 2, 256, 256, 64, True, 128, 30.0),      # SWA + softcap (gemma-ish)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, sq, sk, dh, causal, window, cap, dtype):
    rng = _rng("flash", b, h, sq, sk, dh, causal, window, cap, str(dtype))
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, sk, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, sk, dh)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    want = ref.mha(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_eps_count_matches_bruteforce_semantics():
    a = _pts(_rng("brute_semantics"), 50, 3, jnp.float32)
    got = ops.eps_count(a, a, 5.0)
    d2 = ((np.asarray(a)[:, None] - np.asarray(a)[None]) ** 2).sum(-1)
    want = (d2 <= 25.0).sum(1)
    np.testing.assert_array_equal(np.asarray(got), want)
