"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles.

A representative subset of each sweep runs in the default tier-1 pass;
the full shape matrix is nightly (``slow``) -- on CPU every distinct
shape is a fresh interpret-mode compile at ~1s apiece.
"""

import zlib

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

slow = pytest.mark.slow


def _rng(*key) -> np.random.Generator:
    """Per-test RNG seeded from the param tuple, so a test id sees the
    same data regardless of which other params the -m selection runs
    (crc32, not hash(): str hashing is salted per process)."""
    return np.random.default_rng(zlib.crc32(repr(key).encode()))


def _pts(rng, m, d, dtype):
    return jnp.asarray(rng.normal(size=(m, d)) * 10, dtype)


@pytest.mark.parametrize("m,n,d", [
    (1, 1, 1), (5, 7, 2),
    pytest.param(127, 129, 3, marks=slow),
    pytest.param(128, 128, 7, marks=slow),
    pytest.param(200, 64, 5, marks=slow),
    pytest.param(64, 300, 4, marks=slow),
    (256, 256, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_eps_count_sweep(m, n, d, dtype):
    rng = _rng("eps_count", m, n, d, str(dtype))
    a, b = _pts(rng, m, d, dtype), _pts(rng, n, d, dtype)
    vb = jnp.asarray(rng.uniform(size=n) > 0.3)
    eps = 6.0
    got = ops.eps_count(a, b, eps, vb)
    want = ref.eps_count(a, b, eps, vb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,n,d", [
    (3, 9, 2), (130, 257, 3),
    pytest.param(128, 128, 5, marks=slow),
    pytest.param(64, 512, 7, marks=slow),
])
def test_row_min_sweep(m, n, d):
    rng = _rng("row_min", m, n, d)
    a, b = _pts(rng, m, d, jnp.float32), _pts(rng, n, d, jnp.float32)
    vb = jnp.asarray(rng.uniform(size=n) > 0.2)
    got_m, got_i = ops.row_min(a, b, vb)
    want_m, want_i = ref.row_min(a, b, vb)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


@pytest.mark.parametrize("b,h,sq,sk,dh,causal,window,cap", [
    (2, 3, 64, 64, 32, True, None, None),
    pytest.param(1, 2, 128, 128, 64, True, 32, None, marks=slow),
    pytest.param(1, 2, 100, 100, 64, True, None, 50.0, marks=slow),
    (2, 1, 1, 96, 32, True, None, None),        # decode
    pytest.param(1, 2, 80, 80, 64, False, None, None,  # encoder
                 marks=slow),
    pytest.param(1, 1, 64, 192, 32, True, None, None,  # chunked prefix
                 marks=slow),
    (1, 2, 256, 256, 64, True, 128, 30.0),      # SWA + softcap (gemma-ish)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, sq, sk, dh, causal, window, cap, dtype):
    rng = _rng("flash", b, h, sq, sk, dh, causal, window, cap, str(dtype))
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, sk, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(b, h, sk, dh)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    want = ref.mha(q, k, v, causal=causal, window=window, softcap=cap)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_eps_count_matches_bruteforce_semantics():
    a = _pts(_rng("brute_semantics"), 50, 3, jnp.float32)
    got = ops.eps_count(a, a, 5.0)
    d2 = ((np.asarray(a)[:, None] - np.asarray(a)[None]) ** 2).sum(-1)
    want = (d2 <= 25.0).sum(1)
    np.testing.assert_array_equal(np.asarray(got), want)


# --------------------------------------------------------------------------
# batched (leading grid-batch dimension) kernels: Pallas (interpret) vs
# the pure-jnp oracles, on deliberately unaligned shapes
# --------------------------------------------------------------------------

def _batch(key, bsz, m, n, d):
    rng = _rng(*key)
    a = jnp.asarray(rng.normal(size=(bsz, m, d)) * 10, jnp.float32)
    b = jnp.asarray(rng.normal(size=(bsz, n, d)) * 10, jnp.float32)
    vb = jnp.asarray(rng.uniform(size=(bsz, n)) > 0.3)
    # whole-slot mask: one batch row with *no* valid candidate at all
    if bsz > 1:
        vb = vb.at[0].set(False)
    return a, b, vb


# M, N deliberately not multiples of 128; d sweeps the supported 1..5
BATCH_SHAPES = [
    (1, 1, 1, 1), (3, 5, 7, 2), (2, 17, 130, 3),
    pytest.param(4, 127, 129, 4, marks=slow),
    pytest.param(2, 128, 256, 5, marks=slow),
    pytest.param(3, 130, 257, 1, marks=slow),
    pytest.param(2, 64, 300, 5, marks=slow),
]


@pytest.mark.parametrize("bsz,m,n,d", BATCH_SHAPES)
def test_eps_count_batch_parity(bsz, m, n, d):
    a, b, vb = _batch(("eps_count_batch", bsz, m, n, d), bsz, m, n, d)
    got = ops.eps_count_batch(a, b, 6.0, vb, interpret=True)
    want = ref.eps_count_batch(a, b, 6.0, vb)
    assert got.shape == (bsz, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bsz,m,n,d", BATCH_SHAPES)
def test_row_min_batch_parity(bsz, m, n, d):
    a, b, vb = _batch(("row_min_batch", bsz, m, n, d), bsz, m, n, d)
    got_m, got_i = ops.row_min_batch(a, b, vb, interpret=True)
    want_m, want_i = ref.row_min_batch(a, b, vb)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    if bsz > 1:   # the all-masked slot obeys the (inf, -1) contract
        assert np.isinf(np.asarray(got_m[0])).all()
        assert (np.asarray(got_i[0]) == -1).all()


@pytest.mark.parametrize("bsz,m,n,d", BATCH_SHAPES)
def test_batch_default_dispatch_parity(bsz, m, n, d):
    """The default (non-TPU) dispatch -- the tiled while-loop fast path
    -- must agree with the oracles too, not just the interpreted Pallas
    kernels.  The tiled path sums (a-b)^2 directly while the oracle uses
    the matmul form, so an argmin may legitimately land on the *other*
    member of a distance tie (1-ulp rounding flip); differing indices
    are accepted only when they are such ties."""
    a, b, vb = _batch(("tiled", bsz, m, n, d), bsz, m, n, d)
    got = ops.eps_count_batch(a, b, 6.0, vb)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.eps_count_batch(a, b, 6.0, vb)))
    got_m, got_i = ops.row_min_batch(a, b, vb)
    want_m, want_i = ref.row_min_batch(a, b, vb)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-4)
    got_i, want_i = np.asarray(got_i), np.asarray(want_i)
    vb_np = np.asarray(vb)
    differ = got_i != want_i
    if differ.any():
        d2 = np.asarray(ref.sq_dists_batch(a, b))
        for bb, mm in zip(*np.nonzero(differ)):
            gi, wi = got_i[bb, mm], want_i[bb, mm]
            assert gi >= 0 and vb_np[bb, gi], \
                f"[{bb},{mm}]: argmin {gi} is not a valid candidate"
            np.testing.assert_allclose(
                d2[bb, mm, gi], d2[bb, mm, wi], rtol=1e-5, atol=1e-4,
                err_msg=f"[{bb},{mm}]: argmins {gi} vs {wi} not a tie")


@pytest.mark.parametrize("stop_at", [1, 3, 8, 1000])
def test_eps_count_stop_at_contract(stop_at):
    """Saturating-count contract: with stop_at=k, min(count, k) must
    equal min(exact, k) -- thresholding at >= k (core identification)
    is exact even though counts may saturate once every valid a-row has
    k hits."""
    bsz, m, n, d = 3, 9, 260, 2
    a, b, vb = _batch(("stop_at", bsz, m, n, d), bsz, m, n, d)
    va = jnp.asarray(_rng("stop_at_va", stop_at).uniform(size=(bsz, m)) > 0.2)
    exact = np.asarray(ref.eps_count_batch(a, b, 6.0, vb))
    got = np.asarray(ops.eps_count_batch(a, b, 6.0, vb, va,
                                         stop_at=stop_at))
    va_np = np.asarray(va)
    np.testing.assert_array_equal(
        np.minimum(got, stop_at)[va_np], np.minimum(exact, stop_at)[va_np])
    assert (got[va_np] <= exact[va_np]).all()


def test_row_min_no_valid_candidate_contract():
    """Every b-row masked -> (inf, -1), never a bogus in-range argmin
    over FAR padding (border_block depends on this whenever a grid has
    no core candidates).  Holds for the wrapper on both dispatch paths
    and for the oracle, batched and not."""
    rng = _rng("row_min_contract")
    a = jnp.asarray(rng.normal(size=(5, 3)) * 10, jnp.float32)
    b = jnp.asarray(rng.normal(size=(9, 3)) * 10, jnp.float32)
    none = jnp.zeros((9,), bool)
    for m, i in [ref.row_min(a, b, none),
                 ops.row_min(a, b, none),
                 ref.row_min_batch(a[None], b[None], none[None]),
                 ops.row_min_batch(a[None], b[None], none[None]),
                 ops.row_min_batch(a[None], b[None], none[None],
                                   interpret=True)]:
        assert np.isinf(np.asarray(m)).all()
        assert (np.asarray(i) == -1).all()


def test_eps_exactly_on_tile_boundary_ties():
    """Distances exactly equal to eps (d2 == eps2, exactly representable
    in f32) must count as hits (<= is inclusive) in kernel and oracle
    alike, including for tie points straddling the 128-column tile
    boundary where the j-accumulation switches tiles."""
    n, d = 130, 2
    b = np.zeros((n, d), np.float32)
    b[:, 0] = np.arange(n, dtype=np.float32)     # integer grid: exact f32
    # a-row at x = 6: points at x in {0, 12} sit at distance exactly 6;
    # a-row at x = 121: ties at {115, 127} -- both sides of column 128
    a = np.zeros((2, d), np.float32)
    a[0, 0] = 6.0
    a[1, 0] = 121.0
    eps = 6.0
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    want = ((a[:, None, 0] - b[None, :, 0]) ** 2 <= eps ** 2).sum(1)
    for got in [ref.eps_count(aj, bj, eps),
                ops.eps_count(aj, bj, eps),
                ref.eps_count_batch(aj[None], bj[None], eps)[0],
                ops.eps_count_batch(aj[None], bj[None], eps,
                                    interpret=True)[0]]:
        np.testing.assert_array_equal(np.asarray(got), want)
    # the nearest-core tie at exactly eps must also survive row_min's
    # <=-side: min d2 == eps2 exactly
    m, i = ops.row_min_batch(aj[None], bj[None],
                             jnp.asarray(np.arange(n) == 127)[None],
                             interpret=True)
    assert float(m[0, 1]) == eps ** 2 and int(i[0, 1]) == 127


# --------------------------------------------------------------------------
# guard-band kernels (device-resident serving path): two-threshold counts
# and first/runner-up minima vs the oracles, on both dispatch paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bsz,m,n,d", BATCH_SHAPES)
def test_eps_count_band_batch_parity(bsz, m, n, d):
    a, b, vb = _batch(("band", bsz, m, n, d), bsz, m, n, d)
    want_lo = ref.eps_count_batch(a, b, 5.7, vb)
    want_hi = ref.eps_count_batch(a, b, 6.3, vb)
    for kw in [dict(interpret=True), dict()]:
        got_lo, got_hi = ops.eps_count_band_batch(a, b, 5.7, 6.3, vb, **kw)
        assert got_lo.shape == (bsz, m)
        np.testing.assert_array_equal(np.asarray(got_lo), np.asarray(want_lo))
        np.testing.assert_array_equal(np.asarray(got_hi), np.asarray(want_hi))
        assert (np.asarray(got_lo) <= np.asarray(got_hi)).all()


@pytest.mark.parametrize("bsz,m,n,d", BATCH_SHAPES)
def test_row_min2_batch_parity(bsz, m, n, d):
    a, b, vb = _batch(("min2", bsz, m, n, d), bsz, m, n, d)
    want_m, want_m2, want_i = ref.row_min2_batch(a, b, vb)
    for kw in [dict(interpret=True), dict()]:
        got_m, got_m2, got_i = ops.row_min2_batch(a, b, vb, **kw)
        np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(got_m2), np.asarray(want_m2),
                                   rtol=1e-5, atol=1e-4)
        # a differing argmin is legal only on a distance tie (the two
        # dispatch paths use different d2 summation orders)
        got_iv, want_iv = np.asarray(got_i), np.asarray(want_i)
        differ = got_iv != want_iv
        if differ.any():
            d2 = np.asarray(ref.sq_dists_batch(a, b))
            vb_np = np.asarray(vb)
            for bb, mm in zip(*np.nonzero(differ)):
                gi = got_iv[bb, mm]
                assert gi >= 0 and vb_np[bb, gi]
                np.testing.assert_allclose(
                    d2[bb, mm, gi], d2[bb, mm, want_iv[bb, mm]],
                    rtol=1e-5, atol=1e-4)
        if bsz > 1:   # all-masked slot: (inf, inf, -1)
            assert np.isinf(np.asarray(got_m[0])).all()
            assert np.isinf(np.asarray(got_m2[0])).all()
            assert (np.asarray(got_i[0]) == -1).all()


def test_row_min2_single_candidate_contract():
    """Exactly one valid candidate -> (d2, inf, idx): the runner-up is
    inf so the device path's argmin-margin test is trivially certain."""
    rng = _rng("min2_single")
    a = jnp.asarray(rng.normal(size=(1, 4, 3)) * 10, jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 9, 3)) * 10, jnp.float32)
    vb = jnp.asarray(np.arange(9) == 5)[None]
    for kw in [dict(interpret=True), dict()]:
        m, m2, i = ops.row_min2_batch(a, b, vb, **kw)
        d2 = np.asarray(ref.sq_dists_batch(a, b))[0, :, 5]
        np.testing.assert_allclose(np.asarray(m[0]), d2, rtol=1e-5, atol=1e-4)
        assert np.isinf(np.asarray(m2[0])).all()
        assert (np.asarray(i[0]) == 5).all()


@pytest.mark.parametrize("bar", [0, 2, 5, 1000])
def test_eps_count_band_stop_row_contract(bar):
    """Per-row saturation contract: any row whose returned lo-count is
    *below* its bar has scanned every valid candidate, so both its
    counts must equal the exact oracle counts.  Rows at/over the bar may
    have stopped early (counts are lower bounds)."""
    bsz, m, n, d = 3, 9, 260, 2
    a, b, vb = _batch(("band_stop", bsz, m, n, d), bsz, m, n, d)
    rows = _rng("band_stop_bars", bar).integers(0, max(bar, 1) + 1,
                                                size=(bsz, m))
    stop = jnp.asarray(rows, jnp.int32)
    exact_lo = np.asarray(ref.eps_count_batch(a, b, 5.7, vb))
    exact_hi = np.asarray(ref.eps_count_batch(a, b, 6.3, vb))
    got_lo, got_hi = ops.eps_count_band_batch(a, b, 5.7, 6.3, vb,
                                              stop_row=stop)
    got_lo, got_hi = np.asarray(got_lo), np.asarray(got_hi)
    assert (got_lo <= exact_lo).all() and (got_hi <= exact_hi).all()
    done = got_lo < rows
    np.testing.assert_array_equal(got_lo[done], exact_lo[done])
    np.testing.assert_array_equal(got_hi[done], exact_hi[done])
