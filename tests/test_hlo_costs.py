"""Loop-aware HLO cost analysis: validated against unrolled references."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.launch.hlo_costs import analyze, parse_hlo
from repro.launch.hlo_analysis import (shape_bytes, collective_bytes,
                                       roofline_terms)


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_matches_unroll():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a_s = analyze(_compiled_text(f_scan, xs, ws))
    a_u = analyze(_compiled_text(f_unroll, xs, ws))
    dot_flops = 10 * 2 * 128 ** 3
    assert abs(a_s["flops"] - dot_flops) / dot_flops < 0.05
    assert abs(a_u["flops"] - dot_flops) / dot_flops < 0.05
    # scanned and unrolled bytes within 2x of each other
    assert 0.5 < a_s["bytes"] / a_u["bytes"] < 2.0


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = analyze(_compiled_text(f, xs, ws))
    dot_flops = 20 * 2 * 128 ** 3
    assert abs(a["flops"] - dot_flops) / dot_flops < 0.05


def test_dynamic_slice_bytes_not_amplified():
    """Reading one [1, 4096] row per iteration from a [64, 4096] stack
    must cost ~64 rows total, not 64 x the whole stack."""
    def f(stack):
        def body(c, i):
            row = jax.lax.dynamic_index_in_dim(stack, i, 0)
            return c + row[0], None
        out, _ = jax.lax.scan(body, jnp.zeros((4096,)),
                              jnp.arange(64), length=64)
        return out

    xs = jax.ShapeDtypeStruct((64, 4096), jnp.float32)
    a = analyze(_compiled_text(f, xs))
    stack_bytes = 64 * 4096 * 4
    assert a["bytes"] < 8 * stack_bytes      # O(1x), not O(64x)


def test_shape_bytes():
    assert shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert shape_bytes("f32[4]") == 16
    assert shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert shape_bytes("pred[16]") == 16


def test_collective_parsing_on_synthetic_hlo():
    hlo = """
ENTRY %main.1 (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 2 * 0.75 * 4096
    assert c["all-gather"] == 0.75 * 16384
    assert c["collective-permute"] == 4096


def test_roofline_terms_dominance():
    r = roofline_terms(197e12, 0.0, 0.0)       # 1s of pure compute
    assert r["dominant"] == "compute"
    assert r["compute_fraction"] == 1.0
    r = roofline_terms(197e10, 819e9, 0.0)
    assert r["dominant"] == "memory"
    r = roofline_terms(0.0, 0.0, 50e9)
    assert r["dominant"] == "collective"


def test_parse_hlo_finds_computations():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y
    txt = _compiled_text(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_hlo(txt)
    assert any("main" in n for n in comps)
    assert len(comps) >= 2       # entry + loop body/cond at minimum
