"""Snapshot plumbing guards: the version check must fail *clearly* --
a wrong-file / truncated / future-version snapshot raises ``ValueError``
naming what went wrong, never a raw ``KeyError`` or ``BadZipFile`` from
deep inside the reader.  Both accepted versions keep restoring."""

import io

import numpy as np
import pytest

from repro.data.scenarios import get_serving_scenario
from repro.engine import cluster
from repro.index import GritIndex
from repro.index.snapshot_io import (check_version, load_snapshot,
                                     save_snapshot)


@pytest.fixture(scope="module")
def fitted():
    ss = get_serving_scenario("drift-2d")
    pts = ss.fit_points()
    res = cluster(pts, ss.base.eps, ss.base.min_pts, engine="grit",
                  return_index=True)
    return res.index


def test_v2_roundtrip(fitted):
    buf = io.BytesIO()
    fitted.save(buf)
    buf.seek(0)
    back = GritIndex.load(buf)
    assert np.array_equal(back.labels, fitted.labels)
    assert np.array_equal(back.alive, fitted.alive)
    assert int(np.asarray(fitted.snapshot()["version"])[0]) == 2


def test_v1_snapshot_restores(fitted):
    """A v1 snapshot (no mutation-plane arrays) must keep restoring:
    tombstones default to all-alive, merge graph rebuilds lazily."""
    snap = fitted.snapshot()
    for k in ("alive", "live_counts", "merge_edges", "has_merge_graph"):
        snap.pop(k)
    snap["version"] = np.asarray([1], np.int64)
    back = GritIndex.restore(snap)
    assert np.array_equal(back.labels, fitted.labels)
    assert back.alive.all()
    assert back.merge_edges is None
    # and the lazily rebuilt graph equals the fitted one
    assert np.array_equal(back.ensure_merge_graph(),
                          fitted.ensure_merge_graph())


def test_unknown_version_rejected(fitted):
    snap = fitted.snapshot()
    snap["version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match=r"version 99"):
        GritIndex.restore(snap)


def test_missing_version_field_is_value_error(fitted):
    """A mapping without the version field (wrong file / truncated
    writer) must raise a naming ValueError, not a KeyError."""
    snap = fitted.snapshot()
    del snap["version"]
    with pytest.raises(ValueError, match=r"no 'version' field"):
        GritIndex.restore(snap)
    with pytest.raises(ValueError, match=r"snapshot"):
        check_version(snap, "version", (1, 2), "snapshot")


def test_empty_version_field_is_value_error():
    with pytest.raises(ValueError, match=r"empty"):
        check_version({"version": np.empty(0, np.int64)},
                      "version", (1, 2), "snapshot")


def test_truncated_npz_is_value_error(fitted, tmp_path):
    """A half-written .npz (crashed writer) must fail loudly at load
    with the file named, not as a BadZipFile from the zip reader."""
    path = tmp_path / "snap.npz"
    fitted.save(str(path))
    raw = path.read_bytes()
    for cut in (len(raw) // 2, 10):
        trunc = tmp_path / f"trunc_{cut}.npz"
        trunc.write_bytes(raw[:cut])
        with pytest.raises(ValueError, match=r"trunc_.*npz"):
            load_snapshot(str(trunc))
        with pytest.raises(ValueError):
            GritIndex.load(str(trunc))


def test_wrong_npz_is_value_error(tmp_path):
    """A structurally valid .npz that is not a snapshot (no version
    field) fails the version check, not a KeyError."""
    path = tmp_path / "other.npz"
    np.savez(str(path), foo=np.arange(3))
    snap = load_snapshot(str(path))
    with pytest.raises(ValueError, match=r"no 'version' field"):
        check_version(snap, "version", (1, 2), "snapshot")


def test_save_load_helpers_roundtrip(tmp_path):
    snap = {"version": np.asarray([2], np.int64),
            "x": np.arange(5, dtype=np.float64)}
    p = tmp_path / "s.npz"
    save_snapshot(str(p), snap)
    back = load_snapshot(str(p))
    assert set(back) == {"version", "x"}
    assert np.array_equal(back["x"], snap["x"])
    assert check_version(back, "version", (1, 2), "snapshot") == 2


# ----------------------------------------------------------------------
# sharded snapshot versioning (v3: cut history + replica cursor)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_fitted():
    from repro.index import fit_sharded
    ss = get_serving_scenario("drift-2d")
    pts = ss.fit_points()
    return fit_sharded(pts, ss.base.eps, ss.base.min_pts, n_shards=3,
                       engine="grit")


def test_sharded_v3_roundtrip_carries_topology_state(sharded_fitted):
    """v3 snapshots carry the cut history and the mutation-log cursor
    (``ops_applied``) so a restored primary keeps replica-compatible
    replay positions across save/load."""
    from repro.index import ShardedGritIndex
    sidx = ShardedGritIndex.restore(sharded_fitted.snapshot())
    sidx.split_shard(1)
    sidx.merge_shards(1)
    snap = sidx.snapshot()
    assert int(np.asarray(snap["sharded_version"])[0]) == 3
    back = ShardedGritIndex.restore(snap)
    assert back.cut_history == sidx.cut_history
    assert back.ops_applied == sidx.ops_applied == 2
    assert np.array_equal(back.labels_arrival(), sidx.labels_arrival())
    assert np.array_equal(back.core_arrival(), sidx.core_arrival())


def test_sharded_v2_legacy_snapshot_restores(sharded_fitted):
    """A pre-topology (v2) sharded snapshot -- no ``cut_hist_*`` arrays,
    4-entry ``scalars_i`` -- must keep restoring: empty cut history,
    replay cursor 0."""
    from repro.index import ShardedGritIndex
    snap = sharded_fitted.snapshot()
    for k in ("cut_hist_kind", "cut_hist_shard", "cut_hist_coord"):
        snap.pop(k)
    snap["scalars_i"] = np.asarray(snap["scalars_i"])[:4]
    snap["sharded_version"] = np.asarray([2], np.int64)
    back = ShardedGritIndex.restore(snap)
    assert back.cut_history == []
    assert back.ops_applied == 0
    assert np.array_equal(back.labels_arrival(),
                          sharded_fitted.labels_arrival())
    assert np.array_equal(back.core_arrival(),
                          sharded_fitted.core_arrival())
    # and a legacy-restored index is fully serviceable: topology ops
    # and the replica plane work from a clean slate
    back.split_shard(0)
    assert back.cut_history[0][0] == "split"


def test_sharded_unknown_version_rejected(sharded_fitted):
    from repro.index import ShardedGritIndex
    snap = sharded_fitted.snapshot()
    snap["sharded_version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match=r"version 99"):
        ShardedGritIndex.restore(snap)
