"""Grid-tree property tests: the tree query must agree with the
exhaustive stencil baseline on arbitrary grid configurations.

``hypothesis`` is optional (the container image may not ship it): when
present we fuzz arbitrary grid sets; without it a deterministic
random-grid sweep (same property, fixed seeds) keeps the module useful.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.grid_tree import (GridTree, stencil_neighbors, radius,
                                  offset_stencil, device_neighbor_table,
                                  pack_rows)
from repro.core.grids import PAD_ID

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _csr_to_sets(indptr, nbr):
    return [frozenset(nbr[indptr[i]:indptr[i + 1]].tolist())
            for i in range(len(indptr) - 1)]


def _random_ids(rng: np.random.Generator) -> np.ndarray:
    d = int(rng.integers(1, 6))
    n = int(rng.integers(1, 61))
    eta = int(rng.integers(1, 13))
    rows = rng.integers(0, eta + 1, size=(n, d))
    return np.unique(rows.astype(np.int64), axis=0)


def _check_tree_matches_stencil(ids: np.ndarray) -> None:
    tree = GridTree.build(ids)
    ip_t, nb_t, off_t = tree.query(ids, include_self=False)
    ip_s, nb_s, off_s = stencil_neighbors(ids, ids, include_self=False)
    assert _csr_to_sets(ip_t, nb_t) == _csr_to_sets(ip_s, nb_s)


def _check_offsets_sorted_and_correct(ids: np.ndarray) -> None:
    tree = GridTree.build(ids)
    indptr, nbr, off = tree.query(ids, include_self=False)
    d = ids.shape[1]
    for i in range(len(ids)):
        sl = slice(indptr[i], indptr[i + 1])
        offs = off[sl]
        assert (np.diff(offs) >= 0).all(), "not offset-sorted (paper l.16)"
        # offset definition: sum_j max(|key_j - g_ij| - 1, 0)^2 < d
        delta = np.abs(ids[nbr[sl]] - ids[i][None, :])
        expect = (np.maximum(delta - 1, 0) ** 2).sum(1)
        np.testing.assert_array_equal(offs, expect)
        assert (offs < d).all()


def _check_device_table_matches_host(ids: np.ndarray) -> None:
    G = len(ids)
    cap = max(64, G + 1)
    padded = np.full((cap, ids.shape[1]), int(PAD_ID), np.int32)
    padded[:G] = ids
    nbr, nbr_off, ovf_f, ovf_k = device_neighbor_table(
        jnp.asarray(padded), jnp.int32(G), frontier_cap=256, k_cap=64,
        include_self=False)
    if bool(ovf_f) or bool(ovf_k):
        pytest.skip("static caps exceeded for this random instance")
    tree = GridTree.build(ids)
    indptr, nb, _ = tree.query(ids, include_self=False)
    host = _csr_to_sets(indptr, nb)
    dev = np.asarray(nbr)[:G]
    for i in range(G):
        got = frozenset(int(x) for x in dev[i] if x >= 0)
        assert got == host[i]


# ---- hypothesis fuzzing (when available) ---------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def grid_ids(draw):
        d = draw(st.integers(min_value=1, max_value=5))
        n = draw(st.integers(min_value=1, max_value=60))
        eta = draw(st.integers(min_value=1, max_value=12))
        rows = draw(st.lists(
            st.tuples(*[st.integers(0, eta) for _ in range(d)]),
            min_size=n, max_size=n))
        return np.unique(np.asarray(sorted(set(rows)), np.int64), axis=0)

    @given(grid_ids())
    @settings(max_examples=60, deadline=None)
    def test_tree_query_matches_stencil(ids):
        _check_tree_matches_stencil(ids)

    @given(grid_ids())
    @settings(max_examples=30, deadline=None)
    def test_tree_query_offsets_sorted_and_correct(ids):
        _check_offsets_sorted_and_correct(ids)

    @given(grid_ids())
    @settings(max_examples=20, deadline=None)
    def test_device_table_matches_host(ids):
        _check_device_table_matches_host(ids)


# ---- deterministic fallback sweep (always runs) ---------------------------

@pytest.mark.parametrize("seed", range(12))
def test_tree_query_matches_stencil_seeded(seed, make_rng):
    ids = _random_ids(make_rng(seed))
    _check_tree_matches_stencil(ids)
    _check_offsets_sorted_and_correct(ids)


@pytest.mark.parametrize("seed", range(2))
def test_device_table_matches_host_seeded(seed, make_rng):
    ids = _random_ids(make_rng(100 + seed))
    _check_device_table_matches_host(ids)


# ---- include_self semantics (serving predict path) ------------------------

def test_include_self_drops_only_exact_match():
    """include_self=False removes the query's own grid and nothing
    else: distinct grids at grid-distance 0 (adjacent cells, offset 0)
    stay in the result."""
    ids = np.array([[0, 0], [0, 1], [1, 1], [5, 5]], np.int64)
    tree = GridTree.build(ids)
    ip_t, nb_t, off_t = tree.query(ids, include_self=True)
    ip_f, nb_f, off_f = tree.query(ids, include_self=False)
    sets_t = _csr_to_sets(ip_t, nb_t)
    sets_f = _csr_to_sets(ip_f, nb_f)
    for g in range(len(ids)):
        assert g in sets_t[g], "include_self=True must return the query"
        assert g not in sets_f[g]
        assert sets_t[g] - {g} == sets_f[g]
    # adjacent cells (0,0)-(0,1) are offset 0 yet distinct: kept
    assert 1 in sets_f[0] and 0 in sets_f[1]
    # offsets of the self matches are 0 and must not drag neighbors out
    assert all((off_f >= 0).tolist())


@pytest.mark.parametrize("seed", range(6))
def test_include_self_matches_stencil_both_ways(seed, make_rng):
    ids = _random_ids(make_rng(300 + seed))
    tree = GridTree.build(ids)
    for include_self in (True, False):
        ip_t, nb_t, _ = tree.query(ids, include_self=include_self)
        ip_s, nb_s, _ = stencil_neighbors(ids, ids,
                                          include_self=include_self)
        assert _csr_to_sets(ip_t, nb_t) == _csr_to_sets(ip_s, nb_s)


@pytest.mark.parametrize("seed", range(4))
def test_external_queries_match_stencil(seed, make_rng):
    """Queries that are not grids of the tree -- empty cells, cells
    outside the stored range, negative components (the predict path for
    new points) -- must return exactly the stencil baseline's answer."""
    rng = make_rng(400 + seed)
    ids = _random_ids(rng)
    d = ids.shape[1]
    queries = np.concatenate([
        rng.integers(-3, 15, size=(24, d)),          # arbitrary cells
        ids[:4] + rng.integers(-1, 2, size=(min(4, len(ids)), d))[:4],
    ])
    tree = GridTree.build(ids)
    ip_t, nb_t, _ = tree.query(queries, include_self=True)
    ip_s, nb_s, _ = stencil_neighbors(ids, queries, include_self=True)
    assert _csr_to_sets(ip_t, nb_t) == _csr_to_sets(ip_s, nb_s)


# ---- non-property tests ---------------------------------------------------

def test_stencil_size_matches_paper_bound():
    for d in (2, 3, 5):
        deltas, off = offset_stencil(d)
        r = radius(d)
        assert (np.abs(deltas) <= r).all()
        assert (off < d).all()
        # offsets sorted ascending (used for early exit)
        assert (np.diff(off) >= 0).all()


def test_pack_rows_is_lexicographic():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 1000, size=(100, 4))
    packed = pack_rows(ids)
    order_p = np.argsort(packed, kind="stable")
    order_l = np.lexsort(tuple(ids[:, j] for j in range(3, -1, -1)))
    np.testing.assert_array_equal(ids[order_p], ids[order_l])
