"""Sharding policy unit tests (pure spec logic, no devices needed)."""

import types

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs, SHAPES
from repro.launch.sharding import param_pspec, _batch_spec
from repro.launch.mesh import batch_axes


class FakeMesh(types.SimpleNamespace):
    """Just axis_names + shape -- enough for the spec builders."""


SINGLE = FakeMesh(axis_names=("data", "model"),
                  shape={"data": 16, "model": 16})
MULTI = FakeMesh(axis_names=("pod", "data", "model"),
                 shape={"pod": 2, "data": 16, "model": 16})


def test_attention_weights_fsdp_x_tp():
    cfg = get_config("qwen2-1.5b")
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['attn']['wq']", 3,
                       (28, 1536, 1536))
    assert spec == P(None, "data", "model")
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['attn']['wo']", 3,
                       (28, 1536, 1536))
    assert spec == P(None, "model", "data")


def test_embed_vocab_sharded():
    cfg = get_config("gemma2-27b")
    spec = param_pspec(cfg, SINGLE, "['embed']", 2, (256000, 4608))
    assert spec == P("model", "data")


def test_indivisible_dims_stay_replicated():
    cfg = get_config("qwen2-1.5b")
    # 12 heads * 128 = 1536 divisible; but a dim of 10 is not
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['attn']['wq']", 2,
                       (10, 1536))
    assert spec == P(None, "model")


def test_arctic_experts_sharded_over_model():
    cfg = get_config("arctic-480b")          # 128 experts >= 16
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['moe']['w_gate']", 4,
                       (35, 128, 7168, 4864))
    assert spec == P(None, "model", "data", None)


def test_mixtral_experts_tp_within_expert():
    cfg = get_config("mixtral-8x7b")         # 8 experts < 16
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['moe']['w_gate']", 4,
                       (32, 8, 4096, 14336))
    assert spec == P(None, None, "data", "model")


def test_norm_scales_replicated():
    cfg = get_config("qwen2-1.5b")
    spec = param_pspec(cfg, SINGLE, "['blocks'][0]['ln1']['scale']", 2,
                       (28, 1536))
    assert spec == P(None, None)


def test_batch_spec_divisibility():
    assert _batch_spec(SINGLE, 256) == ("data",)
    assert _batch_spec(MULTI, 256) == ("pod", "data")
    assert _batch_spec(MULTI, 2) == ("pod",)
    assert _batch_spec(SINGLE, 1) == ()
    assert _batch_spec(MULTI, 32) == ("pod", "data")


def test_every_arch_has_lowerable_spec_table():
    """Param specs must be constructible for every arch's full config
    (uses eval_shape; no allocation)."""
    from repro.models import init_params
    for arch in list_archs():
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k: init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
        for path, leaf in flat:
            spec = param_pspec(cfg, SINGLE, jax.tree_util.keystr(path),
                               len(leaf.shape), leaf.shape)
            # spec rank matches leaf rank and all divisibility holds
            assert len(spec) <= len(leaf.shape)
            for dim, ax in zip(leaf.shape, spec):
                if ax is not None:
                    assert dim % SINGLE.shape[ax] == 0, (arch, path)


# ----------------------------------------------------------------------
# slab partition (repro.dist.sharding): degenerate-cut regression
# ----------------------------------------------------------------------

class TestSlabCutsDegenerate:
    """All points in one dim-0 grid column: there is no interior
    grid-line boundary to cut at, so ``slab_cuts`` must degrade to
    "everything in slab 0" (+inf sentinel cuts) instead of fabricating
    cuts that would misroute points, and ``fit_sharded`` must degrade
    to effectively one slab with exact labels."""

    def _column(self, n=60, eps=1.0, seed=0):
        # grid side for d=2 is eps/sqrt(2) ~ 0.707; x0 spread of 0.2
        # keeps every point in one dim-0 column
        rng = np.random.default_rng(seed)
        pts = np.empty((n, 2))
        pts[:, 0] = 5.0 + 0.2 * rng.random(n)
        pts[:, 1] = rng.normal(0.0, 3.0, n)
        return pts

    def test_cuts_are_inf_sentinels(self):
        from repro.dist.sharding import owner_of_slab, slab_cuts
        pts = self._column()
        order, cut_idx, cut_coords = slab_cuts(pts, 1.0, 3)
        assert len(order) == len(pts)
        assert sorted(order.tolist()) == list(range(len(pts)))
        # every cut collapses to the right edge: index n, coord +inf
        assert (cut_idx == len(pts)).all()
        assert np.isposinf(cut_coords).all()
        # and the sentinel cuts route every point to slab 0
        owner = owner_of_slab(pts[:, 0], cut_coords)
        assert (owner == 0).all()

    def test_fit_sharded_degrades_to_one_slab(self):
        from repro.index import fit_index, fit_sharded
        pts = self._column()
        sidx = fit_sharded(pts, 1.0, 3, n_shards=3)
        assert sidx.num_shards == 1
        ref = fit_index(pts, 1.0, 3)
        a, b = sidx.labels_arrival(), ref.labels_arrival()
        # same partition (ids may differ across fit paths)
        assert (a < 0).tolist() == (b < 0).tolist()
        for lab in np.unique(b[b >= 0]):
            members = a[b == lab]
            assert len(np.unique(members)) == 1
        q = pts + 0.05
        pa, pb = sidx.predict(q), ref.predict(q)
        assert ((pa < 0) == (pb < 0)).all()

    def test_degenerate_shard_is_unsplittable(self):
        from repro.index import fit_sharded
        sidx = fit_sharded(self._column(), 1.0, 3, n_shards=2)
        with pytest.raises(ValueError, match="unsplittable|no interior"):
            sidx.split_shard(0)
