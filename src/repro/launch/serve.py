"""Serving driver: batched prefill + decode with continuous batching.

``python -m repro.launch.serve --arch <id> --smoke`` runs a miniature
server loop on CPU: requests arrive with different prompt lengths, get
left-padded into a batch, prefilled once, then decoded step-by-step;
finished sequences are swapped out and new requests swapped in (slot
reuse = continuous batching).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new: int
    out: list = dataclasses.field(default_factory=list)


def _pow2_at_least(n: int) -> int:
    """Smallest power of two >= n (prefill shapes bucket to pow2 so the
    jit cache converges instead of recompiling per prompt length)."""
    return 1 << max(0, int(n) - 1).bit_length()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import (init_params, init_cache, prefill, decode_step)
    from repro.launch.specs import model_cfg_for

    cfg = model_cfg_for(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    rng = np.random.default_rng(0)

    reqs = [Request(i, list(rng.integers(0, cfg.vocab_size,
                                         size=rng.integers(4, 17))),
                    args.max_new)
            for i in range(args.num_requests)]

    B = args.batch_slots
    jit_decode = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))
    jit_prefill = jax.jit(lambda p, b, c: prefill(cfg, p, b, c))

    done: List[Request] = []
    t0 = time.time()
    steps = 0
    while reqs or done is None:
        active = reqs[:B]
        reqs = reqs[B:]
        if not active:
            break
        # left-pad prompts to a common pow2-bucketed length -> one
        # batched prefill per bucket, not one compile per length
        plen = _pow2_at_least(max(len(r.prompt) for r in active))
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(active):
            toks[i, plen - len(r.prompt):] = r.prompt
        extra = {}
        if cfg.family == "encdec":
            extra["frames"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            extra["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        cache = init_cache(cfg, B, args.max_len +
                           (cfg.num_patches if cfg.family == "vlm" else 0))
        logits, cache = jit_prefill(
            params, {"tokens": jnp.asarray(toks), **extra}, cache)
        cur = jnp.argmax(logits, -1)
        for r, t in zip(active, np.asarray(cur)):
            r.out.append(int(t))
        # decode until every slot hit max_new (continuous batching would
        # swap in new requests here; slots simply retire in this demo)
        for step in range(args.max_new - 1):
            logits, cache = jit_decode(params, cur, cache)
            cur = jnp.argmax(logits, -1)
            steps += 1
            for i, r in enumerate(active):
                if len(r.out) < r.max_new:
                    r.out.append(int(np.asarray(cur)[i]))
        done.extend(active)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok / dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}...")


if __name__ == "__main__":
    main()
