"""Roofline-term extraction from compiled HLO.

``cost_analysis`` gives FLOPs and bytes accessed; collective traffic is
NOT included there, so we parse the post-SPMD optimized HLO text and sum
operand sizes of every collective op, with per-op wire factors:

  all-reduce          2 (k-1)/k   (reduce-scatter + all-gather phases)
  all-gather            (k-1)/k   (each chip receives (k-1)/k of result)
  reduce-scatter        (k-1)/k   (of the *input*, = output * (k-1))
  all-to-all            (k-1)/k
  collective-permute    1

k is parsed from replica_groups when present (else the worst-case axis).
The result is the per-chip wire-byte count used for the collective
roofline term  T_coll = bytes / 50 GB/s (serial per-link ICI model).
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[8,128]' or '(f32[4], bf16[2,2])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return default


def collective_bytes(hlo_text: str, default_group: int = 16
                     ) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind (done-ops skipped to avoid
    double counting async pairs)."""
    out: Dict[str, float] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _COLL_RE.match(ln)
        if m is None:
            continue
        if "-done(" in ln:
            continue                    # counted at -start
        shape_str, kind = m.group(1), m.group(2)
        size = shape_bytes(shape_str)
        k = _group_size(ln, default_group)
        frac = (k - 1) / k if k > 1 else 0.0
        if kind == "all-reduce":
            wire = 2 * frac * size
        elif kind == "all-gather":
            wire = frac * size
        elif kind == "reduce-scatter":
            wire = frac * size * k      # input bytes = output * k
        elif kind == "all-to-all":
            wire = frac * size
        else:                           # collective-permute
            wire = float(size)
        out[kind] = out.get(kind, 0.0) + wire
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> Dict[str, float]:
    t_c = flops_per_chip / PEAK_FLOPS
    t_m = bytes_per_chip / HBM_BW
    t_x = coll_bytes_per_chip / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dominant[1],
            "bound": max(t_c, t_m, t_x),
            "compute_fraction": t_c / max(t_c, t_m, t_x, 1e-30)}
