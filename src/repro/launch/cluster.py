"""Fault tolerance & elasticity runtime for the training drivers.

On real multi-host TPU deployments this wraps jax.distributed; on this
CPU container the interfaces are identical and the failure paths are
exercised by tests via fault injection.

Components
----------
* ``Heartbeat``       -- per-host liveness file (atomic mtime bump) +
                         cluster-wide staleness scan: the straggler /
                         dead-node detector a coordinator polls.
* ``StepGuard``       -- wraps the train step with (a) a wall-clock
                         budget derived from a trailing median (straggler
                         mitigation: a step exceeding ``factor`` x median
                         raises ``StragglerDetected`` so the driver can
                         checkpoint-and-rejoin), (b) retry-with-restore
                         on transient failure.
* ``run_resilient``   -- the driver loop: periodic async checkpoints,
                         crash -> restore from latest -> continue;
                         resumable on a different mesh shape (elastic)
                         because checkpoints are sharding-agnostic.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Callable, Optional

from repro.train import checkpoint as ckpt


class StragglerDetected(RuntimeError):
    pass


class Heartbeat:
    def __init__(self, run_dir: str, host_id: int):
        self.path = os.path.join(run_dir, f"heartbeat_{host_id:05d}")
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)

    def beat(self) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(time.time()))
        os.replace(tmp, self.path)

    def stale_hosts(self, timeout_s: float) -> list:
        now = time.time()
        out = []
        for name in os.listdir(self.run_dir):
            if not name.startswith("heartbeat_"):
                continue
            p = os.path.join(self.run_dir, name)
            try:
                age = now - os.stat(p).st_mtime
            except FileNotFoundError:
                continue
            if age > timeout_s:
                out.append(int(name.split("_")[1]))
        return sorted(out)


@dataclasses.dataclass
class StepGuard:
    """Straggler + transient-failure guard around one train step."""
    factor: float = 5.0
    window: int = 32
    min_samples: int = 5
    max_retries: int = 2
    floor_s: float = 0.05    # ignore jitter below this absolute duration

    def __post_init__(self):
        self._times: deque = deque(maxlen=self.window)

    def median(self) -> Optional[float]:
        if len(self._times) < self.min_samples:
            return None
        s = sorted(self._times)
        return s[len(s) // 2]

    def __call__(self, step_fn: Callable, *args):
        med = self.median()
        last_exc: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            t0 = time.time()
            try:
                out = step_fn(*args)
                dt = time.time() - t0
                self._times.append(dt)
                if med is not None and dt > self.factor * med \
                        and dt > self.floor_s:
                    raise StragglerDetected(
                        f"step took {dt:.3f}s vs median {med:.3f}s")
                return out
            except StragglerDetected:
                raise
            except Exception as e:          # transient failure -> retry
                last_exc = e
        raise last_exc


def run_resilient(state, step_fn, next_batch: Callable, *,
                  ckpt_dir: str, num_steps: int,
                  ckpt_every: int = 50, keep: int = 3,
                  guard: Optional[StepGuard] = None,
                  pipeline_state: Optional[Callable] = None,
                  on_metrics: Optional[Callable] = None,
                  inject_failure: Optional[Callable] = None):
    """Checkpointed training loop; crashes restore from the latest save.

    ``inject_failure(step) -> Exception | None`` is the test hook.
    Returns (final state, steps actually run).
    """
    guard = guard or StepGuard()
    os.makedirs(ckpt_dir, exist_ok=True)
    start = int(state["step"])
    pending = None
    i = start
    while i < num_steps:
        batch = next_batch()
        try:
            if inject_failure is not None:
                exc = inject_failure(i)
                if exc is not None:
                    raise exc
            state, metrics = guard(step_fn, state, batch)
        except StragglerDetected:
            # checkpoint immediately; a coordinator would reschedule us
            if pending is not None:
                pending.join()           # avoid two concurrent writers
                pending = None
            ckpt.save(ckpt_dir, i, state,
                      extra=pipeline_state() if pipeline_state else {})
            raise
        except Exception:
            # transient hard failure: restore from latest and continue
            if pending is not None:
                pending.join()           # let the in-flight save commit
                pending = None
            step_no = ckpt.latest_step(ckpt_dir)
            if step_no is None:
                raise
            state, _ = ckpt.restore(ckpt_dir, state)
            i = int(state["step"])
            continue
        i += 1
        if on_metrics is not None:
            on_metrics(i, metrics)
        if i % ckpt_every == 0 or i == num_steps:
            if pending is not None:
                pending.join()
            pending = ckpt.save_async(
                ckpt_dir, i, state,
                extra=pipeline_state() if pipeline_state else {})
            ckpt.gc_checkpoints(ckpt_dir, keep=keep)
    if pending is not None:
        pending.join()
    return state, i - start
