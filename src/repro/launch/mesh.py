"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the 'pod'
axis composes with 'data' for batch sharding and hierarchical gradient
reduction (reduce-scatter in-pod over ICI, all-reduce cross-pod over DCN).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to fabricate the placeholder devices.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
