"""Launch layer: mesh, sharding policy, dry-run, train/serve drivers."""
