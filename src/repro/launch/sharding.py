"""Declarative sharding policy: param / activation / input / cache specs.

Strategy (per DESIGN.md §5):

* **Weights**: 2D FSDP x TP -- contraction-adjacent dim sharded over
  'data' (FSDP; all-gathered per layer by GSPMD), head/ff/vocab dim over
  'model' (TP).  Across pods weights are replicated ('pod' carries only
  batch), giving hierarchical gradient reduction.
* **Experts** (MoE): expert axis over 'model' when num_experts >=
  model-axis size (arctic 128e); otherwise TP inside each expert
  (mixtral 8e).
* **Activations**: residual stream sharded over batch axes; logits over
  'model' (vocab); expert buffers over 'model' when experts are sharded.
  Sequence parallelism is exposed as the "res" tag override (§Perf).
* **Decode caches**: batch axis over ('pod','data') when divisible; KV
  heads over 'model' when divisible, else the sequence dim over 'model'
  (long-context flash-decoding layout).

Everything returns ``NamedSharding`` bound to the target mesh so AOT
``ShapeDtypeStruct`` lowering needs no ambient mesh context.
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import LMConfig
from .mesh import batch_axes, axis_size


def _ns(mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# --------------------------------------------------------------------------
# parameter policy
# --------------------------------------------------------------------------

# rules: (path regex, spec for the *trailing* dims of the leaf)
# leading stack dims (layer groups / expert axis handled separately) get None.
_PARAM_RULES = [
    (r"\['embed'\]$",                ("model", "data")),
    (r"\['head'\]$",                 ("data", "model")),
    (r"\['(wq|wk|wv)'\]$",           ("data", "model")),
    (r"\['wo'\]$",                   ("model", "data")),
    (r"\['(bq|bk|bv)'\]$",           ("model",)),
    (r"\['(w_gate|w_up)'\]$",        ("data", "model")),
    (r"\['w_down'\]$",               ("model", "data")),
    (r"\['router'\]$",               ("data", None)),
    (r"\['(w_r|w_k|w_v|w_g)'\]$",    ("data", "model")),   # rwkv projections
    (r"\['dec_a'\]$",                ("data", None)),
    (r"\['dec_b'\]$",                (None, "data")),
    (r"\['w_in'\]$",                 ("data", None)),      # mamba in-proj
    (r"\['w_out'\]$",                (None, "data")),
]


def param_pspec(cfg: LMConfig, mesh, path: str, ndim: int,
                shape, moe_ep: bool = False) -> P:
    moe_sharded = cfg.moe is not None and \
        cfg.moe.num_experts % axis_size(mesh, "model") == 0
    is_expert = bool(re.search(r"\['moe'\]", path)) and \
        bool(re.search(r"w_(gate|up|down)", path))
    trailing: tuple = ()
    for rx, spec in _PARAM_RULES:
        if re.search(rx, path):
            trailing = spec
            break
    if is_expert:
        key = re.search(r"w_(gate|up|down)", path).group(0)
        ep_ok = cfg.moe.num_experts % axis_size(mesh, "data") == 0 and \
            cfg.moe.d_ff % axis_size(mesh, "model") == 0
        if moe_ep and ep_ok:
            # expert-parallel storage == compute layout (GShard):
            # experts over 'data', FFN dim over 'model'; no weight gather.
            trailing = ("data", None, "model") if key != "w_down" \
                else ("data", "model", None)
        elif moe_sharded:
            # experts over 'model', FSDP over 'data' on the d dim
            trailing = ("model", "data", None)
        else:
            base = dict(w_gate=("data", "model"), w_up=("data", "model"),
                        w_down=("model", "data"))
            trailing = (None,) + base[key]
    spec = [None] * ndim
    for i, ax in enumerate(reversed(trailing)):
        di = ndim - 1 - i
        if di < 0:
            break
        if ax is not None and shape[di] % axis_size(mesh, ax) == 0:
            spec[di] = ax
    return P(*spec)


def param_shardings(cfg: LMConfig, mesh, params_shape,
                    moe_ep: bool = False) -> Any:
    """Map a params pytree (of arrays or ShapeDtypeStructs) to shardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        spec = param_pspec(cfg, mesh, pstr, len(leaf.shape), leaf.shape,
                           moe_ep=moe_ep)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# activation policy (tags consumed by models.sharding_ctx)
# --------------------------------------------------------------------------

def activation_specs(cfg: LMConfig, mesh, *, seq_parallel: bool = False,
                     moe_alltoall: bool = False) -> Dict[str, Any]:
    b = P(batch_axes(mesh))
    res_seq = "model" if seq_parallel else None
    specs = {
        "btd": NamedSharding(mesh, P(*b, None, None)),
        "res": NamedSharding(mesh, P(*b, res_seq, None)),
        "btv": NamedSharding(mesh, P(*b, None, "model")),
    }
    if moe_alltoall and cfg.moe is not None:
        e_sharded = cfg.moe.num_experts % axis_size(mesh, "model") == 0
        if e_sharded:       # arctic: experts over 'model', capacity over 'data'
            specs["moe_ecd"] = NamedSharding(mesh, P("model", "data", None))
            specs["moe_w_in"] = NamedSharding(mesh, P("model", None, None))
            specs["moe_w_out"] = NamedSharding(mesh, P("model", None, None))
        else:               # mixtral: TP inside expert, capacity over 'data'
            specs["moe_ecd"] = NamedSharding(mesh, P(None, "data", None))
            specs["moe_w_in"] = NamedSharding(mesh, P(None, None, "model"))
            specs["moe_w_out"] = NamedSharding(mesh, P(None, "model", None))
    return specs


# --------------------------------------------------------------------------
# inputs
# --------------------------------------------------------------------------

def _batch_spec(mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = []
    size = 1
    for a in batch_axes(mesh):
        s = axis_size(mesh, a)
        if global_batch % (size * s) == 0:
            axes.append(a)
            size *= s
    return tuple(axes)


def batch_shardings(cfg: LMConfig, mesh, batch_struct) -> Any:
    """Shardings for a batch dict ({"tokens", "frames", "patches", ...})."""
    def one(path, leaf):
        gb = leaf.shape[0]
        ba = _batch_spec(mesh, gb)
        return NamedSharding(mesh, P(ba, *([None] * (len(leaf.shape) - 1))))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_struct)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


# --------------------------------------------------------------------------
# decode cache
# --------------------------------------------------------------------------

def cache_shardings(cfg: LMConfig, mesh, cache_struct) -> Any:
    """Cache leaves: [G, B, heads?, S, D] / ssm / conv / shift states.

    Preference order per leaf: shard batch over (pod, data) if divisible;
    shard a heads-like dim over 'model' if divisible; else shard the
    sequence dim over 'model' (and over 'data' too for batch=1
    long-context decode).
    """
    model = axis_size(mesh, "model")

    def one(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        nd = len(shape)
        if pstr.endswith("['pos']"):
            return NamedSharding(mesh, P())
        spec = [None] * nd
        # leading dim is the group stack; dim 1 is batch.
        if nd >= 2:
            ba = _batch_spec(mesh, shape[1])
            if ba:
                spec[1] = ba
        batch_sharded = nd >= 2 and spec[1] is not None and \
            np.prod([axis_size(mesh, a) for a in (spec[1] or ())]) > 1
        if re.search(r"\['(k|v|xk|xv)'\]$", pstr) and nd == 5:
            # [G, B, KV, S, Dh]
            if shape[2] % model == 0:
                spec[2] = "model"
            elif shape[3] % model == 0:
                spec[3] = "model"
                if not batch_sharded and "data" in mesh.axis_names and \
                        shape[3] % (model * axis_size(mesh, "data")) == 0:
                    spec[3] = ("data", "model")
                    if "pod" in mesh.axis_names and \
                            shape[3] % (model * axis_size(mesh, "data")
                                        * axis_size(mesh, "pod")) == 0:
                        spec[3] = ("pod", "data", "model")
        elif re.search(r"\['(wkv|ssm)'\]$", pstr) and nd == 5:
            # [G, B, H, Dk, Dv] / [G, B, H, N, P]
            if shape[2] % model == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_struct)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


# --------------------------------------------------------------------------
# optimizer state (mirror the param sharding leaf-wise)
# --------------------------------------------------------------------------

def state_shardings(cfg: LMConfig, mesh, state_struct, params_sh,
                    moe_ep: bool = False) -> Any:
    """train state {"params", "opt", "step"[, "ef"]} -> shardings.

    Optimizer slots share their parameter's sharding when shapes match
    (mu/nu/ef); factored adafactor rows/cols fall back to replication of
    the reduced dim.
    """
    flat_p, _ = jax.tree_util.tree_flatten(params_sh)

    def match(path, leaf):
        pstr = jax.tree_util.keystr(path)
        if pstr.startswith("['params']"):
            sub = jax.tree_util.keystr(path[1:])
            return _lookup(cfg, mesh, sub, leaf)
        if pstr.startswith("['opt']") or pstr.startswith("['ef']"):
            m = re.match(r"\['(opt|ef)'\]\['(mu|nu|slots)'\](.*)", pstr)
            if m and m.group(2) in ("mu", "nu"):
                return _lookup(cfg, mesh, m.group(3), leaf)
            if pstr.startswith("['ef']"):
                return _lookup(cfg, mesh, pstr[len("['ef']"):], leaf)
            if m and m.group(2) == "slots":
                # adafactor: strip the trailing ['vr']/['vc']/['v'] selector
                sub = re.sub(r"\['(vr|vc|v)'\]$", "", m.group(3))
                spec = _lookup(cfg, mesh, sub, leaf, allow_rank_pad=True)
                return spec
        return NamedSharding(mesh, P())

    def _lookup(cfg, mesh, sub, leaf, allow_rank_pad=False):
        spec = param_pspec(cfg, mesh, sub, len(leaf.shape), leaf.shape,
                           moe_ep=moe_ep)
        return NamedSharding(mesh, spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_struct)
    return jax.tree_util.tree_unflatten(
        treedef, [match(p, l) for p, l in flat])
