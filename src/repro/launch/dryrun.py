import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the entry point that sets XLA_FLAGS *before any jax import* --
jax locks the device count on first init, which is why the two lines
above precede everything (including `from repro...`).

Per cell:
  * build (fn, ShapeDtypeStruct args) via launch.specs,
  * jax.jit(fn).lower(...).compile()  -- proves the sharding config is
    coherent; no arrays are allocated,
  * record memory_analysis (per-device bytes), cost_analysis (FLOPs /
    bytes), and collective wire bytes parsed from the optimized HLO,
  * dump JSON for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun
Exit code != 0 on any cell failure (sharding mismatch, compile OOM, ...).
"""

import argparse
import json
import sys
import time
import traceback


def run_cluster_cell(multi_pod: bool, *, n_points_shard: int = 4096,
                     d: int = 3) -> dict:
    """Dry-run of the paper's own workload: the distributed GriT-DBSCAN
    cluster step (shard_map over the full mesh)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh
    from repro.dist import make_cluster_step, ClusterCaps
    from repro.core.device_dbscan import GritCaps
    from repro.launch import hlo_analysis as H
    from repro.launch import hlo_costs

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": "grit-cluster-step", "shape": f"n{n_points_shard}xd{d}",
           "mesh": mesh_name, "kind": "cluster"}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    caps = ClusterCaps(grit=GritCaps(grid_cap=256, frontier_cap=128,
                                     k_cap=32, c_cap=512, m_cap=256,
                                     pair_cap=1024, grid_block=64,
                                     pair_block=256),
                       halo_cap=128)
    step = make_cluster_step(mesh, 3000.0, 10, caps, n_points_shard, d)
    n_shards = mesh.devices.size
    axes = tuple(mesh.axis_names)
    N = n_shards * n_points_shard
    pts = jax.ShapeDtypeStruct((N, d), jnp.float32,
                               sharding=NamedSharding(mesh, P(axes, None)))
    valid = jax.ShapeDtypeStruct((N,), jnp.bool_,
                                 sharding=NamedSharding(mesh, P(axes)))
    lowered = jax.jit(step).lower(pts, valid)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    la = hlo_costs.analyze(compiled.as_text(), default_group=16)
    rec.update({
        "status": "ok", "chips": int(n_shards),
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "flops_per_chip": la["flops"], "bytes_per_chip": la["bytes"],
        "collective_bytes_per_chip": {
            k[5:]: v for k, v in la.items() if k.startswith("coll_")},
        "roofline": H.roofline_terms(la["flops"], la["bytes"],
                                     la["coll_bytes"]),
    })
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             seq_parallel: bool = False, attn_impl=None,
             moe_alltoall: bool = False, overrides=None) -> dict:
    import jax
    from repro.configs import long_500k_supported
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell
    from repro.launch import hlo_analysis as H
    from repro.launch import hlo_costs

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if shape_name == "long_500k" and not long_500k_supported(arch):
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch: 500k decode is quadratic " \
                        "(see DESIGN.md shape-applicability)"
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, info = build_cell(arch, shape_name, mesh,
                                seq_parallel=seq_parallel,
                                attn_impl=attn_impl,
                                moe_alltoall=moe_alltoall,
                                overrides=overrides)
    rec.update(info)
    lowered = jax.jit(fn).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_chips = mesh.devices.size
    hlo = compiled.as_text()
    # loop-aware per-chip costs (XLA's cost_analysis counts while bodies
    # once; hlo_costs scales by trip counts -- see launch/hlo_costs.py)
    la = hlo_costs.analyze(hlo, default_group=16)
    flops = la["flops"]
    bytes_acc = la["bytes"]
    coll_total = la["coll_bytes"]

    rec.update({
        "status": "ok",
        "chips": int(n_chips),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        # per-chip, post-SPMD, loop-aware
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": {
            k[5:]: v for k, v in la.items() if k.startswith("coll_")},
        "xla_cost_analysis": {           # raw XLA numbers for reference
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", 0),
            "output_size": getattr(mem, "output_size_in_bytes", 0),
            "temp_size": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        "roofline": H.roofline_terms(flops, bytes_acc, coll_total),
    })
    return rec


def main() -> int:
    from repro.configs import list_archs, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--moe-alltoall", action="store_true")
    ap.add_argument("--cluster", action="store_true",
                    help="dry-run the distributed GriT-DBSCAN step instead")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (e.g. attn_chunk=512)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    results, failures = [], 0
    if args.cluster:
        for mp in meshes:
            rec = run_cluster_cell(mp)
            results.append(rec)
            r = rec["roofline"]
            print(f"[{rec['status']:7s}] grit-cluster-step x "
                  f"{rec['mesh']} bound={r['dominant']}"
                  f" t_c={r['t_compute']:.3e}s t_m={r['t_memory']:.3e}s"
                  f" t_x={r['t_collective']:.3e}s", flush=True)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        return 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp,
                                   seq_parallel=args.seq_parallel,
                                   attn_impl=args.attn_impl,
                                   moe_alltoall=args.moe_alltoall,
                                   overrides=overrides or None)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "failed", "error": repr(e)}
                    failures += 1
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bound={r['dominant']}"
                             f" t_c={r['t_compute']:.3e}s"
                             f" t_m={r['t_memory']:.3e}s"
                             f" t_x={r['t_collective']:.3e}s"
                             f" compile={rec['compile_s']}s")
                print(f"[{status:7s}] {tag}{extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
