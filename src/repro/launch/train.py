"""Training driver: ``python -m repro.launch.train --arch <id> ...``

Runs end-to-end on whatever devices exist (CPU smoke / TPU pod): builds
the model + sharded train step from the same specs the dry-run lowers,
then drives the fault-tolerant loop (checkpoint/restart, straggler
guard, heartbeat) from launch.cluster.
"""

from __future__ import annotations

import argparse
import time



def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP axis size for the host mesh")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models import sharding_ctx
    from repro.train import (TrainCfg, make_train_step, init_state,
                             get_optimizer, warmup_cosine)
    from repro.train import checkpoint as ckpt
    from repro.data.tokens import TokenPipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch import sharding as shd
    from repro.launch.cluster import run_resilient, Heartbeat, StepGuard
    from repro.launch.specs import train_cfg_for, model_cfg_for

    cfg = model_cfg_for(args.arch, smoke=args.smoke)
    tcfg = train_cfg_for(args.arch)
    if args.optimizer:
        tcfg = type(tcfg)(**{**tcfg.__dict__, "optimizer": args.optimizer})
    if args.microbatches:
        tcfg = type(tcfg)(**{**tcfg.__dict__,
                             "microbatches": args.microbatches})
    tcfg = type(tcfg)(**{**tcfg.__dict__, "peak_lr": args.lr,
                         "total_steps": args.steps,
                         "warmup_steps": max(args.steps // 10, 1)})

    mesh = make_host_mesh(args.model_axis)
    sharding_ctx.set_policy(shd.activation_specs(cfg, mesh))
    opt = get_optimizer(tcfg.optimizer)
    lr_fn = warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps)
    step_fn = jax.jit(make_train_step(cfg, tcfg, opt, lr_fn))

    pipe = TokenPipeline(cfg.vocab_size, args.seq_len, args.batch, seed=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_state(cfg, tcfg, opt, params)
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, extra = ckpt.restore(args.ckpt_dir, state)
        if "pipeline" in extra:
            pipe = TokenPipeline.from_state(
                cfg.vocab_size, args.seq_len, args.batch, extra["pipeline"])
        print(f"resumed from step {int(state['step'])}")

    hb = Heartbeat(args.ckpt_dir, host_id=jax.process_index())
    t0 = time.time()
    losses = []

    def on_metrics(i, m):
        hb.beat()
        losses.append(float(m["loss"]))
        if i % args.log_every == 0:
            dt = time.time() - t0
            toks = args.batch * args.seq_len * i
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  grad_norm "
                  f"{float(m['grad_norm']):.3f}  tok/s {toks / dt:,.0f}",
                  flush=True)

    def next_batch():
        b = pipe.next_batch()
        return {"tokens": jnp.asarray(b["tokens"])}

    state, ran = run_resilient(
        state, step_fn, next_batch, ckpt_dir=args.ckpt_dir,
        num_steps=args.steps, ckpt_every=args.ckpt_every,
        guard=StepGuard(factor=50.0),
        pipeline_state=lambda: {"pipeline": pipe.state()},
        on_metrics=on_metrics)
    print(f"done: {ran} steps, final loss {losses[-1]:.4f} "
          f"(first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
