"""Input/state ShapeDtypeStruct builders + step functions for every cell.

``build_cell(arch, shape, mesh)`` returns (fn, args) such that

    jax.jit(fn).lower(*args).compile()

is the dry-run for that (architecture x input-shape x mesh) cell.  All
args are ShapeDtypeStructs carrying NamedShardings -- nothing is
allocated.  The same builders power the real drivers (train.py/serve.py)
with concrete arrays.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, canonical
from repro.models import (init_params, init_cache, prefill,
                          decode_step)
from repro.models.config import LMConfig
from repro.models import sharding_ctx
from repro.train import TrainCfg, make_train_step, init_state, \
    get_optimizer, warmup_cosine
from . import sharding as shd


# per-arch training knobs (memory-driven)
ARCH_TRAIN = {
    "arctic_480b": dict(optimizer="adafactor", microbatches=8,
                        param_dtype="bfloat16"),
    "gemma2_27b": dict(optimizer="adamw", microbatches=4),
    "mixtral_8x7b": dict(optimizer="adamw", microbatches=2),
}


def _struct(tree, shardings):
    """Rebuild a ShapeDtypeStruct tree with shardings attached."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


def train_cfg_for(arch: str) -> TrainCfg:
    kw = ARCH_TRAIN.get(canonical(arch), {})
    kw = {k: v for k, v in kw.items() if k in ("optimizer", "microbatches")}
    return TrainCfg(total_steps=10_000, warmup_steps=200, **kw)


def model_cfg_for(arch: str, *, smoke: bool = False) -> LMConfig:
    cfg = get_config(arch, smoke=smoke)
    extra = ARCH_TRAIN.get(canonical(arch), {})
    if "param_dtype" in extra and not smoke:
        cfg = cfg.with_overrides(param_dtype=extra["param_dtype"])
    return cfg


def _batch_struct(cfg: LMConfig, shape_kind: str, seq: int, batch: int
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    act = jnp.dtype(cfg.dtype)
    toks = seq + 1 if shape_kind == "train" else seq
    b: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, toks), jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), act)
    if cfg.family == "vlm":
        b["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_patches, cfg.d_model), act)
    return b


def build_cell(arch: str, shape_name: str, mesh, *,
               seq_parallel: bool = False,
               attn_impl: Optional[str] = None,
               moe_alltoall: bool = False,
               overrides: Optional[dict] = None):
    """Returns (fn, args_tuple, info) for the dry-run of one cell."""
    cfg = model_cfg_for(arch)
    if attn_impl:
        cfg = cfg.with_overrides(attn_impl=attn_impl)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    sc = get_shape(shape_name)
    sharding_ctx.set_policy(
        shd.activation_specs(cfg, mesh, seq_parallel=seq_parallel))
    if moe_alltoall and cfg.moe is not None:
        from .mesh import batch_axes as _ba
        sharding_ctx.set_shardmap_moe((mesh, _ba(mesh), "model"))
    else:
        sharding_ctx.set_shardmap_moe(None)

    params_shape = jax.eval_shape(lambda k: init_params(cfg, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_sh = shd.param_shardings(cfg, mesh, params_shape,
                                    moe_ep=moe_alltoall)
    info = {"arch": arch, "shape": shape_name, "kind": sc.kind}

    if sc.kind == "train":
        tcfg = train_cfg_for(arch)
        opt = get_optimizer(tcfg.optimizer)
        lr_fn = warmup_cosine(tcfg.peak_lr, tcfg.warmup_steps,
                              tcfg.total_steps)
        step_fn = make_train_step(cfg, tcfg, opt, lr_fn)
        state_shape = jax.eval_shape(
            lambda p: init_state(cfg, tcfg, opt, p), params_shape)
        state_sh = shd.state_shardings(cfg, mesh, state_shape, params_sh,
                                       moe_ep=moe_alltoall)
        state = _struct(state_shape, state_sh)
        batch_shape = _batch_struct(cfg, "train", sc.seq_len,
                                    sc.global_batch)
        batch = _struct(batch_shape,
                        shd.batch_shardings(cfg, mesh, batch_shape))
        info["microbatches"] = tcfg.microbatches
        return step_fn, (state, batch), info

    params = _struct(params_shape, params_sh)
    if sc.kind == "prefill":
        max_len = sc.seq_len + (cfg.num_patches if cfg.family == "vlm" else 0)
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, sc.global_batch, max_len))
        cache = _struct(cache_shape,
                        shd.cache_shardings(cfg, mesh, cache_shape))
        batch_shape = _batch_struct(cfg, "prefill", sc.seq_len,
                                    sc.global_batch)
        batch = _struct(batch_shape,
                        shd.batch_shardings(cfg, mesh, batch_shape))

        def prefill_step(params, batch, cache):
            return prefill(cfg, params, batch, cache)

        return prefill_step, (params, batch, cache), info

    # decode: one new token against a seq_len-deep cache
    max_len = sc.seq_len + (cfg.num_patches if cfg.family == "vlm" else 0)
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, sc.global_batch, max_len))
    cache = _struct(cache_shape,
                    shd.cache_shardings(cfg, mesh, cache_shape))
    ba = shd._batch_spec(mesh, sc.global_batch)
    tokens = jax.ShapeDtypeStruct(
        (sc.global_batch,), jnp.int32,
        sharding=NamedSharding(mesh, P(ba)))

    def serve_step(params, tokens, cache):
        return decode_step(cfg, params, tokens, cache)

    return serve_step, (params, tokens, cache), info
