"""Loop-aware cost analysis over optimized HLO text.

XLA's built-in ``cost_analysis`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes/collectives by the trip
count.  This module parses the post-SPMD optimized HLO, builds the
computation call graph, extracts while-loop trip counts from their
condition computations (scan emits ``compare(induction, constant(N)),
direction=LT``), and accumulates

  * dot FLOPs       : 2 * |output| * contraction-size (batch dims incl.)
  * elementwise     : |output| per float op (VPU estimate)
  * HBM bytes       : operands + outputs of materializing top-level ops
                      (post-fusion, each op's output is a real buffer)
  * collective wire bytes per kind (same factors as hlo_analysis)

each scaled by the computation's execution count (product of enclosing
loop trip counts).  Validated against unrolled references in the tests.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from .hlo_analysis import _DTYPE_BYTES, _GROUPS_RE, _GROUPS_IOTA_RE

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}]+?))\s+"
    r"([\w\-]+)\(")
_SHAPE_ONE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_elems(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ONE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ONE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _is_float(shape_str: str) -> bool:
    m = _SHAPE_ONE.search(shape_str)
    return bool(m) and m.group(1) in ("f16", "bf16", "f32", "f64",
                                      "f8e4m3fn", "f8e5m2")


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    ops: List[Op]

    def symbol_shapes(self) -> Dict[str, str]:
        table = dict(self.params)
        for op in self.ops:
            table[op.name] = op.shape
        return table


def _split_top(s: str) -> List[str]:
    """Split on top-level commas (ignoring nested (), [], {})."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _parse_header(line: str):
    """'%name (p0: shape, p1: (tuple)) -> ret {' -> (name, {p: shape})."""
    s = line.strip()
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].strip()
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    paren = s.find(" (")
    if paren < 0 or "->" not in s or not s.endswith("{"):
        return None
    name = s[:paren].lstrip("%").strip()
    # balanced param region
    depth, i = 0, paren + 1
    start = i
    while i < len(s):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    inner = s[start + 1:i]
    params = {}
    for part in _split_top(inner):
        if ":" in part:
            pname, pshape = part.split(":", 1)
            params[pname.strip().lstrip("%")] = pshape.strip()
    return name, params


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{") and "->" in line:
            hdr = _parse_header(line)
            if hdr:
                cur = Computation(hdr[0], hdr[1], [])
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if m:
            name, shape, kind = m.group(1), m.group(2), m.group(3)
            # operands: balanced (...) right after the op name
            rest = line[m.end():]
            depth, j = 1, 0
            while j < len(rest) and depth > 0:
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                j += 1
            operands = []
            for tok in _split_top(rest[:j - 1]):
                # newer XLA prints typed operands ("f32[8]{0} %name");
                # the symbol is always the last whitespace token
                tok = tok.strip().split()[-1].lstrip("%") if tok.strip() else ""
                if tok:
                    operands.append(tok)
            cur.ops.append(Op(name, shape, kind, line, operands))
    return comps


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: List[int] = []
    for op in cond.ops:
        if op.kind == "constant":
            mm = re.search(r"constant\((\d+)\)", op.line)
            if mm and "s32" in op.shape:
                consts.append(int(mm.group(1)))
    if not consts:
        return 1
    return max(consts)


def _exec_counts(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name:
            entry = name
    if entry is None:                      # fall back: last computation
        entry = list(comps)[-1]
    counts: Dict[str, float] = {c: 0.0 for c in comps}

    def visit(name: str, mult: float):
        if name not in comps:
            return
        counts[name] += mult
        for op in comps[name].ops:
            if op.kind == "while":
                cb = _COND_BODY_RE.search(op.line)
                if cb:
                    tm = _TRIP_RE.search(op.line)
                    trips = int(tm.group(1)) if tm else \
                        _trip_count(comps, cb.group(1))
                    visit(cb.group(1), mult * (trips + 1))
                    visit(cb.group(2), mult * trips)
            elif op.kind in ("fusion", "call", "conditional"):
                for callee in _CALLEE_RE.findall(op.line):
                    visit(callee, mult)
            # reduce/map/scatter to_apply bodies: scalar lambdas -- their
            # cost is folded into the op's own estimate, skip.

    visit(entry, 1.0)
    return counts


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.shape)
    lhs_shape = symbols.get(op.operands[0], "") if op.operands else ""
    m = _CONTRACT_RE.search(op.line)
    k = 1
    if m and lhs_shape:
        dims_m = _SHAPE_ONE.search(lhs_shape)
        if dims_m and dims_m.group(2):
            dims = [int(d) for d in dims_m.group(2).split(",")]
            for ci in m.group(1).split(","):
                ci = ci.strip()
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


_SLICY = {"dynamic-slice", "gather", "slice"}


def _fusion_param_bytes(comps: Dict[str, Computation], fname: str,
                        param_idx: int, full_shape: str) -> float:
    """Bytes a fusion actually reads from its ``param_idx``-th operand.

    * every use is a slice/gather            -> count the slice outputs;
    * every use is as dynamic-update-slice's *destination* -> 0 bytes
      (XLA aliases the buffer in place; only the update is written,
      which is charged on the output side by ``_fusion_out_bytes``).
    """
    comp = comps.get(fname)
    if comp is None:
        return float(_shape_bytes(full_shape))
    # parameter names carry their index: parameter(N)
    pname = None
    for op in comp.ops:
        if op.kind == "parameter" and f"parameter({param_idx})" in op.line:
            pname = op.name
            break
    if pname is None:
        for n, s in comp.params.items():
            if s == full_shape:
                pname = n
                break
    if pname is None:
        return float(_shape_bytes(full_shape))
    uses = [op for op in comp.ops if pname in op.operands]
    if uses and all(u.kind in _SLICY and u.operands
                    and u.operands[0] == pname for u in uses):
        return float(sum(_shape_bytes(u.shape) for u in uses))
    if uses and all(u.kind == "dynamic-update-slice" and u.operands
                    and u.operands[0] == pname for u in uses):
        return 0.0
    return float(_shape_bytes(full_shape))


def _fusion_out_bytes(comps: Dict[str, Computation], fname: str,
                      out_shape: str) -> float:
    """Bytes a fusion writes: if its root is a dynamic-update-slice the
    buffer is updated in place -- only the update slice is written."""
    comp = comps.get(fname)
    if comp is None or not comp.ops:
        return float(_shape_bytes(out_shape))
    symbols = comp.symbol_shapes()
    root = comp.ops[-1]
    roots = [root]
    if root.kind == "tuple":             # multi-output fusion
        roots = [op for op in comp.ops if op.name in root.operands]
    total = 0.0
    for r in roots:
        if r.kind == "dynamic-update-slice" and len(r.operands) > 1:
            total += _shape_bytes(symbols.get(r.operands[1], r.shape))
        else:
            total += _shape_bytes(r.shape)
    return total


def _op_bytes(op: Op, symbols: Dict[str, str],
              comps: Dict[str, Computation]) -> float:
    """HBM traffic estimate for one top-level op (post-fusion)."""
    out_b = _shape_bytes(op.shape)
    if op.kind in _SLICY:
        return 2.0 * out_b                       # read slice + write out
    if op.kind == "dynamic-update-slice":
        upd = _shape_bytes(symbols.get(op.operands[1], "")) \
            if len(op.operands) > 1 else out_b
        return 2.0 * upd                         # read update + write slice
    if op.kind in ("broadcast", "iota"):
        return float(out_b)
    if op.kind == "scatter":
        upd = _shape_bytes(symbols.get(op.operands[-1], "")) \
            if op.operands else out_b
        return 2.0 * upd
    if op.kind == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        b = _fusion_out_bytes(comps, m.group(1), op.shape) if m \
            else float(out_b)
        for i, o in enumerate(op.operands):
            full = symbols.get(o, "")
            if m:
                b += _fusion_param_bytes(comps, m.group(1), i, full)
            else:
                b += _shape_bytes(full)
        return b
    b = float(out_b)
    for o in op.operands:
        b += _shape_bytes(symbols.get(o, ""))
    return b


def analyze(text: str, default_group: int = 16) -> Dict[str, float]:
    """Loop-aware {flops, bytes, coll_bytes, coll_<kind>...} totals."""
    comps = parse_hlo(text)
    counts = _exec_counts(comps)
    flops = 0.0
    bytes_acc = 0.0
    coll: Dict[str, float] = {}
    for cname, comp in comps.items():
        mult = counts.get(cname, 0.0)
        if mult == 0.0:
            continue
        symbols = comp.symbol_shapes()
        in_fusion = cname.startswith("fused") or "fused_computation" in cname \
            or cname.startswith("wrapped")
        for op in comp.ops:
            if op.kind == "dot":
                flops += mult * _dot_flops(op, symbols)
            elif op.kind == "convolution":
                # not used by this zoo; approximate as output elems
                flops += mult * 2.0 * _shape_elems(op.shape)
            elif op.kind not in _FREE_OPS and _is_float(op.shape):
                flops += mult * _shape_elems(op.shape)
            # HBM bytes: only ops that materialize at computation top level
            if in_fusion:
                continue                    # fusion internals stay in regs
            if op.kind in _FREE_OPS or op.kind == "while":
                continue
            bytes_acc += mult * _op_bytes(op, symbols, comps)
            base = op.kind.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                size = _shape_bytes(op.shape)
                if op.kind.endswith("-start"):
                    size = size // 2 or size   # start returns (in, out) tuple
                k = default_group
                m = _GROUPS_RE.search(op.line)
                if m:
                    k = max(len(m.group(1).split(",")), 1)
                else:
                    m = _GROUPS_IOTA_RE.search(op.line)
                    if m:
                        k = max(int(m.group(2)), 1)
                frac = (k - 1) / k if k > 1 else 0.0
                if base == "all-reduce":
                    wire = 2 * frac * size
                elif base == "reduce-scatter":
                    wire = frac * size * k
                elif base in ("all-gather", "all-to-all"):
                    wire = frac * size
                else:
                    wire = float(size)
                coll[base] = coll.get(base, 0.0) + mult * wire
    out = {"flops": flops, "bytes": bytes_acc,
           "coll_bytes": sum(coll.values())}
    for k, v in coll.items():
        out[f"coll_{k}"] = v
    return out
