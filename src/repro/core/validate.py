"""Equivalence checking between DBSCAN labelings.

DBSCAN's clustering is unique on core points and noise; *border* points
may validly belong to any cluster owning a core point within eps (the
original paper and Alg. 6 both assign them order-dependently).  Two
labelings are therefore equivalent iff:

  1. identical core-point sets,
  2. identical partitions of the core points into clusters,
  3. identical noise sets (a non-core point is border iff it has a core
     point within eps -- regardless of which cluster claimed it),
  4. every border assignment is *valid*: its cluster contains a core
     point within eps of it.
"""

from __future__ import annotations

import numpy as np


def contested_border_mask(points: np.ndarray, eps: float,
                          core: np.ndarray,
                          core_labels: np.ndarray) -> np.ndarray:
    """True for non-core points reachable from cores of >1 cluster.

    Those are the only points whose DBSCAN label is genuinely
    order-dependent; everywhere else the output is unique and two exact
    engines must agree label-for-label (after canonicalization).
    ``core_labels`` is any labeling of the core partition.
    """
    pts = np.asarray(points, np.float64)
    eps2 = float(eps) ** 2
    out = np.zeros(len(pts), bool)
    cpts = pts[core]
    clab = np.asarray(core_labels)[core]
    for i in np.flatnonzero(~core):
        d2 = ((cpts - pts[i]) ** 2).sum(1)
        cands = np.unique(clab[d2 <= eps2])
        out[i] = len(cands) > 1
    return out


def core_flags(points: np.ndarray, eps: float, min_pts: int,
               chunk: int = 2048) -> np.ndarray:
    pts = np.asarray(points, np.float64)
    n = len(pts)
    eps2 = float(eps) ** 2
    counts = np.zeros(n, dtype=np.int64)
    for s in range(0, n, chunk):
        d2 = ((pts[s:s + chunk, None, :] - pts[None, :, :]) ** 2).sum(-1)
        counts[s:s + chunk] = (d2 <= eps2).sum(1)
    return counts >= min_pts


def _partition_signature(labels: np.ndarray, mask: np.ndarray) -> set:
    sig = {}
    for i in np.flatnonzero(mask):
        sig.setdefault(labels[i], []).append(i)
    return {frozenset(v) for v in sig.values()}


def assert_dbscan_equivalent(points: np.ndarray, eps: float, min_pts: int,
                             labels_a: np.ndarray, labels_b: np.ndarray,
                             core: np.ndarray | None = None) -> None:
    pts = np.asarray(points, np.float64)
    eps2 = float(eps) ** 2
    if core is None:
        core = core_flags(pts, eps, min_pts)
    la, lb = np.asarray(labels_a), np.asarray(labels_b)

    # 1+2: core partition identical
    assert (la[core] >= 0).all(), "labeling A: core point marked noise"
    assert (lb[core] >= 0).all(), "labeling B: core point marked noise"
    pa = _partition_signature(la, core)
    pb = _partition_signature(lb, core)
    assert pa == pb, "core-point partitions differ"

    # 3: border/noise sets identical
    noncore = ~core
    for name, l in (("A", la), ("B", lb)):
        for i in np.flatnonzero(noncore):
            d2 = ((pts[core] - pts[i]) ** 2).sum(1)
            has_core = (d2 <= eps2).any()
            if has_core:
                assert l[i] >= 0, f"labeling {name}: border point {i} marked noise"
            else:
                assert l[i] < 0, f"labeling {name}: noise point {i} in a cluster"

    # 4: border assignments valid
    for name, l in (("A", la), ("B", lb)):
        for i in np.flatnonzero(noncore & (la >= 0 if name == "A" else lb >= 0)):
            same = core & (l == l[i])
            if not same.any():
                raise AssertionError(f"labeling {name}: border {i} in empty cluster")
            d2 = ((pts[same] - pts[i]) ** 2).sum(1)
            assert (d2 <= eps2).any(), \
                f"labeling {name}: border {i} assigned to cluster w/o core in eps"


def assert_labels_conformant(points: np.ndarray, eps: float, min_pts: int,
                             labels_ref: np.ndarray,
                             labels_got: np.ndarray,
                             core: np.ndarray | None = None) -> None:
    """Strictest meaningful engine-equality check.

    1. DBSCAN-equivalence (core partition, noise set, border validity)
       via :func:`assert_dbscan_equivalent`.
    2. Label-for-label equality after ``canonicalize_labels`` on every
       point whose output DBSCAN defines uniquely -- i.e. everything
       except *contested* borders (non-core points within eps of cores
       of more than one cluster, which Alg. 6 assigns order-dependently).
    """
    from .dbscan import canonicalize_labels

    pts = np.asarray(points, np.float64)
    if core is None:
        core = core_flags(pts, eps, min_pts)
    la, lb = np.asarray(labels_ref), np.asarray(labels_got)
    assert_dbscan_equivalent(pts, eps, min_pts, la, lb, core=core)
    contested = contested_border_mask(pts, eps, core, la)
    m = ~contested
    np.testing.assert_array_equal(
        canonicalize_labels(la[m]), canonicalize_labels(lb[m]),
        err_msg="canonicalized labels differ on uncontested points")
