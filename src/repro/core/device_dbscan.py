"""GriT-DBSCAN fully in-graph (device path).

The whole of Algorithm 6 as one jittable function with static shape caps:

  grids (Alg 1, lax.sort)            -> ``grids.build_grids_device``
  grid-tree neighbor query (Alg 3)   -> ``grid_tree.device_neighbor_table``
  core identification (G13 + all-core shortcut, offset-sorted candidates)
  FastMerging over core-grid pairs (Alg 5, masked)
  connected components (pointer jumping)
  border / noise assignment

Static caps replace the dynamic data structures of the paper; every cap
has an ``overflow`` flag so a driver can retry with larger caps (the
standard static-shape discipline on TPU).

``GritCaps.packed`` (default True) selects *occupancy-packed* dispatch
for the three cap-proportional stages.  The dense strategy maps
``core_block`` / ``border_block`` over every ``grid_cap`` slot and the
merge step over every ``pair_cap`` slot, so work scales with the caps
even when most slots are dead.  The packed strategy keeps the paper's
work-proportional claim: live small grids are compacted to a prefix
sorted by candidate total, and three ``lax.while_loop`` tiers with
data-dependent trip counts sweep that prefix at pow2 sub-caps
(``c_cap/4``, ``c_cap/2``, ``c_cap`` -- the flat pow2-bucket discipline
of ``kernels.ops``), the widest tier doubling as the dense-tail path
for the few heavy grids; merge blocks run only up to the number of
valid pairs.  Outputs are bit-identical to the dense path: a grid in a
tier has candidate total <= the tier width, so no candidate is
truncated, the per-row distance rows are elementwise the same values,
and the result scatters (max for core flags, min for border labels)
are order-independent.  Overflow flags are computed from the global
per-grid candidate totals, never from what a tier dispatched, so the
``OverflowReport`` semantics are unchanged (pinned packed-vs-dense by
``tests/test_packed_dispatch.py``).

``GritCaps.use_kernels`` selects the distance plane for the two
distance-heavy stages.  ``False`` (default) materializes the naive
``[B, P, C, d]`` broadcast difference tensor -- the in-graph oracle.
``True`` routes ``core_block`` (per-point eps-counts over own+neighbor
candidates) through ``kernels.ops.eps_count_batch`` and ``border_block``
(nearest-core-point query) through ``kernels.ops.row_min_batch``: the
MXU-tiled batched Pallas kernels on TPU, a tiled loop with a
data-dependent trip count (padding-tail skip + MinPts early exit)
elsewhere (see the dispatch policy in ``repro.kernels.ops``).  Before a
kernel call both point sets are re-centered on the grid's first own
point: candidates live within the neighbor stencil (a few eps), so the
`aa + bb - 2ab` contraction runs on stencil-scale coordinates and the
cancellation error stays far below the scenario decision margins.  The
overflow flags are computed from candidate totals, never from distance
values, so kernelization leaves the ``OverflowReport`` untouched.

Padding convention: invalid points are moved to ``PAD_COORD`` so they
land in (ignorable) far-away grids and never satisfy a distance
predicate; the kernels share the convention (``kernels.ops.FAR``) for
masked candidate rows.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

from .grids import build_grids_device, DeviceGrids
from .grid_tree import device_neighbor_table
from .merging import fast_merging_batch
from .labels import label_propagation
from ..kernels import ops as kernel_ops

PAD_COORD = 1e15


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OverflowReport:
    """Per-cap overflow flags (scalar device bools).

    Each flag names the ``GritCaps`` field (or distributed halo cap) that
    was exceeded, so a driver can grow exactly the caps that overflowed
    instead of blindly scaling everything.  When a flag fires the result
    is a *subset* (silently truncated) and must not be trusted.
    """

    grid: jnp.ndarray        # grid_cap: non-empty grids truncated
    frontier: jnp.ndarray    # frontier_cap: grid-tree level frontier
    neighbors: jnp.ndarray   # k_cap: neighbor grids per grid
    candidates: jnp.ndarray  # c_cap: candidate points per small grid
    core_set: jnp.ndarray    # m_cap: core points per grid (merging)
    pairs: jnp.ndarray       # pair_cap: core-grid merge pairs
    halo: jnp.ndarray        # halo_cap: distributed boundary exchange

    FIELDS: ClassVar[Tuple[str, ...]] = (
        "grid", "frontier", "neighbors", "candidates", "core_set",
        "pairs", "halo")

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in self.FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)

    @classmethod
    def none(cls) -> "OverflowReport":
        return cls(*(jnp.zeros((), bool) for _ in cls.FIELDS))

    @classmethod
    def from_vector(cls, vec) -> "OverflowReport":
        assert len(vec) == len(cls.FIELDS)
        return cls(*(vec[i] for i in range(len(cls.FIELDS))))

    def as_vector(self) -> jnp.ndarray:
        return jnp.stack([jnp.asarray(getattr(self, f), bool)
                          for f in self.FIELDS])

    def any(self):
        out = jnp.zeros((), bool)
        for f in self.FIELDS:
            out = out | jnp.asarray(getattr(self, f), bool)
        return out

    def overflowing(self) -> Tuple[str, ...]:
        """Host-side: names of the caps that overflowed."""
        return tuple(f for f in self.FIELDS if bool(getattr(self, f)))

    def __bool__(self) -> bool:
        return bool(self.any())


@dataclasses.dataclass(frozen=True)
class GritCaps:
    """Static shape caps + execution strategy for the in-graph pipeline.

    ``use_kernels`` rides along with the caps (it is part of the same
    static jit key): True routes the core/border distance plane through
    the batched Pallas kernels instead of the naive broadcast tensor.
    """

    grid_cap: int = 1024       # max non-empty grids
    frontier_cap: int = 128    # grid-tree per-level frontier
    k_cap: int = 48            # neighbors per grid
    c_cap: int = 512           # candidate points per grid (self + neighbors)
    m_cap: int = 64            # core points per grid used by merging
    pair_cap: int = 4096       # merge pairs
    grid_block: int = 128      # chunk over grids (memory bound)
    pair_block: int = 512      # chunk over merge pairs
    merge_iters: int = 64      # FastMerging max iterations (paper kappa<=11)
    use_kernels: bool = False  # kernelized distance plane (see module doc)
    packed: bool = True        # occupancy-packed dispatch (see module doc)

    def __post_init__(self):
        # the dense maps reshape [grid_cap] -> [-1, grid_block] and
        # [pair_cap] -> [-1, pair_block]; an indivisible cap used to
        # crash deep inside the pipeline at pg.reshape -- fail loudly
        # at construction instead
        if self.grid_block <= 0 or self.grid_cap % self.grid_block != 0:
            raise ValueError(
                f"grid_cap ({self.grid_cap}) must be a positive multiple "
                f"of grid_block ({self.grid_block})")
        if self.pair_block <= 0 or self.pair_cap % self.pair_block != 0:
            raise ValueError(
                f"pair_cap ({self.pair_cap}) must be a positive multiple "
                f"of pair_block ({self.pair_block})")

    @classmethod
    def for_dim(cls, d: int, **kw) -> "GritCaps":
        """Caps with the frontier sized to the paper's per-level fanout
        bound (2*ceil(sqrt(d))+1)^(d-1) -- a 1.5x memory-term win over a
        generic cap at d=3 (§Perf cluster iterations). Overflow flags
        still guard correctness if data exceeds any cap."""
        import math
        r = 2 * math.ceil(math.sqrt(d)) + 1
        frontier = int(min(r ** max(d - 1, 1), 256))
        kw.setdefault("frontier_cap", max(frontier, 8))
        kw.setdefault("merge_iters", 16)   # paper Remark 3: kappa <= 11
        return cls(**kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceDBSCANResult:
    labels: jnp.ndarray        # [n] int32, original order; -1 noise
    core: jnp.ndarray          # [n] bool, original order
    point_grid: jnp.ndarray    # [n] int32 grid row of each point, original
                               # order (rows of the device grid table; f32
                               # identifiers -- provenance, not the float64
                               # host partition)
    num_clusters: jnp.ndarray  # [] int32
    overflow: jnp.ndarray      # [] bool -- any static cap exceeded
    report: OverflowReport     # which cap(s) overflowed
    dispatch_tiers: jnp.ndarray  # [4] int32 dispatch telemetry: grids
                               # swept by the three packed occupancy
                               # tiers (c_cap/4, c_cap/2, c_cap) and, in
                               # slot 3, the dense-path grid slots (0
                               # when packed); their sum is the total
                               # dispatched grid work

    def tree_flatten(self):
        return (self.labels, self.core, self.point_grid, self.num_clusters,
                self.overflow, self.report, self.dispatch_tiers), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


def _candidates_for_grids(dg: DeviceGrids, nbr: jnp.ndarray, gsel: jnp.ndarray,
                          c_cap: int):
    """Candidate point indices for each grid in ``gsel``: own grid first,
    then neighbors in offset-ascending order (paper's early-exit order).

    Returns (cand_idx [B, c_cap] into sorted points, cand_grid [B, c_cap],
    cand_valid [B, c_cap], cand_total [B])."""
    B = gsel.shape[0]
    K = nbr.shape[1]
    cg = jnp.concatenate([gsel[:, None], nbr[gsel]], axis=1)        # [B, K+1]
    cg_valid = cg >= 0
    cgc = jnp.where(cg_valid, cg, 0)
    sizes = jnp.where(cg_valid, dg.counts[cgc], 0)                  # [B, K+1]
    cum = jnp.cumsum(sizes, axis=1)                                 # inclusive
    total = cum[:, -1]
    slots = jnp.arange(c_cap, dtype=jnp.int32)[None, :]             # [1, C]
    # segment of each slot: first seg with cum > slot
    seg = jax.vmap(lambda c, s: jnp.searchsorted(c, s, side="right"))(
        cum, jnp.broadcast_to(slots, (B, c_cap)))
    seg = jnp.minimum(seg, K)
    prev = jnp.where(seg > 0,
                     jnp.take_along_axis(cum, jnp.maximum(seg - 1, 0), axis=1),
                     0)
    within = slots - prev
    g_of = jnp.take_along_axis(cgc, seg, axis=1)
    idx = dg.starts[g_of] + within
    valid = (slots < total[:, None])
    idx = jnp.where(valid, idx, 0)
    return idx, g_of, valid, total


@partial(jax.jit, static_argnames=("min_pts", "caps"))
def device_dbscan(points: jnp.ndarray, eps, min_pts: int, caps: GritCaps,
                  point_valid: Optional[jnp.ndarray] = None) -> DeviceDBSCANResult:
    """Exact GriT-DBSCAN, fully in-graph. Labels in original point order."""
    n, d = points.shape
    eps = jnp.asarray(eps, points.dtype)
    eps2 = eps * eps
    if point_valid is None:
        point_valid = jnp.ones((n,), bool)
    pts = jnp.where(point_valid[:, None], points, PAD_COORD)

    # ---- step 1: grids + grid tree neighbors --------------------------
    dg = build_grids_device(pts, eps, caps.grid_cap)
    nbr, nbr_off, ovf_frontier, ovf_k = device_neighbor_table(
        dg.ids, dg.num_grids, frontier_cap=caps.frontier_cap,
        k_cap=caps.k_cap, include_self=False, packed=caps.packed)
    G = caps.grid_cap
    live = jnp.arange(G, dtype=jnp.int32) < dg.num_grids
    sorted_valid = point_valid[dg.order]

    spts = dg.sorted_points

    # ---- step 2: core points ------------------------------------------
    # all-core shortcut: grids with >= MinPts (valid) points
    valid_counts = jnp.zeros((G,), jnp.int32).at[dg.point_grid].add(
        sorted_valid.astype(jnp.int32))
    big = (valid_counts >= min_pts) & live
    core_sorted = big[dg.point_grid] & sorted_valid
    # grids holding only padding points (all invalid points share
    # PAD_COORD, so they land in grids of their own) need no core scan
    # and must not count against c_cap
    occupied = live & (valid_counts > 0)

    p_cap = max(min_pts - 1, 1)

    def grid_anchor(gsel):
        """First own point of each selected grid: the re-centering origin
        for the kernelized distance plane (module docstring)."""
        return spts[jnp.minimum(dg.starts[gsel], n - 1)][:, None, :]

    # per-grid candidate totals (own + neighbor occupancies): the same
    # numbers _candidates_for_grids derives per block, computed once for
    # every grid -- they drive the candidates overflow flag and, under
    # packed dispatch, the occupancy-tier assignment
    cg_all = jnp.concatenate(
        [jnp.arange(G, dtype=jnp.int32)[:, None], nbr], axis=1)
    total_all = jnp.sum(
        jnp.where(cg_all >= 0, dg.counts[jnp.maximum(cg_all, 0)], 0),
        axis=1)                                               # [G]
    small_all = (~big) & occupied
    ovf_candidates = jnp.any((total_all > caps.c_cap) & small_all)

    def core_rows(gsel, width, active):
        """Core test of one grid block at candidate width ``width``:
        identical values to the full-width pass for any grid whose
        candidate total fits (no truncation, same candidate prefix
        order, same distance rows)."""
        cand_idx, _, cand_valid, _ = _candidates_for_grids(
            dg, nbr, gsel, width)
        cand_valid = cand_valid & sorted_valid[cand_idx]
        own_slot = jnp.arange(p_cap, dtype=jnp.int32)[None, :]
        own_idx = dg.starts[gsel][:, None] + own_slot
        small = (~big[gsel]) & occupied[gsel] & active
        own_valid = (own_slot < dg.counts[gsel][:, None]) & small[:, None]
        own_idx = jnp.where(own_valid, own_idx, 0)
        a = spts[own_idx]                       # [B, P, d]
        b = spts[cand_idx]                      # [B, C, d]
        if caps.use_kernels:
            # stop_at=min_pts: the saturating-count contract -- exact
            # below min_pts, ">= min_pts" above -- is all the core test
            # needs, and it unlocks the paper's offset-ascending early
            # exit (candidates are already in that order)
            anchor = grid_anchor(gsel)
            cnt = kernel_ops.eps_count_batch(a - anchor, b - anchor, eps,
                                             valid_b=cand_valid,
                                             valid_a=own_valid,
                                             stop_at=min_pts)
        else:
            d2 = jnp.sum((a[:, :, None, :] - b[:, None, :, :]) ** 2, axis=-1)
            hit = (d2 <= eps2) & cand_valid[:, None, :]
            cnt = hit.sum(axis=2)
        return own_idx, (cnt >= min_pts) & own_valid

    GB = caps.grid_block
    if caps.packed:
        # occupancy-packed dispatch: live small grids compacted to a
        # prefix sorted by candidate total (stable, so equal totals keep
        # grid order), swept tier by tier at pow2 sub-caps.  A grid's
        # tier width bounds its candidate total, so every tier sees the
        # exact candidate set; grids whose total exceeds c_cap run (and
        # truncate) in the widest tier exactly as the dense path does,
        # with the candidates flag raised from total_all above.
        tier_w = sorted({max(8, caps.c_cap // 4),
                         max(8, caps.c_cap // 2), caps.c_cap})
        pperm = jnp.argsort(jnp.where(small_all, total_all,
                                      jnp.int32(2 ** 30)), stable=True)
        n_small = jnp.sum(small_all.astype(jnp.int32))
        cuts = [jnp.sum((small_all
                         & (total_all <= w)).astype(jnp.int32))
                for w in tier_w[:-1]] + [n_small]
        tier_bounds = list(zip([jnp.int32(0)] + cuts[:-1], cuts))
        tier_counts = [hi - lo for lo, hi in tier_bounds]

        def sweep_tiers(row_fn, init, scatter):
            def one_tier(acc, lo, hi, width):
                nblk = (hi - lo + GB - 1) // GB

                def body(state):
                    b, acc = state
                    pos = lo + b * GB + jnp.arange(GB, dtype=jnp.int32)
                    active = pos < hi
                    gsel = pperm[jnp.where(active, pos, 0)]
                    oi, val = row_fn(gsel, width, active)
                    return b + 1, scatter(acc, oi, val)

                return jax.lax.while_loop(
                    lambda s: s[0] < nblk, body, (jnp.int32(0), acc))[1]

            for (lo, hi), width in zip(tier_bounds, tier_w):
                init = one_tier(init, lo, hi, width)
            return init

        core_sorted = sweep_tiers(
            core_rows, core_sorted,
            lambda acc, oi, v: acc.at[oi.reshape(-1)].max(v.reshape(-1)))
        dispatch_tiers = jnp.zeros((4,), jnp.int32)
        for t, cnt in enumerate(tier_counts):
            dispatch_tiers = dispatch_tiers.at[t].set(cnt)
    else:
        gsel_all = jnp.arange(G, dtype=jnp.int32).reshape(-1, GB)
        ones = jnp.ones((GB,), bool)
        own_idx, is_core = jax.lax.map(
            lambda gsel: core_rows(gsel, caps.c_cap, ones), gsel_all)
        core_sorted = core_sorted.at[own_idx.reshape(-1)].max(
            is_core.reshape(-1))
        dispatch_tiers = jnp.zeros((4,), jnp.int32).at[3].set(G)

    core_per_grid = jnp.zeros((G,), jnp.int32).at[dg.point_grid].add(
        core_sorted.astype(jnp.int32))
    core_grid = (core_per_grid > 0) & live
    ovf_core_set = jnp.any(core_per_grid > caps.m_cap)

    # ---- step 3: merging -----------------------------------------------
    # pairs (g, g') with g' in Nei(g), both core, deduped by g' > g
    K = caps.k_cap
    gg = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None], (G, K))
    g2 = nbr
    pair_valid = (g2 >= 0) & (g2 > gg) & core_grid[gg] & core_grid[
        jnp.maximum(g2, 0)]
    flat_valid = pair_valid.reshape(-1)
    order = jnp.argsort(~flat_valid, stable=True)
    take = order[:caps.pair_cap]
    pg = gg.reshape(-1)[take]
    ph = jnp.maximum(g2.reshape(-1), 0)[take]
    pvalid = flat_valid[take]
    if take.shape[0] < caps.pair_cap:
        # pair_cap exceeds the G*K pair universe: pad the compacted
        # prefix back up to the cap (all padding invalid) so the block
        # reshape below keeps its static shape
        pad = caps.pair_cap - take.shape[0]
        pg = jnp.pad(pg, (0, pad))
        ph = jnp.pad(ph, (0, pad))
        pvalid = jnp.pad(pvalid, (0, pad))
    ovf_pairs = jnp.sum(flat_valid) > caps.pair_cap

    # compacted core set of EVERY grid, computed once: each core grid
    # takes part in ~k_cap merge pairs, so hoisting the compaction out
    # of the pair blocks removes the dominant per-pair gather cost
    def gather_core_set(g):
        w = jnp.arange(caps.m_cap, dtype=jnp.int32)
        pidx = dg.starts[g] + w
        pidx = jnp.where(w < dg.counts[g], pidx, 0)
        flag = core_sorted[pidx] & (w < dg.counts[g])
        tgt = jnp.cumsum(flag.astype(jnp.int32)) - 1
        out = jnp.zeros((caps.m_cap,), jnp.int32)
        out = out.at[jnp.where(flag, tgt, caps.m_cap - 1)].max(
            jnp.where(flag, pidx, 0))
        m = flag.sum()
        setv = jnp.arange(caps.m_cap) < m
        return jnp.where(setv, out, 0), setv

    core_set_idx, core_set_valid = jax.vmap(gather_core_set)(
        jnp.arange(G, dtype=jnp.int32))                  # [G, m_cap]

    def merge_block(args):
        a_g, b_g, pv = args
        av = core_set_valid[a_g] & pv[:, None]
        bv = core_set_valid[b_g] & pv[:, None]
        yes, iters = fast_merging_batch(
            spts[core_set_idx[a_g]], av, spts[core_set_idx[b_g]], bv,
            eps, max_iters=caps.merge_iters)
        return yes & pv, iters

    PB = caps.pair_block
    n_pb = caps.pair_cap // PB
    if caps.packed:
        # the valid pairs are argsort-compacted to a prefix above, so
        # only ceil(n_valid / PB) blocks carry work; blocks past the
        # prefix would compute all-False rows, which is exactly the
        # initial value of ``merged`` -- skipping them is bit-identical
        n_valid_pairs = jnp.minimum(
            jnp.sum(flat_valid.astype(jnp.int32)), caps.pair_cap)
        nblk_m = (n_valid_pairs + PB - 1) // PB

        def merge_body(state):
            b, acc = state
            s = b * PB
            yes, _ = merge_block((
                jax.lax.dynamic_slice(pg, (s,), (PB,)),
                jax.lax.dynamic_slice(ph, (s,), (PB,)),
                jax.lax.dynamic_slice(pvalid, (s,), (PB,))))
            return b + 1, jax.lax.dynamic_update_slice(acc, yes, (s,))

        merged = jax.lax.while_loop(
            lambda s: s[0] < nblk_m, merge_body,
            (jnp.int32(0), jnp.zeros((caps.pair_cap,), bool)))[1]
    else:
        merged, _ = jax.lax.map(
            merge_block, (pg.reshape(n_pb, PB), ph.reshape(n_pb, PB),
                          pvalid.reshape(n_pb, PB)))
        merged = merged.reshape(-1)

    edges = jnp.stack([pg, ph], axis=1)
    grid_label = label_propagation(G, edges, merged, core_grid)
    # representative grid index per cluster; sentinel G for non-core grids
    num_clusters = jnp.sum((grid_label == jnp.arange(G)) & core_grid)

    # ---- step 4: border / noise ----------------------------------------
    def border_rows(gsel, width, active):
        cand_idx, cand_grid, cand_valid, _ = _candidates_for_grids(
            dg, nbr, gsel, width)
        cand_valid = cand_valid & core_sorted[cand_idx]
        own_slot = jnp.arange(p_cap, dtype=jnp.int32)[None, :]
        own_idx = dg.starts[gsel][:, None] + own_slot
        small = (~big[gsel]) & occupied[gsel] & active
        own_valid = (own_slot < dg.counts[gsel][:, None]) & small[:, None]
        own_idx_s = jnp.where(own_valid, own_idx, 0)
        noncore = own_valid & ~core_sorted[own_idx_s]
        a = spts[own_idx_s]
        b = spts[cand_idx]
        if caps.use_kernels:
            anchor = grid_anchor(gsel)
            dbest, jbest = kernel_ops.row_min_batch(a - anchor, b - anchor,
                                                    valid_b=cand_valid)
            # jbest == -1: no core candidate at all (row_min contract);
            # dbest is inf there, so the eps2 test already rejects it --
            # the clamp only keeps the gather in range
            gbest = jnp.take_along_axis(cand_grid,
                                        jnp.maximum(jbest, 0), axis=1)
        else:
            d2 = jnp.sum((a[:, :, None, :] - b[:, None, :, :]) ** 2, axis=-1)
            d2 = jnp.where(cand_valid[:, None, :], d2, jnp.inf)
            jbest = jnp.argmin(d2, axis=2)
            dbest = jnp.take_along_axis(d2, jbest[..., None], axis=2)[..., 0]
            gbest = jnp.take_along_axis(cand_grid, jbest, axis=1)
        lab = jnp.where((dbest <= eps2) & noncore,
                        grid_label[gbest], jnp.int32(G))
        return own_idx_s, jnp.where(noncore, lab, G)

    if caps.packed:
        border_sorted = sweep_tiers(
            border_rows, jnp.full((n,), jnp.int32(G)),
            lambda acc, oi, v: acc.at[oi.reshape(-1)].min(v.reshape(-1)))
    else:
        b_own_idx, b_lab = jax.lax.map(
            lambda gsel: border_rows(gsel, caps.c_cap, ones), gsel_all)
        border_sorted = jnp.full((n,), jnp.int32(G)).at[
            b_own_idx.reshape(-1)].min(b_lab.reshape(-1))

    lab_sorted = jnp.where(core_sorted, grid_label[dg.point_grid],
                           border_sorted)
    lab_sorted = jnp.where(lab_sorted >= G, -1, lab_sorted)
    lab_sorted = jnp.where(sorted_valid, lab_sorted, -1)

    labels = jnp.zeros((n,), jnp.int32).at[dg.order].set(lab_sorted)
    core = jnp.zeros((n,), bool).at[dg.order].set(core_sorted)
    point_grid = jnp.zeros((n,), jnp.int32).at[dg.order].set(dg.point_grid)
    report = OverflowReport(
        grid=dg.overflow, frontier=ovf_frontier, neighbors=ovf_k,
        candidates=ovf_candidates, core_set=ovf_core_set, pairs=ovf_pairs,
        halo=jnp.zeros((), bool))
    return DeviceDBSCANResult(labels=labels, core=core,
                              point_grid=point_grid,
                              num_clusters=num_clusters,
                              overflow=report.any(), report=report,
                              dispatch_tiers=dispatch_tiers)
