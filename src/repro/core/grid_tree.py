"""Grid tree (paper §4.2): index over non-empty grids + neighbor queries.

The paper's grid tree is a (d+1)-level trie over the lexicographically
sorted identifiers of the non-empty grids, queried level-by-level while
pruning subtrees whose accumulated *offset*

    offset = sum_j max(|key_j - g_ij| - 1, 0)^2        (integer, side^2 units)

reaches ``d`` (at which point the minimum grid distance already exceeds
eps).  Neighbors are returned sorted by offset (closest grids first).

TPU adaptation (see DESIGN.md §2): the pointer trie becomes *level
arrays* -- each level is the sorted array of identifier prefixes, child
sets are contiguous ranges, and the paper's hash-table shortcut becomes
(vectorized) binary search.  Offset pruning and offset-sorted output are
preserved verbatim.

Three query engines with identical results:

* ``GridTree.query``          -- host, fully vectorized over all queries
                                 (the production index path).
* ``stencil_neighbors``       -- host baseline: gan/appr-DBSCAN style
                                 candidate-stencil enumeration (what the
                                 grid tree is designed to beat; Fig. 11).
* ``device_neighbor_table``   -- pure-jnp in-graph version (static caps)
                                 used inside the jitted/sharded pipeline.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def pack_rows(ids: np.ndarray) -> np.ndarray:
    """Pack non-negative int rows into byte strings whose lexicographic
    (bytewise) order equals numeric lexicographic row order."""
    ids = np.ascontiguousarray(ids.astype(">u4"))
    return ids.view(f"S{4 * ids.shape[1]}").ravel()


def radius(d: int) -> int:
    """Per-dimension search radius ceil(sqrt(d)) (paper §4.2.2)."""
    return int(math.ceil(math.sqrt(d)))


# --------------------------------------------------------------------------
# host grid tree
# --------------------------------------------------------------------------

@dataclasses.dataclass
class GridTree:
    """Trie-as-arrays over lex-sorted grid identifiers (host index)."""

    ids: np.ndarray                       # [G, d] lex-sorted identifiers
    # per level j (0-based, key = ids[:, j]):
    level_starts: list                    # level j -> [n_j] row where prefix begins
    level_ends: list                      # level j -> [n_j] row past prefix end
    child_lo: list                        # level j -> [n_j] first child in level j+1
    child_hi: list                        # level j -> [n_j] past-last child

    @property
    def d(self) -> int:
        return int(self.ids.shape[1])

    @property
    def num_grids(self) -> int:
        return int(self.ids.shape[0])

    # -- Algorithm 2 (vectorized build) ------------------------------------
    @classmethod
    def build(cls, ids: np.ndarray) -> "GridTree":
        ids = np.asarray(ids, dtype=np.int64)
        G, d = ids.shape
        level_starts, level_ends = [], []
        for j in range(d):
            # new length-(j+1) prefix whenever any of the first j+1 cols change
            if G == 0:
                level_starts.append(np.zeros(0, np.int64))
                level_ends.append(np.zeros(0, np.int64))
                continue
            new = np.ones(G, dtype=bool)
            new[1:] = np.any(ids[1:, : j + 1] != ids[:-1, : j + 1], axis=1)
            s = np.flatnonzero(new)
            level_starts.append(s)
            level_ends.append(np.append(s[1:], G))
        child_lo, child_hi = [], []
        for j in range(d - 1):
            # children of level-j node = level-(j+1) nodes within its row range
            child_lo.append(np.searchsorted(level_starts[j + 1], level_starts[j], "left"))
            child_hi.append(np.searchsorted(level_starts[j + 1], level_ends[j], "left"))
        return cls(ids=ids, level_starts=level_starts, level_ends=level_ends,
                   child_lo=child_lo, child_hi=child_hi)

    # -- Algorithm 3 (batched over queries) --------------------------------
    def query(self, queries: np.ndarray, include_self: bool = True
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Non-empty neighboring grids for each query identifier.

        Returns CSR ``(indptr[nq+1], nbr_grid[idx], nbr_offset[idx])`` with
        neighbors of each query sorted by offset ascending (paper line 16).
        ``nbr_offset`` is the integer squared grid distance in side^2 units.

        Queries need not be identifiers *of* the tree: the serving path
        (``GritIndex.predict``) queries with the cells of arbitrary new
        points, including empty cells and cells outside the fitted
        range (negative components are fine -- the per-level searches
        are value-based against the stored keys, which are >= 0).
        ``include_self=False`` drops only the *exact* identifier match;
        distinct grids at grid-distance 0 (adjacent cells, offset 0)
        are kept.
        """
        queries = np.asarray(queries, dtype=np.int64)
        nq, d = queries.shape
        assert d == self.d
        r = radius(d)
        G = self.num_grids

        # frontier: (query row, node position in level-j arrays, offset)
        q_idx = np.arange(nq, dtype=np.int64)
        # level 0 expansion: nodes are all level-0 entries; restrict by key
        node = None
        for j in range(d):
            keys = self.ids[self.level_starts[j], j]
            if j == 0:
                # root children: full level-0 node array, globally key-sorted
                lo = np.searchsorted(keys, queries[:, 0] - r, "left")
                hi = np.searchsorted(keys, queries[:, 0] + r, "right")
                cnt = hi - lo
                total = int(cnt.sum())
                base = np.repeat(np.cumsum(cnt) - cnt, cnt)
                node = (np.arange(total) - base) + np.repeat(lo, cnt)
                q_of = np.repeat(q_idx, cnt)
                delta = np.abs(keys[node] - queries[q_of, 0])
                off = np.maximum(delta - 1, 0) ** 2
            else:
                # children of frontier nodes: contiguous ranges in level j,
                # keys sorted within each range -> packed searchsorted
                clo = self.child_lo[j - 1][node]
                chi = self.child_hi[j - 1][node]
                # pack (child's parent position, key) so a single global
                # searchsorted respects per-parent ranges
                parent_of_level = np.repeat(
                    np.arange(len(self.level_starts[j - 1])),
                    self.child_hi[j - 1] - self.child_lo[j - 1])
                K = int(keys.max(initial=0)) + 2
                packed = parent_of_level * K + keys
                want = queries[q_of, j]
                lo = np.searchsorted(packed, node * K + np.maximum(want - r, 0), "left")
                hi = np.searchsorted(packed, node * K + (want + r), "right")
                lo = np.maximum(lo, clo)
                hi = np.minimum(hi, chi)
                cnt = np.maximum(hi - lo, 0)
                total = int(cnt.sum())
                base = np.repeat(np.cumsum(cnt) - cnt, cnt)
                child = (np.arange(total) - base) + np.repeat(lo, cnt)
                q_of = np.repeat(q_of, cnt)
                delta = np.abs(keys[child] - queries[q_of, j])
                off = np.repeat(off, cnt) + np.maximum(delta - 1, 0) ** 2
                node = child
            # offset pruning (Algorithm 3 line 9): drop subtrees at >= d
            keep = off < d
            node, q_of, off = node[keep], q_of[keep], off[keep]

        # leaf level: node positions are rows of `ids`
        grid = self.level_starts[d - 1][node] if d > 1 else self.level_starts[0][node]
        # NOTE: at j == d-1 each node is a unique full identifier -> one grid
        if not include_self:
            # offset 0 also matches *distinct* grids at grid-distance 0
            # (adjacent cells); only drop the exact self match.
            self_match = np.all(self.ids[grid] == queries[q_of], axis=1)
            grid, q_of, off = (grid[~self_match], q_of[~self_match],
                               off[~self_match])

        # sort per query by offset ascending (paper: counting sort)
        perm = np.lexsort((grid, off, q_of))
        grid, q_of, off = grid[perm], q_of[perm], off[perm]
        indptr = np.zeros(nq + 1, dtype=np.int64)
        np.add.at(indptr, q_of + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, grid, off


# --------------------------------------------------------------------------
# stencil baseline (gan-DBSCAN / appr-DBSCAN neighbor enumeration)
# --------------------------------------------------------------------------

_STENCILS: dict = {}


def offset_stencil(d: int) -> Tuple[np.ndarray, np.ndarray]:
    """All identifier deltas with offset < d (the exponential stencil)."""
    if d in _STENCILS:
        return _STENCILS[d]
    r = radius(d)
    rng = np.arange(-r, r + 1)
    grids = np.meshgrid(*([rng] * d), indexing="ij")
    deltas = np.stack([g.ravel() for g in grids], axis=1)
    off = (np.maximum(np.abs(deltas) - 1, 0) ** 2).sum(axis=1)
    keep = off < d
    deltas, off = deltas[keep], off[keep]
    order = np.argsort(off, kind="stable")
    _STENCILS[d] = (deltas[order], off[order])
    return _STENCILS[d]


def stencil_neighbors(ids: np.ndarray, queries: np.ndarray,
                      include_self: bool = True,
                      chunk: int = 256) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Baseline neighbor query: enumerate the full (2r+1)^d candidate
    stencil per grid and membership-test against the non-empty set.

    Same CSR output contract as ``GridTree.query``.  Cost is
    Theta(|stencil| * nq * log G) -- the exponential-in-d behaviour the
    grid tree avoids (paper §4.2, Fig. 11 analogue).
    """
    ids = np.asarray(ids, np.int64)
    queries = np.asarray(queries, np.int64)
    nq, d = queries.shape
    deltas, doff = offset_stencil(d)
    packed = pack_rows(ids)               # lex-sorted already
    out_q, out_g, out_o = [], [], []
    for s in range(0, nq, chunk):
        q = queries[s:s + chunk]
        cand = q[:, None, :] + deltas[None, :, :]          # [c, S, d]
        valid = (cand >= 0).all(-1)
        flat = cand.reshape(-1, d)
        flat = np.maximum(flat, 0)
        pos = np.searchsorted(packed, pack_rows(flat))
        pos = np.minimum(pos, len(packed) - 1)
        hit = (packed[pos] == pack_rows(flat)) & valid.reshape(-1)
        qq = np.repeat(np.arange(len(q)) + s, len(deltas))[hit]
        gg = pos[hit]
        oo = np.tile(doff, len(q))[hit]
        if not include_self:
            keep = ~np.all(ids[gg] == queries[qq], axis=1)
            qq, gg, oo = qq[keep], gg[keep], oo[keep]
        out_q.append(qq); out_g.append(gg); out_o.append(oo)
    q_of = np.concatenate(out_q); grid = np.concatenate(out_g); off = np.concatenate(out_o)
    perm = np.lexsort((grid, off, q_of))
    q_of, grid, off = q_of[perm], grid[perm], off[perm]
    indptr = np.zeros(nq + 1, dtype=np.int64)
    np.add.at(indptr, q_of + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, grid, off


# --------------------------------------------------------------------------
# in-graph (device) neighbor table
# --------------------------------------------------------------------------

def _bsearch(col: jnp.ndarray, value: jnp.ndarray, lo: jnp.ndarray,
             hi: jnp.ndarray, side: str, steps: int) -> jnp.ndarray:
    """Binary search for `value` in sorted col[lo:hi] (vectorized)."""

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        v = col[jnp.clip(mid, 0, col.shape[0] - 1)]
        pred = (v < value) if side == "left" else (v <= value)
        active = lo < hi
        lo = jnp.where(active & pred, mid + 1, lo)
        hi = jnp.where(active & ~pred, mid, hi)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


@partial(jax.jit, static_argnames=("frontier_cap", "k_cap", "include_self",
                                   "packed"))
def device_neighbor_table(sorted_ids: jnp.ndarray, num_grids: jnp.ndarray,
                          frontier_cap: int = 128, k_cap: int = 64,
                          include_self: bool = True, packed: bool = True):
    """In-graph Algorithm 3 for every non-empty grid simultaneously.

    Args:
      sorted_ids: [G_cap, d] lex-sorted identifiers (PAD_ID padded).
      num_grids:  [] actual number of grids.
      frontier_cap: static cap on per-level surviving prefix ranges.
      k_cap: static cap on returned neighbors per grid.
      packed: sweep only the live-grid prefix in fixed-size blocks
        (the lex sort parks every live grid in rows [0, num_grids), so
        a blocked ``while_loop`` skips the dead tail entirely); the
        dense path traverses every ``G_cap`` row.  Bit-identical: live
        rows run the same per-row query either way, and dead rows are
        ``-1`` in both (the dense path masks them, the packed path
        never writes them).

    Returns:
      nbr:     [G_cap, k_cap] int32 neighbor grid rows (-1 padded),
               offset-ascending per row (paper's sorted order).
      nbr_off: [G_cap, k_cap] int32 integer offsets (side^2 units).
      ovf_frontier: [] bool -- frontier_cap exceeded (result a subset).
      ovf_k:        [] bool -- k_cap exceeded (result a subset).
    """
    G_cap, d = sorted_ids.shape
    r = radius(d)
    steps = int(math.ceil(math.log2(max(G_cap, 2)))) + 1
    n_k = 2 * r + 1
    BIG = jnp.int32(2**30)

    def one_query(qid_row):
        q = sorted_ids[qid_row]
        lo = jnp.zeros((1,), jnp.int32)
        hi = jnp.asarray([num_grids], jnp.int32)
        off = jnp.zeros((1,), jnp.int32)
        valid = jnp.ones((1,), bool)
        ovf_frontier = jnp.zeros((), bool)

        for j in range(d):
            # the traversal starts from ONE root range and multiplies
            # by at most n_k per level, so level j holds <= n_k^j live
            # ranges -- size the level's arrays to that bound instead
            # of a flat frontier_cap (the dead-lane padding dominated
            # this stage's wall).  Same entries, same compaction order,
            # same overflow predicate: width only drops provably-dead
            # lanes, so the output is bit-identical.
            W = lo.shape[0]
            col = sorted_ids[:, j]
            # one left-bsearch over the n_k+1 consecutive keys
            # [q_j-r .. q_j+r+1]; since keys are consecutive integers,
            # right(k) == left(k+1), so range ends come for free
            # (halves the search work -- §Perf cluster iteration).
            ks1 = q[j] + jnp.arange(-r, r + 2, dtype=jnp.int32)    # [n_k+1]
            lo_e1 = jnp.repeat(lo, n_k + 1)
            hi_e1 = jnp.repeat(hi, n_k + 1)
            k_e1 = jnp.tile(ks1, W)
            pos = _bsearch(col, k_e1, lo_e1, hi_e1, "left", steps)
            pos = pos.reshape(W, n_k + 1)
            nlo = pos[:, :-1].reshape(-1)
            nhi = pos[:, 1:].reshape(-1)
            off_e = jnp.repeat(off, n_k)
            val_e = jnp.repeat(valid, n_k)
            k_e = jnp.tile(ks1[:-1], W)
            doff = jnp.maximum(jnp.abs(k_e - q[j]) - 1, 0) ** 2
            noff = off_e + doff
            nval = val_e & (nlo < nhi) & (noff < d) & (k_e >= 0)
            # compact: valid entries first, offset ascending within valid
            key = jnp.where(nval, noff, BIG)
            order = jnp.argsort(key, stable=True)
            take = order[:min(W * n_k, frontier_cap)]
            ovf_frontier = ovf_frontier | (jnp.sum(nval) > frontier_cap)
            lo, hi = nlo[take], nhi[take]
            off, valid = noff[take], nval[take]

        # leaves: each surviving range is a single grid row (full id fixed)
        if k_cap > lo.shape[0]:
            # leaf arrays are level-d wide; widen so the promised
            # [., k_cap] output shape holds
            ext = k_cap - lo.shape[0]
            lo = jnp.concatenate([lo, jnp.full((ext,), 0, lo.dtype)])
            off = jnp.concatenate([off, jnp.full((ext,), BIG, off.dtype)])
            valid = jnp.concatenate([valid, jnp.zeros((ext,), bool)])
        grid = jnp.where(valid, lo, -1)
        if not include_self:
            is_self = valid & (lo == qid_row)
            valid = valid & ~is_self
            grid = jnp.where(valid, grid, -1)
            off = jnp.where(valid, off, BIG)
            order = jnp.argsort(off, stable=True)
            grid, off, valid = grid[order], off[order], valid[order]
        ovf_k = jnp.sum(valid) > k_cap
        return (grid[:k_cap], jnp.where(valid, off, -1)[:k_cap],
                ovf_frontier, ovf_k)

    if not packed:
        rows = jnp.arange(G_cap, dtype=jnp.int32)
        nbr, nbr_off, ovf_f, ovf_k = jax.vmap(one_query)(rows)
        live = rows < num_grids
        nbr = jnp.where(live[:, None], nbr, -1)
        nbr_off = jnp.where(live[:, None], nbr_off, -1)
        return nbr, nbr_off, jnp.any(ovf_f & live), jnp.any(ovf_k & live)

    # packed: blocked sweep over the live prefix only.  Block starts
    # are clamped so the last block stays in bounds when GB does not
    # divide G_cap; overlapped rows recompute the same per-row values,
    # so the double write is benign.
    GB = min(64, G_cap)
    nblk = (jnp.minimum(num_grids, G_cap) + GB - 1) // GB

    def body(state):
        b, nbr, nbr_off, ovf_f, ovf_k = state
        s = jnp.minimum(b * GB, G_cap - GB)
        rows = s + jnp.arange(GB, dtype=jnp.int32)
        live = rows < num_grids
        g, o, of, ok = jax.vmap(one_query)(rows)
        g = jnp.where(live[:, None], g, -1)
        o = jnp.where(live[:, None], o, -1)
        nbr = jax.lax.dynamic_update_slice(nbr, g, (s, 0))
        nbr_off = jax.lax.dynamic_update_slice(nbr_off, o, (s, 0))
        return (b + 1, nbr, nbr_off,
                ovf_f | jnp.any(of & live), ovf_k | jnp.any(ok & live))

    init = (jnp.int32(0),
            jnp.full((G_cap, k_cap), -1, jnp.int32),
            jnp.full((G_cap, k_cap), -1, jnp.int32),
            jnp.zeros((), bool), jnp.zeros((), bool))
    _, nbr, nbr_off, ovf_f, ovf_k = jax.lax.while_loop(
        lambda st: st[0] < nblk, body, init)
    return nbr, nbr_off, ovf_f, ovf_k
