"""Grid construction (paper Algorithm 1).

Each dimension of the feature space is divided into intervals of length
``eps / sqrt(d)``; a point's grid *identifier* is the d-vector of its
interval indices (eq. (1) of the paper).  Points are then sorted
lexicographically by identifier (the paper uses radix sort; we use a
stable multi-key sort which is the vectorized equivalent) so points of
the same grid are adjacent, and the non-empty grids are read off as a
CSR partition of the sorted order.

Two implementations share the same semantics:

* ``build_grids``        -- host path (numpy, dynamic shapes): used by the
                            paper-faithful benchmarks and the LDF variant.
* ``build_grids_device`` -- device path (pure jnp, static ``grid_cap``):
                            jittable, feeds the distributed pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GridIndex:
    """CSR view of the non-empty grids over a sorted point order (host)."""

    order: np.ndarray        # [n]   permutation: points sorted by grid id
    ids: np.ndarray          # [G,d] identifiers of non-empty grids (lex-sorted)
    starts: np.ndarray       # [G]   start of each grid's points in `order`
    counts: np.ndarray       # [G]   points per grid
    point_grid: np.ndarray   # [n]   grid index (into ids) of each point, original order
    side: float              # grid side length eps/sqrt(d)
    mins: np.ndarray         # [d]   per-dim minimum used as the origin
    eta: int                 # max interval index over all dims (paper's eta)

    @property
    def num_grids(self) -> int:
        return int(self.ids.shape[0])


def identifiers(points: np.ndarray, eps: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Eq. (1): per-point grid identifiers. Returns (ids[n,d], mins[d], side)."""
    points = np.asarray(points)
    d = points.shape[1]
    side = float(eps) / np.sqrt(d)
    mins = points.min(axis=0)
    ids = np.floor((points - mins[None, :]) / side).astype(np.int64)
    return ids, mins, side


def group_rows(ids: np.ndarray):
    """Lex-sort integer id rows and read off the run (grid) structure.

    The shared core of Algorithm 1, also used by the fitted index's
    insert splice and the kernel predict's query grouping.  Returns
    ``(order, sorted_ids, starts, counts, group_of_sorted)``: a stable
    lexicographic permutation, the sorted rows, CSR boundaries of each
    run of equal rows, and each sorted row's run index.
    """
    ids = np.asarray(ids)
    n, d = ids.shape
    order = np.lexsort(tuple(ids[:, j] for j in range(d - 1, -1, -1)))
    sids = ids[order]
    new = np.ones(n, dtype=bool)
    if n:
        new[1:] = np.any(sids[1:] != sids[:-1], axis=1)
    starts = np.flatnonzero(new).astype(np.int64)
    counts = np.diff(np.append(starts, n)).astype(np.int64)
    group_of = np.cumsum(new) - 1
    return order, sids, starts, counts, group_of


def build_grids(points: np.ndarray, eps: float) -> GridIndex:
    """Algorithm 1 (host). O(n log n) via lexsort (radix-family, stable)."""
    pts = np.asarray(points, dtype=np.float64)
    n, d = pts.shape
    # n == 0 must fail *here*, not as an opaque reduction error inside
    # identifiers(); the public API (engine.cluster) validates earlier
    # still, with the same message style
    if n == 0:
        raise ValueError("empty point set")
    ids, mins, side = identifiers(pts, eps)
    order, sids, starts, counts, grid_of_sorted = group_rows(ids)
    point_grid = np.empty(n, dtype=np.int64)
    point_grid[order] = grid_of_sorted
    gids = sids[starts]
    eta = int(ids.max()) if n else 0
    return GridIndex(order=order, ids=gids, starts=starts, counts=counts,
                     point_grid=point_grid, side=side, mins=mins, eta=eta)


# --------------------------------------------------------------------------
# Device path: identical semantics, static shapes (grid_cap), pure jnp.
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceGrids:
    """Static-shape grid partition living on device.

    Grids beyond ``num_grids`` are padding: ids == INT_MAX sentinel,
    counts == 0.
    """

    sorted_points: jnp.ndarray   # [n, d] points permuted to grid order
    order: jnp.ndarray           # [n]    original index of each sorted point
    ids: jnp.ndarray             # [G_cap, d] int32 identifiers (lex-sorted, padded)
    starts: jnp.ndarray          # [G_cap] int32
    counts: jnp.ndarray          # [G_cap] int32 (0 for padding)
    point_grid: jnp.ndarray      # [n] int32 grid index of each *sorted* point
    num_grids: jnp.ndarray       # [] int32
    side: jnp.ndarray            # [] f32
    mins: jnp.ndarray            # [d] f32
    overflow: jnp.ndarray        # [] bool: true grid count exceeded G_cap

    def tree_flatten(self):
        fields = (self.sorted_points, self.order, self.ids, self.starts,
                  self.counts, self.point_grid, self.num_grids, self.side,
                  self.mins, self.overflow)
        return fields, None

    @classmethod
    def tree_unflatten(cls, aux, fields):
        return cls(*fields)


PAD_ID = jnp.int32(2**30)


def build_grids_device(points: jnp.ndarray, eps, grid_cap: int) -> DeviceGrids:
    """Algorithm 1 fully in-graph. Shapes static given ``grid_cap``.

    The lexicographic radix sort of the paper maps to a stable multi-key
    ``lax.sort`` over the identifier columns.
    """
    n, d = points.shape
    side = jnp.asarray(eps, jnp.float32) / jnp.sqrt(jnp.float32(d))
    mins = points.min(axis=0)
    # Clamp identifiers into [0, PAD_ID] *before* the int32 cast:
    # padding points sit at PAD_COORD (~1e15), whose raw interval index
    # overflows int32, and XLA's out-of-range float->int conversion is
    # implementation-defined -- it may wrap negative and lex-sort the
    # padding grids *ahead of* every real grid, corrupting
    # point_grid/starts.  Clamped, every out-of-range (or non-finite)
    # coordinate lands exactly on the PAD_ID sentinel, so padding points
    # share one sentinel grid that sorts after all real grids.  A *valid*
    # point can only reach the clamp when span/side >= 2^30 -- but the
    # f32 quotient already quantizes by whole cells beyond ~2^22, so the
    # engine layer rejects span/side >= 2^22 host-side before tracing
    # (engines._check_device_grid_range); raising is impossible here
    # under jit.
    idf = jnp.floor((points - mins[None, :]) / side)
    idf = jnp.where(jnp.isfinite(idf), idf, jnp.float32(PAD_ID))
    ids = jnp.clip(idf, 0.0, jnp.float32(PAD_ID)).astype(jnp.int32)

    operands = tuple(ids[:, j] for j in range(d)) + (
        jnp.arange(n, dtype=jnp.int32),)
    sorted_ops = jax.lax.sort(operands, num_keys=d, is_stable=True)
    sids = jnp.stack(sorted_ops[:d], axis=1)          # [n, d]
    order = sorted_ops[d]
    sorted_points = points[order]

    new = jnp.concatenate([
        jnp.ones((1,), bool),
        jnp.any(sids[1:] != sids[:-1], axis=1)])      # [n]
    grid_of_sorted = (jnp.cumsum(new.astype(jnp.int32)) - 1)
    num_grids = grid_of_sorted[-1] + 1
    overflow = num_grids > grid_cap
    g = jnp.minimum(grid_of_sorted, grid_cap - 1).astype(jnp.int32)

    starts = jnp.full((grid_cap,), n, jnp.int32).at[g].min(
        jnp.arange(n, dtype=jnp.int32))
    counts = jnp.zeros((grid_cap,), jnp.int32).at[g].add(1)
    counts = jnp.where(jnp.arange(grid_cap) < num_grids, counts, 0)
    gids = jnp.full((grid_cap, d), PAD_ID, jnp.int32).at[g].set(sids)
    gids = jnp.where((jnp.arange(grid_cap) < num_grids)[:, None], gids, PAD_ID)

    return DeviceGrids(sorted_points=sorted_points, order=order, ids=gids,
                       starts=starts, counts=counts, point_grid=g,
                       num_grids=num_grids, side=side, mins=mins,
                       overflow=overflow)
