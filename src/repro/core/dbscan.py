"""GriT-DBSCAN (paper Algorithm 6) and baselines — host engines.

Pipeline (paper §4.4):
  1. partition into grids (Alg 1) + grid tree (Alg 2) + neighbor queries (Alg 3)
  2. identify core points G13-style (all-core shortcut for grids with
     >= MinPts points; offset-sorted candidate scan with early exit otherwise)
  3. merge core grids into clusters via FastMerging (Alg 5)
       - variant "grit": BFS over seeds exactly as Algorithm 6
       - variant "ldf":  union-find + low-density-first order (paper §5.2)
  4. assign non-core points as border/noise

Label contract: ``labels[i] >= 0`` cluster id, ``-1`` noise.  Cluster ids
are arbitrary but consistent; use ``canonicalize_labels`` to compare.

``brute_dbscan`` is the O(n^2) oracle used by tests and benchmarks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .grids import build_grids, GridIndex
from .grid_tree import GridTree, stencil_neighbors
from .merging import fast_merging, center_prune_merge, brute_min_dist
from .labels import UnionFind


# --------------------------------------------------------------------------
# oracle
# --------------------------------------------------------------------------

def brute_dbscan(points: np.ndarray, eps: float, min_pts: int,
                 chunk: int = 2048) -> np.ndarray:
    """Reference DBSCAN: O(n^2) neighborhood counts + BFS over core graph."""
    pts = np.asarray(points, np.float64)
    n = len(pts)
    eps2 = float(eps) ** 2
    counts = np.zeros(n, dtype=np.int64)
    for s in range(0, n, chunk):
        d2 = ((pts[s:s + chunk, None, :] - pts[None, :, :]) ** 2).sum(-1)
        counts[s:s + chunk] = (d2 <= eps2).sum(1)
    core = counts >= min_pts
    labels = np.full(n, -1, dtype=np.int64)
    cid = 0
    core_idx = np.flatnonzero(core)
    for seed in core_idx:
        if labels[seed] != -1:
            continue
        labels[seed] = cid
        frontier = [seed]
        while frontier:
            b = pts[frontier]
            d2 = ((b[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
            reach = np.flatnonzero((d2 <= eps2).any(0))
            nxt = []
            for r in reach:
                if labels[r] == -1:
                    labels[r] = cid
                    if core[r]:
                        nxt.append(r)
            frontier = nxt
        cid += 1
    return labels


def canonicalize_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel clusters by first occurrence so label arrays are comparable."""
    labels = np.asarray(labels)
    out = np.full_like(labels, -1)
    mapping: dict = {}
    nxt = 0
    for i, l in enumerate(labels):
        if l < 0:
            continue
        if l not in mapping:
            mapping[l] = nxt
            nxt += 1
        out[i] = mapping[l]
    return out


# --------------------------------------------------------------------------
# GriT-DBSCAN host engine
# --------------------------------------------------------------------------

@dataclasses.dataclass
class DBSCANResult:
    labels: np.ndarray           # [n] cluster per point (-1 noise)
    core: np.ndarray             # [n] bool
    stats: dict                  # timings + counters
    grid: Optional[GridIndex] = None   # the partition the run was built on


def _neighbor_lists(gi: GridIndex, engine: str):
    """CSR neighbor lists for all grids (self excluded), offset-sorted."""
    if engine == "tree":
        tree = GridTree.build(gi.ids)
        return tree.query(gi.ids, include_self=False)
    elif engine == "stencil":
        return stencil_neighbors(gi.ids, gi.ids, include_self=False)
    raise ValueError(engine)


def _identify_cores(points: np.ndarray, gi: GridIndex, indptr, nbr,
                    eps: float, min_pts: int, stats: dict) -> np.ndarray:
    """Step 2: core flags per point (original order)."""
    pts = np.asarray(points, np.float64)
    eps2 = eps * eps
    n = len(pts)
    core = np.zeros(n, dtype=bool)
    big = gi.counts >= min_pts
    # all-core shortcut
    for g in np.flatnonzero(big):
        core[gi.order[gi.starts[g]:gi.starts[g] + gi.counts[g]]] = True
    stats["all_core_grids"] = int(big.sum())
    # small grids: offset-sorted candidate scan with early exit
    dist_evals = 0
    for g in np.flatnonzero(~big):
        own = gi.order[gi.starts[g]:gi.starts[g] + gi.counts[g]]
        p = pts[own]
        cnt = np.full(len(own), len(own), dtype=np.int64)  # own grid all <= eps
        nbrs = nbr[indptr[g]:indptr[g + 1]]
        undecided = cnt < min_pts
        for ng in nbrs:                       # offset-ascending (paper order)
            if not undecided.any():
                break
            cand = gi.order[gi.starts[ng]:gi.starts[ng] + gi.counts[ng]]
            d2 = ((p[undecided][:, None, :] - pts[cand][None, :, :]) ** 2).sum(-1)
            dist_evals += d2.size
            cnt[undecided] += (d2 <= eps2).sum(1)
            undecided = cnt < min_pts
        core[own] = cnt >= min_pts
    stats["core_dist_evals"] = dist_evals
    return core


def _core_sets(gi: GridIndex, core: np.ndarray):
    """Per-grid arrays of core-point indices (original order ids)."""
    sets = []
    for g in range(gi.num_grids):
        own = gi.order[gi.starts[g]:gi.starts[g] + gi.counts[g]]
        sets.append(own[core[own]])
    return sets


def _assign_noncore(points, gi: GridIndex, indptr, nbr, core, grid_label,
                    eps, labels, stats):
    """Step 4: border vs noise for non-core points."""
    pts = np.asarray(points, np.float64)
    eps2 = eps * eps
    dist_evals = 0
    for g in range(gi.num_grids):
        own = gi.order[gi.starts[g]:gi.starts[g] + gi.counts[g]]
        nc = own[~core[own]]
        if len(nc) == 0:
            continue
        p = pts[nc]
        best = np.full(len(nc), np.inf)
        blab = np.full(len(nc), -1, dtype=np.int64)
        cand_grids = [g] + list(nbr[indptr[g]:indptr[g + 1]])
        for ng in cand_grids:
            cand = gi.order[gi.starts[ng]:gi.starts[ng] + gi.counts[ng]]
            cand = cand[core[cand]]
            if len(cand) == 0:
                continue
            d2 = ((p[:, None, :] - pts[cand][None, :, :]) ** 2).sum(-1)
            dist_evals += d2.size
            j = d2.argmin(1)
            m = d2[np.arange(len(nc)), j]
            upd = (m <= eps2) & (m < best)
            best[upd] = m[upd]
            blab[upd] = labels[cand[j[upd]]]
        labels[nc] = blab
    stats["border_dist_evals"] = dist_evals


def grit_dbscan(points: np.ndarray, eps: float, min_pts: int,
                variant: str = "grit", neighbor_engine: str = "tree",
                merge_engine: str = "fast",
                rng: Optional[np.random.Generator] = None) -> DBSCANResult:
    """GriT-DBSCAN / GriT-DBSCAN-LDF and ablation engines (host).

    variant: "grit" (Alg 6 BFS) | "ldf" (union-find, low-density first)
    neighbor_engine: "tree" (grid tree) | "stencil" (gan-style baseline)
    merge_engine: "fast" (Alg 5) | "center" (KNN-BLOCK baseline) | "brute"
    """
    pts = np.asarray(points, np.float64)
    n = len(pts)
    stats: dict = {"n": n, "variant": variant, "neighbor_engine": neighbor_engine,
                   "merge_engine": merge_engine}

    t0 = time.perf_counter()
    gi = build_grids(pts, eps)
    stats["num_grids"] = gi.num_grids
    t1 = time.perf_counter()
    indptr, nbr, nbr_off = _neighbor_lists(gi, neighbor_engine)
    t2 = time.perf_counter()
    core = _identify_cores(pts, gi, indptr, nbr, eps, min_pts, stats)
    t3 = time.perf_counter()

    core_sets = _core_sets(gi, core)
    is_core_grid = np.array([len(s) > 0 for s in core_sets])
    merge_stats: dict = {}
    if merge_engine == "fast":
        merge = lambda a, b: fast_merging(a, b, eps, rng=rng, stats=merge_stats)
    elif merge_engine == "center":
        merge = lambda a, b: center_prune_merge(a, b, eps, stats=merge_stats)
    elif merge_engine == "brute":
        def merge(a, b):
            merge_stats["dist_evals"] = merge_stats.get("dist_evals", 0) + len(a) * len(b)
            merge_stats["calls"] = merge_stats.get("calls", 0) + 1
            return brute_min_dist(a, b) <= eps
    else:
        raise ValueError(merge_engine)

    grid_label = np.full(gi.num_grids, -1, dtype=np.int64)
    merge_checks = 0
    if variant == "grit":
        # Algorithm 6: BFS over seeds
        cid = 0
        for g0 in range(gi.num_grids):
            if not is_core_grid[g0] or grid_label[g0] != -1:
                continue
            grid_label[g0] = cid
            seeds = [g0]
            pos = 0
            while pos < len(seeds):
                cur = seeds[pos]
                pos += 1
                for g2 in nbr[indptr[cur]:indptr[cur + 1]]:
                    if not is_core_grid[g2] or grid_label[g2] != -1:
                        continue
                    merge_checks += 1
                    if merge(pts[core_sets[cur]], pts[core_sets[g2]]):
                        grid_label[g2] = cid
                        seeds.append(g2)
            cid += 1
    elif variant == "ldf":
        # union-find + low-density-first traversal (paper §5.2)
        uf = UnionFind(gi.num_grids)
        m = np.array([len(s) for s in core_sets])
        order = np.argsort(m, kind="stable")          # ascending core count
        for g in order:
            if not is_core_grid[g]:
                continue
            for g2 in nbr[indptr[g]:indptr[g + 1]]:
                if not is_core_grid[g2]:
                    continue
                if uf.find(g) == uf.find(g2):
                    continue                          # already same cluster
                merge_checks += 1
                if merge(pts[core_sets[g]], pts[core_sets[g2]]):
                    uf.union(g, g2)
        roots = {}
        for g in np.flatnonzero(is_core_grid):
            r = uf.find(g)
            if r not in roots:
                roots[r] = len(roots)
            grid_label[g] = roots[r]
    else:
        raise ValueError(variant)
    t4 = time.perf_counter()
    stats["merge_checks"] = merge_checks
    stats.update({f"merge_{k}": v for k, v in merge_stats.items()})

    labels = np.full(n, -1, dtype=np.int64)
    for g in range(gi.num_grids):
        if grid_label[g] < 0:
            continue
        own = gi.order[gi.starts[g]:gi.starts[g] + gi.counts[g]]
        labels[own[core[own]]] = grid_label[g]
    _assign_noncore(pts, gi, indptr, nbr, core, grid_label, eps, labels, stats)
    t5 = time.perf_counter()

    stats["t_partition"] = t1 - t0
    stats["t_neighbors"] = t2 - t1
    stats["t_cores"] = t3 - t2
    stats["t_merge"] = t4 - t3
    stats["t_assign"] = t5 - t4
    stats["t_total"] = t5 - t0
    stats["num_clusters"] = int(grid_label.max() + 1) if (grid_label >= 0).any() else 0
    return DBSCANResult(labels=labels, core=core, stats=stats, grid=gi)
