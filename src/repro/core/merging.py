"""FastMerging (paper §4.3, Algorithms 4-5).

Decides exactly whether ``MinDist(s_i, s_j) <= eps`` while pruning
distance work via two spatial strategies:

* triangle-inequality pruning: with pivot ``p`` and its nearest point
  ``q`` in the other set at distance > eps, every ``x`` with
  ``dist(x, p) < dist(p, q) - eps`` can never reach the other set.
* angle pruning (Theorem 1): every ``x`` whose angle to ``pq`` exceeds
  ``lambda = max_y [ arcsin(eps / dist(p, y)) + angle(pq, py) ]``
  is provably outside every ``N_eps(y)``;  Theorem 1 guarantees
  ``lambda < 5*pi/6`` for neighboring core grids, so the pruned region
  is never empty and the loop always progresses.

Three engines, identical decisions:

* ``fast_merging``        -- host, paper-faithful (physical point removal).
* ``fast_merging_masked`` -- pure-jnp, removal -> mask update, fixed
                             shapes, ``lax.while_loop`` over the paper's
                             kappa iterations. vmap-able across grid pairs.
* ``center_prune_merge``  -- the KNN-BLOCK-DBSCAN-style baseline the paper
                             compares against in §4.3.1 (single
                             center-distance filter, then brute force).

All report the number of iterations (paper's kappa) and distance
evaluations so the benchmarks can reproduce the paper's efficiency story.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

_INF = np.float64(np.inf)


# --------------------------------------------------------------------------
# host, paper-faithful
# --------------------------------------------------------------------------

def _prune(si: np.ndarray, sj: np.ndarray, p: np.ndarray, q: np.ndarray,
           eps: float) -> np.ndarray:
    """Algorithm 4: remove trivial points from ``si`` (returns kept rows)."""
    dpq = np.linalg.norm(p - q)
    sigma = dpq - eps
    # lambda = max_y arcsin(eps/d(p,y)) + angle(pq, py)   (eq. 5, eq. 10)
    py = sj - p[None, :]
    dpy = np.linalg.norm(py, axis=1)
    # all y satisfy d(p,y) >= d(p,q) > eps  (q is the argmin), so arcsin is safe
    cos_t1 = np.clip((py @ (q - p)) / (dpy * dpq), -1.0, 1.0)
    lam = float(np.max(np.arcsin(np.clip(eps / dpy, -1.0, 1.0)) + np.arccos(cos_t1)))

    px = si - p[None, :]
    dpx = np.linalg.norm(px, axis=1)
    tri = dpx < sigma                                   # triangle-inequality prune
    with np.errstate(invalid="ignore", divide="ignore"):
        cos_g = np.clip((px @ (q - p)) / (dpx * dpq), -1.0, 1.0)
        theta = np.arccos(cos_g)
    theta = np.where(dpx == 0.0, 0.0, theta)            # x == p handled by tri
    ang = theta > lam                                   # angle prune
    return si[~(tri | ang)]


def fast_merging(si: np.ndarray, sj: np.ndarray, eps: float,
                 rng: np.random.Generator | None = None,
                 stats: dict | None = None) -> bool:
    """Algorithm 5 (host). Exact: True iff MinDist(si, sj) <= eps."""
    si = np.asarray(si, np.float64).copy()
    sj = np.asarray(sj, np.float64).copy()
    if si.size == 0 or sj.size == 0:
        return False
    eps = float(eps)
    idx = 0 if rng is None else int(rng.integers(len(si)))
    p = si[idx]
    iters = 0
    dist_evals = 0
    while True:
        iters += 1
        # q = argmin_{y in s_j} dist(p, y)
        dj = np.linalg.norm(sj - p[None, :], axis=1)
        dist_evals += len(sj)
        jq = int(np.argmin(dj))
        q = sj[jq]
        if dj[jq] <= eps:
            break_yes = True
            break
        si = _prune(si, sj, p, q, eps)
        dist_evals += len(si)
        if len(si) == 0:
            break_yes = False
            break
        # p = argmin_{x in s_i} dist(x, q)
        di = np.linalg.norm(si - q[None, :], axis=1)
        dist_evals += len(si)
        ip = int(np.argmin(di))
        p = si[ip]
        if di[ip] <= eps:
            break_yes = True
            break
        sj = _prune(sj, si, q, p, eps)
        dist_evals += len(sj)
        if len(sj) == 0:
            break_yes = False
            break
    if stats is not None:
        stats["iters"] = stats.get("iters", 0) + iters
        stats["max_iters"] = max(stats.get("max_iters", 0), iters)
        stats["dist_evals"] = stats.get("dist_evals", 0) + dist_evals
        stats["calls"] = stats.get("calls", 0) + 1
    return break_yes


def brute_min_dist(si: np.ndarray, sj: np.ndarray) -> float:
    """O(m_i * m_j) oracle for MinDist (paper §4.3.1 'straightforward way')."""
    d2 = ((si[:, None, :] - sj[None, :, :]) ** 2).sum(-1)
    return float(np.sqrt(d2.min()))


def center_prune_merge(si: np.ndarray, sj: np.ndarray, eps: float,
                       stats: dict | None = None) -> bool:
    """KNN-BLOCK-DBSCAN-style merging baseline (paper §4.3.1).

    Prunes p in s_i with dist(p, c_j) > eps + xi_j (and symmetrically),
    then brute-forces the rest.  Exact, but degrades to O(m_i m_j).
    """
    si = np.asarray(si, np.float64)
    sj = np.asarray(sj, np.float64)
    ci, cj = si.mean(0), sj.mean(0)
    xi_i = np.linalg.norm(si - ci[None], axis=1).max()
    xi_j = np.linalg.norm(sj - cj[None], axis=1).max()
    keep_i = np.linalg.norm(si - cj[None], axis=1) <= eps + xi_j
    keep_j = np.linalg.norm(sj - ci[None], axis=1) <= eps + xi_i
    a, b = si[keep_i], sj[keep_j]
    if stats is not None:
        stats["dist_evals"] = stats.get("dist_evals", 0) + \
            len(si) + len(sj) + len(a) * len(b)
        stats["calls"] = stats.get("calls", 0) + 1
    if len(a) == 0 or len(b) == 0:
        return False
    return brute_min_dist(a, b) <= eps


# --------------------------------------------------------------------------
# device, masked (removal -> mask update), fixed shapes
# --------------------------------------------------------------------------

def _masked_prune_jnp(A, va, B, vb, p, q, eps):
    """Algorithm 4 on masks: returns updated validity mask for A.

    The angular test runs entirely in cosine space: with
    ``lam_y = arcsin(eps/d(p,y)) + arccos(cos_b)`` and
    ``theta_x = arccos(cos_g)`` all in [0, pi] where cosine is strictly
    decreasing, ``theta_x > max_y lam_y`` is equivalent to
    ``cos_g < min_y cos(lam_y)`` with
    ``cos(a + b) = cos_a cos_b - sin_a sin_b`` (sum identity), unless
    some ``lam_y`` exceeds pi -- detected as ``cos_b < -cos_a`` (since
    ``a <= pi/2``), in which case ``lam >= pi >= theta`` and no point
    is angle-pruned.  This removes every ``arcsin``/``arccos`` from
    the merge hot loop (they dominated its wall on CPU)."""
    dpq = jnp.linalg.norm(p - q)
    sigma = dpq - eps
    py = B - p[None, :]
    dpy = jnp.linalg.norm(py, axis=1)
    safe_dpy = jnp.maximum(dpy, 1e-30)
    cos_b = jnp.clip((py @ (q - p)) / (safe_dpy * jnp.maximum(dpq, 1e-30)), -1., 1.)
    sin_a = jnp.clip(eps / safe_dpy, 0., 1.)
    cos_a = jnp.sqrt(1. - sin_a * sin_a)
    sin_b = jnp.sqrt(1. - cos_b * cos_b)
    cos_ab = cos_a * cos_b - sin_a * sin_b
    over_pi = jnp.any(vb & (cos_b < -cos_a))
    # empty B: min over nothing -> +inf, so every x is angle-pruned
    # (matching the lam = -inf behavior of the angle-space form)
    cos_lam = jnp.min(jnp.where(vb, cos_ab, jnp.inf))

    px = A - p[None, :]
    dpx = jnp.linalg.norm(px, axis=1)
    tri = dpx < sigma
    cos_g = jnp.clip((px @ (q - p)) /
                     (jnp.maximum(dpx, 1e-30) * jnp.maximum(dpq, 1e-30)), -1., 1.)
    cos_g = jnp.where(dpx == 0.0, 1.0, cos_g)   # theta(p) = 0
    ang = (cos_g < cos_lam) & ~over_pi
    return va & ~(tri | ang)


@partial(jax.jit, static_argnames=("max_iters",))
def fast_merging_masked(si: jnp.ndarray, valid_i: jnp.ndarray,
                        sj: jnp.ndarray, valid_j: jnp.ndarray,
                        eps, max_iters: int = 64):
    """Algorithm 5 with masking. Exact decision; fixed shapes.

    Args:
      si: [Mi, d] padded point set, valid_i: [Mi] bool.
      sj: [Mj, d] padded point set, valid_j: [Mj] bool.
    Returns:
      (merge: bool, iters: int32) -- `iters` is the paper's kappa.
    """
    si = si.astype(jnp.float32)
    sj = sj.astype(jnp.float32)
    eps = jnp.asarray(eps, jnp.float32)

    def masked_argmin(dists, valid):
        d = jnp.where(valid, dists, jnp.inf)
        i = jnp.argmin(d)
        return i, d[i]

    # pivot: first valid point of s_i
    p0 = jnp.argmax(valid_i)

    def cond(state):
        va, vb, _, done, _, it = state
        return (~done) & jnp.any(va) & jnp.any(vb) & (it < max_iters)

    def body(state):
        va, vb, p_idx, done, res, it = state
        p = si[p_idx]
        jq, dq = masked_argmin(jnp.linalg.norm(sj - p[None], axis=1), vb)
        q = sj[jq]
        hit1 = dq <= eps
        va_pruned = _masked_prune_jnp(si, va, sj, vb, p, q, eps)
        va2 = jnp.where(hit1, va, va_pruned)
        empty_i = ~jnp.any(va2)
        ip, dp = masked_argmin(jnp.linalg.norm(si - q[None], axis=1), va2)
        hit2 = (~hit1) & (~empty_i) & (dp <= eps)
        p2 = si[ip]
        vb2 = jnp.where(hit1 | hit2 | empty_i, vb,
                        _masked_prune_jnp(sj, vb, si, va2, q, p2, eps))
        new_done = hit1 | hit2 | empty_i | ~jnp.any(vb2)
        new_res = hit1 | hit2
        return (va2, vb2, ip, done | new_done, res | new_res, it + 1)

    init = (valid_i, valid_j, p0, ~(jnp.any(valid_i) & jnp.any(valid_j)),
            jnp.zeros((), bool), jnp.zeros((), jnp.int32))
    va, vb, _, done, res, it = jax.lax.while_loop(cond, body, init)
    return res, it


def fast_merging_batch(si, valid_i, sj, valid_j, eps, max_iters: int = 64):
    """vmap of ``fast_merging_masked`` across a batch of grid pairs."""
    f = partial(fast_merging_masked, max_iters=max_iters)
    return jax.vmap(lambda a, va, b, vb: f(a, va, b, vb, eps))(
        si, valid_i, sj, valid_j)
