"""Connected components over the core-grid merge graph.

* ``UnionFind``             -- host path-compression union-find, used by the
                               GriT-DBSCAN-LDF variant (paper §5.2) where the
                               *order* of merge checks matters (low-density
                               first, skip same-set pairs).
* ``label_propagation``     -- device pointer-jumping min-label propagation:
                               the TPU-native equivalent of BFS/union-find
                               (log-depth, fixed shapes, jit/shard_map-able).
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp


class UnionFind:
    """Array-based union-find with path compression + union by size."""

    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.size = np.ones(n, dtype=np.int64)

    def find(self, x: int) -> int:
        root = x
        p = self.parent
        while p[root] != root:
            root = p[root]
        while p[x] != root:            # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.size[ra] < self.size[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.size[ra] += self.size[rb]
        return True

    def labels(self) -> np.ndarray:
        return np.array([self.find(i) for i in range(len(self.parent))])


@partial(jax.jit, static_argnames=("num_nodes_cap", "max_rounds"))
def label_propagation(num_nodes_cap: int, edges: jnp.ndarray,
                      edge_valid: jnp.ndarray, node_valid: jnp.ndarray,
                      max_rounds: int = 0):
    """Min-label propagation + pointer jumping over an undirected edge list.

    Args:
      num_nodes_cap: static node capacity N.
      edges: [E, 2] int32 endpoints (arbitrary values where invalid).
      edge_valid: [E] bool.
      node_valid: [N] bool -- labels of invalid nodes stay = own index.

    Returns labels [N] int32: connected-component representative (min node
    index in component).  Converges in O(log N) rounds; loop exits early
    on a fixpoint.
    """
    N = num_nodes_cap
    E = edges.shape[0]
    rounds = max_rounds or (int(np.ceil(np.log2(max(N, 2)))) + 2)
    u = jnp.where(edge_valid, edges[:, 0], 0)
    v = jnp.where(edge_valid, edges[:, 1], 0)

    def body(state):
        labels, _, it = state
        lu, lv = labels[u], labels[v]
        m = jnp.minimum(lu, lv)
        m = jnp.where(edge_valid, m, jnp.int32(N))
        new = labels
        new = new.at[u].min(jnp.where(edge_valid, m, labels[u]))
        new = new.at[v].min(jnp.where(edge_valid, m, labels[v]))
        # pointer jumping: label <- label[label]  (halves tree height)
        new = new[new]
        new = new[new]
        changed = jnp.any(new != labels)
        return new, changed, it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < rounds)

    init_labels = jnp.arange(N, dtype=jnp.int32)
    labels, _, _ = jax.lax.while_loop(
        cond, body, (init_labels, jnp.ones((), bool), jnp.zeros((), jnp.int32)))
    labels = jnp.where(node_valid, labels, jnp.int32(N))
    return labels
