"""core: the paper (grids, grid tree, FastMerging, GriT-DBSCAN, distribution)."""
