"""Compatibility shim: the distributed plane moved to ``repro.dist``.

What used to live here as one file is now a package with one module per
concern -- host slab sharding (``repro.dist.sharding``), device halo
compaction (``repro.dist.halo``), cross-shard label reconciliation
(``repro.dist.reconcile``), the shard_map SPMD step + caps
(``repro.dist.step``) and the host-facing entry points
(``repro.dist.api``).  Import from ``repro.dist`` in new code; this
module keeps the historical names importable (same pattern as
``repro.index.insert``).
"""

import warnings

from repro.dist import (ClusterCaps, DistributedFitResult,  # noqa: F401
                        distributed_dbscan, distributed_fit,
                        make_cluster_step, shard_points_by_slab)
from repro.dist.halo import halo_buffer as _halo_buffer  # noqa: F401
from repro.dist.step import (_STEP_CACHE,  # noqa: F401
                             cached_cluster_step as _cached_cluster_step)

warnings.warn(
    "repro.core.distributed is deprecated; import ClusterCaps, "
    "distributed_fit, distributed_dbscan, ... from repro.dist (the "
    "distributed serving subsystem) instead.",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = [
    "ClusterCaps", "DistributedFitResult", "distributed_dbscan",
    "distributed_fit", "make_cluster_step", "shard_points_by_slab",
]
