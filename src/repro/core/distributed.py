"""Distributed GriT-DBSCAN: spatial sharding + halo exchange + global merge.

Scale-out story for the paper's "very large databases" claim: points are
slab-sharded along the leading grid coordinate (grid side eps/sqrt(d), so
slab boundaries align with grid lines), every shard runs the exact local
GriT pipeline (grids -> grid tree -> FastMerging -> components), and
cross-shard cluster identity is resolved by label reconciliation over
*shared* halo points:

  1. each shard ppermutes the points within 2*eps of its slab boundary to
     the adjacent shard (ghost points); 2*eps guarantees the ghost's own
     eps-neighborhood is complete, so its core status and merges computed
     remotely are exact;
  2. the local run clusters [own + ghosts] together (ghosts are ordinary
     points to the grid tree / FastMerging);
  3. the ghosts' locally-assigned labels are ppermuted *back*: a shared
     core point seen by both shards yields an edge
     (home_label, remote_label) between the two label spaces;
  4. edges are all-gathered and a replicated pointer-jumping pass maps
     every (shard, local label) to its global component.

Exactness follows from the paper's Theorem 4 plus the halo width
argument: any merge edge between grids in adjacent slabs is witnessed by
a core point within eps of the boundary, which is a shared point.

The SPMD program (``make_cluster_step``) is a single ``shard_map`` over
the flattened device axis -- the same artifact the multi-pod dry-run
lowers on the production mesh.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .device_dbscan import (device_dbscan, GritCaps, OverflowReport,
                            PAD_COORD)
from .labels import label_propagation


@dataclasses.dataclass(frozen=True)
class ClusterCaps:
    grit: GritCaps = GritCaps()
    halo_cap: int = 512          # max points shipped per boundary side
    edge_cap: int = 1024         # max reconciliation edges per shard


def shard_points_by_slab(points: np.ndarray, eps: float, n_shards: int,
                         pad_to: Optional[int] = None):
    """Host-side spatial pre-sharding.

    Sorts by the dim-0 grid coordinate and cuts into ``n_shards`` slabs at
    grid-line boundaries (equal point counts up to grid granularity).
    Returns (padded [n_shards, cap, d] f32, valid [n_shards, cap] bool,
    perm with original indices [n_shards, cap]).
    """
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    side = eps / np.sqrt(d)
    key = np.floor((pts[:, 0] - pts[:, 0].min()) / side).astype(np.int64)
    order = np.argsort(key, kind="stable")
    cuts = [0]
    for s in range(1, n_shards):
        tgt = s * n // n_shards
        # move the cut forward to the next grid-line boundary
        while tgt < n and tgt > cuts[-1] and \
                key[order[tgt]] == key[order[tgt - 1]]:
            tgt += 1
        cuts.append(min(tgt, n))
    cuts.append(n)
    counts = [cuts[i + 1] - cuts[i] for i in range(n_shards)]
    need = int(max(max(counts), 1))
    if pad_to is not None and pad_to < need:
        raise ValueError(
            f"pad_to={pad_to} is smaller than the largest slab ({need} "
            f"points); slab cuts land on grid lines, so per-shard counts "
            f"cannot be reduced below that")
    cap = pad_to or need
    out = np.full((n_shards, cap, d), PAD_COORD, np.float32)
    valid = np.zeros((n_shards, cap), bool)
    perm = np.full((n_shards, cap), -1, np.int64)
    for i in range(n_shards):
        idx = order[cuts[i]:cuts[i + 1]]
        out[i, :len(idx)] = pts[idx]
        valid[i, :len(idx)] = True
        perm[i, :len(idx)] = idx
    return out, valid, perm


def _halo_buffer(pts, valid, eps, side: str, cap: int):
    """Points within 2*eps of the slab's min/max dim-0 edge (fixed cap)."""
    x0 = pts[:, 0]
    lo = jnp.min(jnp.where(valid, x0, jnp.inf))
    hi = jnp.max(jnp.where(valid, x0, -jnp.inf))
    near = valid & ((x0 <= lo + 2 * eps) if side == "lo"
                    else (x0 >= hi - 2 * eps))
    # compact the selected points into a fixed-size buffer front
    n = pts.shape[0]
    order = jnp.argsort(~near, stable=True)
    if n < cap:
        order = jnp.concatenate(
            [order, jnp.zeros((cap - n,), order.dtype)])
        sel = jnp.concatenate([near[order[:n]],
                               jnp.zeros((cap - n,), bool)])
    else:
        order = order[:cap]
        sel = near[order]
    buf = jnp.where(sel[:, None], pts[order], PAD_COORD)
    idx = jnp.where(sel, order, -1)
    overflow = jnp.sum(near) > cap
    return buf.astype(jnp.float32), idx.astype(jnp.int32), overflow


def make_cluster_step(mesh: Mesh, eps, min_pts: int, caps: ClusterCaps,
                      n_points_shard: int, d: int):
    """Build the SPMD cluster step for ``mesh`` (all axes flattened).

    Returns a jit-able fn: (points [N, d] f32, valid [N] bool) ->
    (labels [N] int32 global cluster ids (-1 noise),
     overflow ``OverflowReport`` with per-cap flags OR-ed over shards),
    with N = n_shards * n_points_shard sharded over all mesh axes.
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    L = caps.grit.grid_cap          # per-shard label space
    H = caps.halo_cap

    def local_step(pts, valid):
        # shard_map hands us the local block: [n_points_shard, d]
        me = jax.lax.axis_index(axes)
        # --- 1. halo exchange (both directions, ring) ---
        lo_buf, lo_idx, ov1 = _halo_buffer(pts, valid, eps, "lo", H)
        hi_buf, hi_idx, ov2 = _halo_buffer(pts, valid, eps, "hi", H)
        right = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        left = [((i + 1) % n_shards, i) for i in range(n_shards)]
        # my hi-edge points go to the right neighbor; lo-edge to the left
        ghosts_from_left = jax.lax.ppermute(hi_buf, axes, right)
        ghosts_from_right = jax.lax.ppermute(lo_buf, axes, left)
        # ring wrap: shard 0 has no left neighbor in a slab decomposition
        first = me == 0
        last = me == n_shards - 1
        ghosts_from_left = jnp.where(first, PAD_COORD, ghosts_from_left)
        ghosts_from_right = jnp.where(last, PAD_COORD, ghosts_from_right)

        # --- 2. local exact GriT-DBSCAN on own + ghosts ---
        all_pts = jnp.concatenate([pts, ghosts_from_left, ghosts_from_right])
        all_valid = jnp.concatenate([
            valid,
            jnp.any(ghosts_from_left < PAD_COORD / 2, axis=1),
            jnp.any(ghosts_from_right < PAD_COORD / 2, axis=1)])
        res = device_dbscan(all_pts.astype(jnp.float32), eps, min_pts,
                            caps.grit, point_valid=all_valid)
        n_own = pts.shape[0]
        own_labels = res.labels[:n_own]
        own_core = res.core[:n_own]
        ghost_l_labels = res.labels[n_own:n_own + H]
        ghost_l_core = res.core[n_own:n_own + H]
        ghost_r_labels = res.labels[n_own + H:]
        ghost_r_core = res.core[n_own + H:]

        # --- 3. reconcile: my labels of the ghosts go back to their home
        back_to_left = jnp.where(ghost_l_core, ghost_l_labels, -1)
        back_to_right = jnp.where(ghost_r_core, ghost_r_labels, -1)
        # label the ghosts got at the neighbor, aligned with my halo idx
        hi_remote = jax.lax.ppermute(back_to_left, axes, left)
        lo_remote = jax.lax.ppermute(back_to_right, axes, right)

        def edges_for(local_idx, remote_labels, remote_shard):
            ok = (local_idx >= 0) & (remote_labels >= 0)
            safe = jnp.maximum(local_idx, 0)
            mine = own_labels[safe]
            ok = ok & (mine >= 0) & own_core[safe]
            a = me * L + mine
            b = remote_shard * L + remote_labels
            return jnp.where(ok[:, None],
                             jnp.stack([a, b], axis=1), -1), ok

        e_hi, ok_hi = edges_for(hi_idx, hi_remote,
                                jnp.minimum(me + 1, n_shards - 1))
        e_lo, ok_lo = edges_for(lo_idx, lo_remote, jnp.maximum(me - 1, 0))
        ok_hi = ok_hi & ~last
        ok_lo = ok_lo & ~first
        edges = jnp.concatenate([e_hi, e_lo])              # [2H, 2]
        edge_valid = jnp.concatenate([ok_hi, ok_lo])

        # --- 4. global components over (shard, label) space ---
        all_edges = jax.lax.all_gather(edges, axes).reshape(-1, 2)
        all_ok = jax.lax.all_gather(edge_valid, axes).reshape(-1)
        node_valid = jnp.ones((n_shards * L,), bool)
        gmap = label_propagation(n_shards * L,
                                 jnp.maximum(all_edges, 0).astype(jnp.int32),
                                 all_ok, node_valid)
        glab = jnp.where(own_labels >= 0,
                         gmap[me * L + jnp.maximum(own_labels, 0)],
                         -1)
        report = res.report
        report.halo = report.halo | ov1 | ov2
        return glab, report.as_vector()[None, :]

    from jax.experimental.shard_map import shard_map
    spec = P(axes)
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(P(axes, None), spec),
                   out_specs=(spec, P(axes, None)),
                   check_rep=False)

    def cluster_step(points, valid):
        labels, flags = fn(points, valid)           # flags [n_shards, F]
        return labels, OverflowReport.from_vector(jnp.any(flags, axis=0))

    return cluster_step


# jitted SPMD steps keyed by everything that shapes the program; reused
# across distributed_dbscan calls so the adaptive driver's quantized cap
# retries (and repeated runs on similarly-sized data) don't recompile
_STEP_CACHE: dict = {}
_STEP_CACHE_MAX = 32


def _cached_cluster_step(mesh: Mesh, eps: float, min_pts: int,
                         caps: ClusterCaps, n_points_shard: int, d: int):
    key = (mesh, float(eps), int(min_pts), caps, int(n_points_shard),
           int(d))
    if key not in _STEP_CACHE:
        if len(_STEP_CACHE) >= _STEP_CACHE_MAX:
            _STEP_CACHE.clear()
        step = make_cluster_step(mesh, eps, min_pts, caps,
                                 n_points_shard, d)
        _STEP_CACHE[key] = jax.jit(step)
    return _STEP_CACHE[key]


def distributed_dbscan(points: np.ndarray, eps: float, min_pts: int,
                       mesh: Mesh, caps: Optional[ClusterCaps] = None,
                       pad_to: Optional[int] = None
                       ) -> Tuple[np.ndarray, OverflowReport]:
    """Host-facing wrapper: pre-shard, run the SPMD step, unpermute.

    Returns (labels in original point order [n], ``OverflowReport``).
    The report is truthy iff any static cap overflowed on any shard
    (``bool(report)`` keeps the legacy overflow-flag contract).
    """
    caps = caps or ClusterCaps()
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    pts_sh, valid_sh, perm = shard_points_by_slab(points, eps, n_shards,
                                                  pad_to=pad_to)
    cap = pts_sh.shape[1]
    step = _cached_cluster_step(mesh, eps, min_pts, caps, cap,
                                points.shape[1])
    flat_pts = jnp.asarray(pts_sh.reshape(n_shards * cap, -1))
    flat_valid = jnp.asarray(valid_sh.reshape(-1))
    sharding = NamedSharding(mesh, P(axes))
    flat_pts = jax.device_put(flat_pts, NamedSharding(mesh, P(axes, None)))
    flat_valid = jax.device_put(flat_valid, sharding)
    labels, report = step(flat_pts, flat_valid)
    labels = np.asarray(labels).reshape(n_shards, cap)
    out = np.full(len(points), -1, np.int64)
    for i in range(n_shards):
        m = perm[i] >= 0
        out[perm[i][m]] = labels[i][m]
    return out, jax.device_get(report)
