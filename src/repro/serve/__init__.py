"""serve: online clustering traffic against a fitted ``GritIndex``.

    from repro.serve import ClusterServer
    srv = ClusterServer(index, slots=8)
    rid = srv.submit(query_points)          # ragged request
    done = srv.step()                       # one batched predict step
    print(srv.summary())

See ``repro.serve.driver`` for the continuous-batching loop and
``python -m repro.serve.driver --smoke`` for a miniature server run.
"""

from .driver import ClusterRequest, ClusterServer

__all__ = ["ClusterRequest", "ClusterServer"]
