"""Serving driver: continuous batching for clustering traffic.

The LM serving loop (``repro.launch.serve``) left-pads ragged prompts
into batch slots, runs one jitted program per step, and swaps finished
sequences out; this driver applies the same discipline to point-query
traffic against a fitted :class:`~repro.index.GritIndex`:

* requests arrive as *ragged* [m_i, d] query batches -- or as mutation
  requests (:meth:`ClusterServer.submit_insert` /
  :meth:`ClusterServer.submit_delete`) -- and are admitted into
  ``slots`` request slots of ``query_cap`` queries each -- the step's
  admission budget (slot occupancy is reported per step);
* each step applies the admitted mutations in submission order, then
  concatenates the admitted query requests and runs one batched
  :meth:`GritIndex.predict` over them (predicts in a step observe the
  step's mutations), then retires every slot (requests finish in one
  step, so continuous batching reduces to refilling all slots from the
  queue).  The *jit-facing* fixed shapes live inside the index
  (`PredictCaps` slot packing), not here.  Delete requests carry
  rejected-id telemetry through the step log and summary: unknown /
  already-deleted ids are normal serving traffic (TTL expiry racing
  explicit erasure, replays), rejected per id by the index, and must
  never poison the co-batched requests;
* caps grow, never shrink: an oversized request bumps the admission
  shape ``query_cap`` to the next power of two (the adaptive driver's
  quantization, shared via ``_pow2_at_least``), and the kernel path's
  :class:`PredictCaps` grow the same way inside the index.  Every
  growth event is recorded; the ``predict_caps`` events are the ones
  that correspond to re-jits (the jit key is the PredictCaps shape),
  while ``query_cap`` events record when traffic outgrew the admission
  tensor;
* per-request latency (submit -> labels) and per-step occupancy are
  recorded for the summary (p50/p95 latency, throughput);
* the driver is index-agnostic: a :class:`~repro.index.ShardedGritIndex`
  drops in as the backend unchanged -- its ``predict`` buckets the
  step's batch by owning slab internally (one batched per-shard call)
  and reports the routing counters (queries per slab, multi-routed
  cut-band queries) through the same per-step ``stats`` channel, so the
  step log shows slab occupancy next to slot occupancy.  Per-step slab
  load (owned routed queries + mutated rows per shard) is promoted to
  ``repro.obs`` gauges -- ``serve.slab.load.<k>`` and the max/mean
  ``serve.slab.imbalance`` -- on both the per-server registry and the
  process default, so the rebalance trigger is visible in
  ``repro.obs.view`` and trace exports;
* ``rebalance=`` attaches a :class:`~repro.dist.rebalance.Rebalancer`:
  the slab-load gauges feed its EWMA and *between* steps it applies at
  most one bounded topology op (split the hottest slab / merge the
  coldest adjacent pair) to the sharded backend, recorded in
  ``topology_events``;
* ``replicas=R`` clones R read-only :class:`~repro.index.ReplicaIndex`
  off the primary (mutation-log replay plane) and fans each step's
  predict batch across them round-robin -- mutations keep hitting the
  primary, replicas catch up from its log before answering, so the
  labels stay bit-identical to primary serving.

``python -m repro.serve.driver --smoke`` runs a miniature server on a
catalogue scenario: fit, then serve a stream of ragged query batches;
``--sharded N`` serves from an N-slab ``ShardedGritIndex`` instead of
the single-host index (the distributed-serving backend);
``--rebalance`` / ``--replicas R`` attach the topology and replica
planes above.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro import obs
from repro.engine.adaptive import _pow2_at_least
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class ClusterRequest:
    """One in-flight request: a ragged query batch (``kind="predict"``),
    a micro-batch insert (``kind="insert"``) or a delete-by-arrival-ids
    (``kind="delete"``).  Mutations carry their stats dict back on
    ``result``; predicts carry ``labels``."""

    rid: int
    points: np.ndarray                    # [m, d] ragged (empty: delete)
    t_submit: float
    kind: str = "predict"
    ids: Optional[np.ndarray] = None      # delete requests: arrival ids
    labels: Optional[np.ndarray] = None   # [m] int64 once served
    result: Optional[Dict[str, Any]] = None   # mutation stats once applied
    t_admit: float = 0.0                  # popped from the queue
    t_done: float = 0.0

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


class ClusterServer:
    """Continuous-batching predict server over a fitted index."""

    def __init__(self, index, *, slots: int = 4, query_cap: int = 64,
                 mode: str = "auto", device_state: bool = False,
                 rebalance=None, replicas: int = 0):
        self.index = index
        self.slots = int(slots)
        self.query_cap = _pow2_at_least(query_cap, lo=8)
        self.mode = mode
        self.pending: Deque[ClusterRequest] = deque()
        self.done: List[ClusterRequest] = []
        self.growth_events: List[Dict[str, Any]] = []
        self.step_log: List[Dict[str, Any]] = []
        self.rejected_ids: List[np.ndarray] = []   # delete telemetry
        # topology plane: load-triggered split/merge between steps
        self.rebalancer = None
        self.topology_events: List[Dict[str, Any]] = []
        if rebalance is not None and rebalance is not False:
            from repro.dist.rebalance import RebalancePolicy, Rebalancer
            if isinstance(rebalance, Rebalancer):
                self.rebalancer = rebalance
            elif isinstance(rebalance, RebalancePolicy):
                self.rebalancer = Rebalancer(rebalance)
            else:
                self.rebalancer = Rebalancer()
            if not hasattr(index, "split_shard"):
                raise ValueError(
                    "rebalance= needs a backend with topology ops; "
                    f"{type(index).__name__} has no split_shard()")
        # replica plane: read-only clones fed by the primary's log;
        # each step's predict batch goes to one replica round-robin
        self.replicas: List[Any] = []
        self._rr = 0
        if replicas:
            from repro.index.replica import make_replicas
            self.replicas = make_replicas(index, int(replicas))
        # per-server books (a process may run many servers; the shared
        # default registry keeps only cross-cutting counters) -- the
        # summary() aggregates are a view over these instruments
        self.metrics = MetricsRegistry()
        self._next_rid = 0
        # double-buffered admission: the batch packed while the previous
        # step's kernels were executing (device path), served next step
        self._staged: Optional[List[ClusterRequest]] = None
        if device_state:
            ensure = getattr(index, "ensure_device_state", None)
            if ensure is None:
                raise ValueError(
                    "device_state=True needs a backend with device-"
                    f"resident serving state; {type(index).__name__} "
                    "has no ensure_device_state()")
            ensure()

    # ------------------------------------------------------------------

    def submit(self, points) -> int:
        """Enqueue one ragged query batch; returns its request id.

        Validation happens *here*, at admission: a malformed request is
        rejected before it can join a batch, so it can never poison the
        co-batched requests of a serving step.
        """
        pts = np.asarray(points, np.float64)
        if pts.ndim != 2 or pts.shape[1] != self.index.d:
            raise ValueError(
                f"request must be [m, {self.index.d}], got {pts.shape}")
        if not np.isfinite(pts).all():
            raise ValueError("request contains non-finite coordinates")
        req = ClusterRequest(rid=self._next_rid, points=pts,
                             t_submit=time.perf_counter())
        self._next_rid += 1
        self.pending.append(req)
        return req.rid

    def submit_insert(self, points) -> int:
        """Enqueue a micro-batch insert; validated at admission like
        predicts, co-batched into a serving step with them."""
        pts = np.asarray(points, np.float64)
        if pts.ndim != 2 or pts.shape[1] != self.index.d:
            raise ValueError(
                f"request must be [m, {self.index.d}], got {pts.shape}")
        if not np.isfinite(pts).all():
            raise ValueError("request contains non-finite coordinates")
        req = ClusterRequest(rid=self._next_rid, points=pts,
                             kind="insert", t_submit=time.perf_counter())
        self._next_rid += 1
        self.pending.append(req)
        return req.rid

    def submit_delete(self, arrival_ids) -> int:
        """Enqueue a delete-by-arrival-ids request.

        Unknown / already-deleted ids are not an admission error -- the
        index rejects them individually and the step log carries the
        rejected-id telemetry (TTL races and replays are normal
        traffic, and one bad id must not poison a co-batched step).
        """
        ids = np.asarray(arrival_ids, np.int64).ravel()
        req = ClusterRequest(rid=self._next_rid,
                             points=np.zeros((0, self.index.d)),
                             kind="delete", ids=ids,
                             t_submit=time.perf_counter())
        self._next_rid += 1
        self.pending.append(req)
        return req.rid

    def _admit(self) -> List[ClusterRequest]:
        """Fill up to ``slots`` slots from the queue (admission-time
        ``query_cap`` growth included) -- the host-packing half of a
        step, so it can run while the previous step's kernels execute."""
        active: List[ClusterRequest] = []
        now = time.perf_counter()
        while self.pending and len(active) < self.slots:
            req = self.pending.popleft()
            req.t_admit = now
            active.append(req)
        need = max((len(r.points) for r in active
                    if r.kind == "predict"), default=0)
        if need > self.query_cap:
            grown = _pow2_at_least(need, lo=8)
            self.growth_events.append(
                {"step": len(self.step_log), "cap": "query_cap",
                 "was": self.query_cap, "now": grown})
            self.query_cap = grown
        return active

    def step(self) -> List[ClusterRequest]:
        """Serve one batch: fill up to ``slots`` slots, apply the
        admitted mutations (in submission order), then one predict call
        over the co-batched query requests -- predicts in a step
        observe that step's mutations.

        The admission is double-buffered: the predict is *dispatched*
        (``predict_async``), the *next* step's batch is admitted while
        the kernels run, and only then does the step block on the
        labels -- on the device path the host packing of step k+1
        overlaps the jitted program of step k.  The step log splits
        ``kernel_s`` (device kernel + resolve time) from ``pack_s``
        (host slot packing) next to the total ``seconds``.

        Returns the requests finished this step (empty when idle).
        """
        active = self._staged if self._staged is not None \
            else self._admit()
        self._staged = None
        if not active:
            return []
        predicts = [r for r in active if r.kind == "predict"]

        reg = self.metrics
        t0 = time.perf_counter()
        with obs.span("serve.step", requests=len(active)):
            inserted = deleted = rejected = 0
            kernel_s = pack_s = 0.0
            with obs.span("serve.step.mutate"):
                for r in active:
                    if r.kind == "insert":
                        r.result = self.index.insert(r.points)
                        inserted += r.result["inserted"]
                    elif r.kind == "delete":
                        r.result = self.index.delete(r.ids)
                        deleted += r.result["deleted"]
                        if r.result["rejected"]:
                            rejected += r.result["rejected"]
                            self.rejected_ids.append(
                                r.result["rejected_ids"])
                    if r.result is not None:
                        kernel_s += r.result.get("t_kernel", 0.0)
                        pack_s += r.result.get("t_pack", 0.0)
            pstats: Dict[str, Any] = {}
            flat = (np.concatenate([r.points for r in predicts])
                    if predicts else np.zeros((0, self.index.d)))
            # read fan-out: mutations hit the primary above; the step's
            # predict batch goes to one replica round-robin (it catches
            # up from the log first, so answers are bit-identical)
            reader = self.index
            if self.replicas and len(flat):
                reader = self.replicas[self._rr % len(self.replicas)]
                self._rr += 1
            dispatch = getattr(reader, "predict_async", None)
            # queue wait: admission (queue pop) -> this batch's dispatch
            t_disp = time.perf_counter()
            qw_ms = [(t_disp - r.t_admit) * 1e3 for r in active]
            for w in qw_ms:
                reg.histogram("serve.queue_wait_ms").observe(w)
            with obs.span("serve.step.dispatch", queries=len(flat)):
                if len(flat) == 0:
                    resolve = lambda: np.empty(0, np.int64)
                elif dispatch is not None:
                    resolve = dispatch(flat, mode=self.mode, stats=pstats)
                else:
                    out = reader.predict(flat, mode=self.mode,
                                         stats=pstats)
                    resolve = lambda: out
            # admit the next step's batch while the dispatched work runs
            with obs.span("serve.step.admit_next"):
                staged = self._admit()
                self._staged = staged if staged else None
            with obs.span("serve.step.resolve"):
                flat_labels = resolve()
            kernel_s += pstats.get("t_kernel", 0.0)
            pack_s += pstats.get("t_pack", 0.0)
            # slab-load gauges: owned routed queries + mutated rows per
            # shard -- the rebalance trigger, exported on both the
            # per-server registry and the process default registry so
            # it shows in repro.obs.view and trace exports
            num_shards = int(getattr(self.index, "num_shards", 0))
            if num_shards:
                slab_load = np.zeros(num_shards, np.float64)
                owned = pstats.get("owned_per_shard")
                if owned is not None:
                    slab_load[:len(owned)] += owned
                for r in active:
                    if r.result is not None:
                        for s in r.result.get("per_shard", ()):
                            if s["shard"] < num_shards:
                                slab_load[s["shard"]] += \
                                    s["own"] + s["ghost"]
                mean = float(slab_load.mean())
                imb = float(slab_load.max()) / mean if mean > 0 else 1.0
                for k in range(num_shards):
                    v = float(slab_load[k])
                    reg.gauge(f"serve.slab.load.{k}").set(v)
                    obs.gauge(f"serve.slab.load.{k}").set(v)
                reg.gauge("serve.slab.imbalance").set(imb)
                obs.gauge("serve.slab.imbalance").set(imb)
                if self.rebalancer is not None:
                    self.rebalancer.observe(slab_load)
            t_step = time.perf_counter() - t0
            if pstats.get("caps_grew"):
                self.growth_events.append(
                    {"step": len(self.step_log), "cap": "predict_caps",
                     "now": pstats.get("caps")})

            off = 0
            now = time.perf_counter()
            for r in active:
                if r.kind == "predict":
                    m = len(r.points)
                    r.labels = flat_labels[off:off + m]
                    off += m
                r.t_done = now
                self.done.append(r)
                reg.histogram("serve.latency_ms").observe(r.latency_ms)
            slot_fill = len(flat) / (self.slots * self.query_cap)
            reg.counter("serve.steps").inc()
            reg.counter("serve.requests").inc(len(active))
            reg.counter("serve.queries").inc(len(flat))
            reg.counter("serve.inserted").inc(inserted)
            reg.counter("serve.deleted").inc(deleted)
            reg.counter("serve.rejected").inc(rejected)
            reg.histogram("serve.slot_fill").observe(slot_fill)
            reg.histogram("serve.step_seconds").observe(t_step)
            reg.histogram("serve.kernel_seconds").observe(kernel_s)
            reg.histogram("serve.pack_seconds").observe(pack_s)
            self.step_log.append(
                {"requests": len(active), "queries": len(flat),
                 "slot_fill": slot_fill,
                 "inserted": inserted, "deleted": deleted,
                 "rejected": rejected,
                 "queue_wait_ms": float(np.mean(qw_ms)),
                 "seconds": t_step, "kernel_s": kernel_s,
                 "pack_s": pack_s, "predict": pstats})
        # topology op *between* steps: bounded by the policy's period,
        # so reconcile cost amortizes against every subsequent step
        if self.rebalancer is not None:
            op_st = self.rebalancer.maybe_rebalance(self.index)
            if op_st is not None:
                self.topology_events.append(
                    {"step": len(self.step_log), **op_st})
                reg.counter("serve.topology_ops").inc()
        return active

    def run(self) -> List[ClusterRequest]:
        """Drain the queue (staged batch included); returns every
        request served."""
        out: List[ClusterRequest] = []
        while self.pending or self._staged is not None:
            out.extend(self.step())
        return out

    # ------------------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        """Aggregate serving stats: a thin view over the per-server
        metrics registry (``self.metrics``) -- every number here is
        read back from the instruments ``step()`` feeds, so the same
        figures flow to trace exports (``repro.obs``) unchanged.  The
        registry's exact-percentile histograms reproduce the
        ``np.percentile`` values this summary historically computed
        from the request list."""
        reg = self.metrics
        lat = reg.histogram("serve.latency_ms")
        qw = reg.histogram("serve.queue_wait_ms")
        served_s = reg.histogram("serve.step_seconds").total
        queries = reg.counter("serve.queries").value
        rejected = (np.concatenate(self.rejected_ids)
                    if self.rejected_ids else np.empty(0, np.int64))
        return {
            "requests": len(self.done),
            "queries": queries,
            "inserted": reg.counter("serve.inserted").value,
            "deleted": reg.counter("serve.deleted").value,
            "rejected": int(len(rejected)),
            "rejected_ids": rejected,
            "steps": len(self.step_log),
            "latency_ms_p50": lat.percentile(50),
            "latency_ms_p95": lat.percentile(95),
            "latency_ms_p99": lat.percentile(99),
            "latency_ms_mean": lat.mean,
            "queue_wait_ms_p50": qw.percentile(50),
            "queue_wait_ms_p95": qw.percentile(95),
            "queue_wait_ms_mean": qw.mean,
            "queries_per_s": queries / served_s if served_s else 0.0,
            "mean_slot_fill": reg.histogram("serve.slot_fill").mean,
            "query_cap": self.query_cap,
            "growth_events": list(self.growth_events),
            "topology_events": list(self.topology_events),
            "replicas": len(self.replicas),
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="blobs-2d")
    ap.add_argument("--engine", default="grit")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny request stream (CI-scale)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--num-requests", type=int, default=24)
    ap.add_argument("--max-queries", type=int, default=96)
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "host", "kernel", "device"))
    ap.add_argument("--device", action="store_true",
                    help="attach device-resident serving state to the "
                         "index (guard-band kernel hot path; outputs "
                         "stay bit-identical to host serving)")
    ap.add_argument("--sharded", type=int, default=0, metavar="N",
                    help="serve from an N-slab ShardedGritIndex "
                         "(slab-routed predict) instead of the "
                         "single-host index")
    ap.add_argument("--rebalance", action="store_true",
                    help="attach a load-triggered Rebalancer to the "
                         "sharded backend (split hottest / merge "
                         "coldest between steps; needs --sharded)")
    ap.add_argument("--rebalance-period", type=int, default=8,
                    help="min steps between topology ops")
    ap.add_argument("--replicas", type=int, default=0, metavar="R",
                    help="fan predict traffic across R read-only "
                         "replicas fed by the primary's mutation log")
    ap.add_argument("--mutate", action="store_true",
                    help="mix insert and delete requests into the "
                         "stream (~70/20/10 predict/insert/delete, "
                         "incl. one bogus delete id for the rejected "
                         "telemetry)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.data.scenarios import get_scenario
    from repro.engine import cluster

    sc = get_scenario(args.scenario)
    pts = sc.points(seed=args.seed)
    print(f"fitting {args.scenario} (n={len(pts)}, eps={sc.eps}, "
          f"min_pts={sc.min_pts}) with engine={args.engine}...")
    t0 = time.perf_counter()
    if args.sharded:
        from repro.index import fit_sharded
        index = fit_sharded(pts, sc.eps, sc.min_pts,
                            n_shards=args.sharded, engine=args.engine)
        print(f"  fit {time.perf_counter() - t0:.2f}s: "
              f"{index.num_shards} slab shards "
              f"(cuts at {np.round(index.cuts, 1).tolist()}), "
              f"{index.num_grids} grids total")
    else:
        res = cluster(pts, sc.eps, sc.min_pts, engine=args.engine,
                      return_index=True)
        index = res.index
        print(f"  fit {time.perf_counter() - t0:.2f}s: "
              f"{res.n_clusters} clusters, {index.num_grids} grids")

    rng = np.random.default_rng(args.seed)
    n_req = 6 if args.smoke else args.num_requests
    rebalance = None
    if args.rebalance:
        from repro.dist.rebalance import RebalancePolicy
        rebalance = RebalancePolicy(period=args.rebalance_period)
    srv = ClusterServer(index, slots=args.slots, mode=args.mode,
                        device_state=args.device, rebalance=rebalance,
                        replicas=args.replicas)
    deletable = list(range(len(pts)))
    for i in range(n_req):
        kind = (rng.choice(["predict", "insert", "delete"],
                           p=[0.7, 0.2, 0.1]) if args.mutate
                else "predict")
        m = int(rng.integers(4, args.max_queries + 1))
        near = pts[rng.integers(0, len(pts), m)] + rng.normal(
            scale=sc.eps * 0.25, size=(m, sc.d))
        if kind == "insert":
            srv.submit_insert(near[:max(m // 4, 1)])
        elif kind == "delete" and deletable:
            k = min(len(deletable), int(rng.integers(1, 9)))
            pick = rng.choice(len(deletable), k, replace=False)
            ids = [deletable[j] for j in pick]
            for j in sorted(pick)[::-1]:
                deletable.pop(j)
            # one bogus id exercises the rejected-id telemetry
            srv.submit_delete(np.asarray(ids + [10 ** 9]))
        else:
            srv.submit(near)
    srv.run()
    s = srv.summary()
    print(f"served {s['requests']} requests / {s['queries']} queries in "
          f"{s['steps']} steps ({s['queries_per_s']:.0f} q/s)")
    if args.mutate:
        print(f"  mutations: {s['inserted']} inserted, "
              f"{s['deleted']} deleted, {s['rejected']} delete ids "
              f"rejected {s['rejected_ids'][:4].tolist()}...")
    print(f"  latency p50 {s['latency_ms_p50']:.2f}ms  "
          f"p95 {s['latency_ms_p95']:.2f}ms  "
          f"p99 {s['latency_ms_p99']:.2f}ms  "
          f"queue wait p50 {s['queue_wait_ms_p50']:.2f}ms  "
          f"slot fill {s['mean_slot_fill']:.2f}  "
          f"cap growth events: {len(s['growth_events'])}")
    noise = sum(int((r.labels < 0).sum()) for r in srv.done
                if r.labels is not None)
    print(f"  noise rate {noise / max(s['queries'], 1):.2f}")
    if args.sharded:
        routed = sum(st["predict"].get("multi_routed", 0)
                     for st in srv.step_log)
        imb = srv.metrics.gauge("serve.slab.imbalance").value
        print(f"  slab routing: {index.num_shards} shards, "
              f"imbalance (max/mean) {imb:.2f}, "
              f"{routed} cut-band queries consulted both neighbors")
    if srv.topology_events:
        ops = [(e["op"], e["shard"]) for e in srv.topology_events]
        print(f"  topology ops: {ops} -> {index.num_shards} shards, "
              f"cut history {len(index.cut_history)} entries")
    if srv.replicas:
        print(f"  replicas: {len(srv.replicas)} read-only, lag "
              f"{[r.lag for r in srv.replicas]} ops behind primary")


if __name__ == "__main__":
    main()
