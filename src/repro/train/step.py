"""Training step: loss + grads + optimizer, with microbatch accumulation.

``make_train_step`` builds the jit-able function the launcher lowers for
the dry-run.  Structure:

  * grads in f32 via ``jax.value_and_grad`` over the chunked-CE loss,
  * optional microbatch gradient accumulation (``lax.scan`` over
    microbatches -- needed for the big configs' activation memory),
  * global-norm clipping,
  * LR schedule + optimizer update,
  * optional error-feedback int8 gradient compression hook (see
    compress.py) applied before the (GSPMD-inserted) gradient reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.models.config import LMConfig
from .optim import Optimizer, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient accumulation factor
    compress_grads: bool = False   # error-feedback int8 (see compress.py)


def make_train_step(cfg: LMConfig, tcfg: TrainCfg, opt: Optimizer,
                    lr_fn: Callable):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step"}  (plus "ef" when compressing).
    batch = {"tokens": [B, S+1], ...modality extras}.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)

    def accumulate(params, batch):
        mb = tcfg.microbatches
        if mb == 1:
            (loss, metrics), grads = grads_of(params, batch)
            return loss, metrics, grads
        split = lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        mbatch = jax.tree.map(split, batch)

        def step(carry, b):
            acc, tot = carry
            (loss, metrics), grads = grads_of(params, b)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, tot + loss), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, tot), metrics = jax.lax.scan(
            step, (zeros, jnp.zeros(())), mbatch)
        grads = jax.tree.map(lambda g: g / mb, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return tot / mb, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        loss, metrics, grads = accumulate(params, batch)
        if tcfg.compress_grads:
            from .compress import ef_compress_tree
            grads, ef = ef_compress_tree(grads, state["ef"])
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if tcfg.compress_grads:
            new_state["ef"] = ef
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return new_state, metrics

    return train_step


def init_state(cfg: LMConfig, tcfg: TrainCfg, opt: Optimizer, params):
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.compress_grads:
        from .compress import ef_init
        state["ef"] = ef_init(params)
    return state
