"""Error-feedback int8 gradient compression.

Distributed-optimization trick for bandwidth-bound gradient reduction:
each step, the f32 gradient plus the carried error residual is quantized
to int8 with a per-leaf scale; the quantization error is fed back into
the next step's residual (EF-SGD, Karimireddy et al. 2019), so the
compression is unbiased *over time* and training converges to the same
point.  With GSPMD the int8 tensor is what crosses the data axis: the
all-reduce payload drops 4x.

Used behind ``TrainCfg.compress_grads``; exactness of the
quantize/dequantize pair and EF convergence are covered by tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Q = 127.0


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """f32 -> (int8, scale). scale is per-tensor amax / 127."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / Q
    q = jnp.clip(jnp.round(x / scale), -Q, Q).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, residuals):
    """Compress each gradient leaf with error feedback.

    Returns (dequantized grads -- what the optimizer consumes; the int8
    round-trip is what crosses the network -- and new residuals).
    """
    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = quantize(v)
        deq = dequantize(q, s)
        return deq, v - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    new = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = tdef.unflatten([t[0] for t in new])
    res = tdef.unflatten([t[1] for t in new])
    return deq, res
