"""Training substrate: optimizers, step builder, checkpointing, compression."""

from .optim import (get_optimizer, adamw, adafactor, lion, warmup_cosine,
                    clip_by_global_norm, global_norm, Optimizer)
from .step import TrainCfg, make_train_step, init_state
