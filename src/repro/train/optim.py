"""Optimizers as pure pytree transforms (no external deps).

``Optimizer`` is an (init, update) pair; ``update`` maps
(grads, state, params) -> (new_params, new_state).  All three optimizers
keep master weights in f32 regardless of the compute dtype.

* adamw     -- default for <= ~30B configs.
* adafactor -- factored second moment: optimizer state is O(rows+cols)
               per matrix instead of O(rows*cols); used for the arctic
               480B config so state fits HBM.
* lion      -- sign-momentum; 1 state slot, cheapest memory after
               adafactor.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, lr) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["mu"])
        flat_v = tdef.flatten_up_to(state["nu"])
        flat_p = tdef.flatten_up_to(params)
        new = [upd(g, m, v, p) for g, m, v, p
               in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([t[0] for t in new])
        mu = tdef.unflatten([t[1] for t in new])
        nu = tdef.unflatten([t[2] for t in new])
        return new_p, {"mu": mu, "nu": nu, "count": c}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment)
# --------------------------------------------------------------------------

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def slot(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(slot, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32) + 1.0) ** -decay

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                step = g * jax.lax.rsqrt(vr / denom)[..., None] \
                    * jax.lax.rsqrt(vc)[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                step = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # relative clipping
            rms = jnp.sqrt(jnp.mean(step * step))
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_s

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_s = tdef.flatten_up_to(state["slots"])
        flat_p = tdef.flatten_up_to(params)
        new = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([t[0] for t in new])
        slots = tdef.unflatten([t[1] for t in new])
        return new_p, {"slots": slots, "count": c}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Lion
# --------------------------------------------------------------------------

def lion(b1: float = 0.9, b2: float = 0.99,
         weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g.astype(jnp.float32)
            step = jnp.sign(b1 * m + (1 - b1) * g) \
                + weight_decay * p.astype(jnp.float32)
            m = b2 * m + (1 - b2) * g
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state["mu"])
        flat_p = tdef.flatten_up_to(params)
        new = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        new_p = tdef.unflatten([t[0] for t in new])
        mu = tdef.unflatten([t[1] for t in new])
        return new_p, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "lion": lion}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr
