"""Checkpointing: async, atomic, elastic (sharding-agnostic).

Layout of one checkpoint:

  <dir>/step_000123.tmp/        -- written first
      manifest.json             -- step, rng, data cursor, tree structure
      arrays/<idx>.npy          -- one file per leaf (host layout)
  <dir>/step_000123/            -- atomic rename after fsync
  <dir>/LATEST                  -- text file naming the newest step

Design points (1000+ node deployment):

* **Async**: ``save_async`` snapshots leaves to host memory on the caller
  thread (device_get), then serializes on a background thread, so the
  train loop stalls only for the device->host copy.
* **Atomic**: the manifest + arrays land in a ``.tmp`` dir; the rename
  and the LATEST update happen only after everything is flushed, so a
  mid-write failure never corrupts the restore path.
* **Elastic**: arrays are saved in host (unsharded) layout with the tree
  structure in the manifest; ``restore`` re-places them under *any* mesh
  via the caller-provided placement fn, so a job can resume on a
  different topology (e.g. 512 -> 256 chips).
* **Cursor**: the data-pipeline cursor and RNG key ride in the manifest,
  making restarts bit-deterministic.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Optional, Tuple

import numpy as np
import jax


def _flatten(state):
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state, extra: Optional[dict] = None
         ) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    leaves, treedef = _flatten(state)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    return _write(ckpt_dir, step, host, treedef, extra or {})


def save_async(ckpt_dir: str, step: int, state,
               extra: Optional[dict] = None) -> threading.Thread:
    """Device->host snapshot now; disk write on a background thread."""
    leaves, treedef = _flatten(state)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    t = threading.Thread(
        target=_write, args=(ckpt_dir, step, host, treedef, extra or {}),
        daemon=True)
    t.start()
    return t


def _write(ckpt_dir, step, host_leaves, treedef, extra) -> str:
    name = f"step_{step:09d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    for i, a in enumerate(host_leaves):
        with open(os.path.join(tmp, "arrays", f"{i}.npy"), "wb") as f:
            np.save(f, a)
            f.flush()
            os.fsync(f.fileno())
    manifest = {
        "step": int(step),
        "num_leaves": len(host_leaves),
        "treedef": str(treedef),
        "extra": extra,
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # unique tmp name: concurrent writers (async + emergency sync saves)
    # must not race each other's rename.  Writers that died mid-save
    # leave their tmp behind, so prune stale ones.  The generous age
    # threshold protects a live writer stalled on slow storage: pruning
    # its tmp would turn its os.replace into a lost LATEST update.
    for entry in os.listdir(ckpt_dir):
        if entry.startswith("LATEST.") and entry.endswith(".tmp"):
            stale = os.path.join(ckpt_dir, entry)
            try:
                if time.time() - os.stat(stale).st_mtime > 600.0:
                    os.unlink(stale)
            except OSError:
                pass
    latest_tmp = os.path.join(
        ckpt_dir, f"LATEST.{os.getpid()}.{threading.get_ident()}.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template,
            place: Optional[Callable[[np.ndarray, Any], Any]] = None,
            step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``place(host_array, template_leaf)`` controls device placement /
    (re)sharding; default is plain ``jnp`` upload.  Returns
    (state, manifest_extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    assert manifest["num_leaves"] == len(leaves), \
        f"leaf count mismatch: ckpt {manifest['num_leaves']} vs {len(leaves)}"
    out = []
    for i, tmpl in enumerate(leaves):
        a = np.load(os.path.join(path, "arrays", f"{i}.npy"))
        assert tuple(a.shape) == tuple(tmpl.shape), \
            f"leaf {i}: shape {a.shape} vs template {tmpl.shape}"
        if place is not None:
            out.append(place(a, tmpl))
        else:
            import jax.numpy as jnp
            out.append(jnp.asarray(a, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def gc_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, n, "manifest.json")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
