"""Device-side halo compaction for the slab exchange.

Each shard ships the points within 2*eps of its slab boundary to the
adjacent shard (via ``jax.lax.ppermute``).  The 2*eps width guarantees a
shipped point's own eps-neighborhood is complete on the receiving side
for any point within eps of the boundary -- the width the reconciliation
exactness argument needs (DESIGN.md §5).

The buffers are fixed-cap (``ClusterCaps.halo_cap``) so the exchange is
a static-shape collective; selection overflow is reported, never
silently truncated (the adaptive driver grows the cap and retries).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.device_dbscan import PAD_COORD


def halo_buffer(pts, valid, eps, side: str, cap: int):
    """Compact the points within 2*eps of the slab's dim-0 edge into a
    fixed-cap buffer.

    Args:
      pts: [n, d] shard-local points (padding rows at ``PAD_COORD``).
      valid: [n] bool.
      side: "lo" (points near the slab's min edge) or "hi" (max edge).
      cap: static buffer size.  ``cap > n`` is legal: the buffer's tail
        beyond the ``n`` selectable points is explicit padding
        (``PAD_COORD`` coordinates, index -1), and overflow can then
        never fire (at most ``n`` points are selectable).

    Returns ``(buf [cap, d] f32, idx [cap] int32 rows into pts or -1,
    overflow [] bool)``.
    """
    x0 = pts[:, 0]
    lo = jnp.min(jnp.where(valid, x0, jnp.inf))
    hi = jnp.max(jnp.where(valid, x0, -jnp.inf))
    near = valid & ((x0 <= lo + 2 * eps) if side == "lo"
                    else (x0 >= hi - 2 * eps))
    # compact the selected points into a fixed-size buffer front
    n = pts.shape[0]
    order = jnp.argsort(~near, stable=True)
    if n < cap:
        order = jnp.concatenate(
            [order, jnp.zeros((cap - n,), order.dtype)])
        sel = jnp.concatenate([near[order[:n]],
                               jnp.zeros((cap - n,), bool)])
    else:
        order = order[:cap]
        sel = near[order]
    buf = jnp.where(sel[:, None], pts[order], PAD_COORD)
    idx = jnp.where(sel, order, -1)
    overflow = jnp.sum(near) > cap
    return buf.astype(jnp.float32), idx.astype(jnp.int32), overflow


def boundary_census(points: np.ndarray, eps: float, n_shards: int) -> int:
    """Worst per-side 2*eps boundary-band population of the slab
    partition: the exact host-side mirror of :func:`halo_buffer`'s
    selection predicate, maximized over every shard and both sides.

    ``slab_cuts`` is deterministic, so a ``halo_cap >= boundary_census``
    can never overflow on the fit that sized it -- unlike the
    ``halo_bound`` densest-window estimate, which bounds *any* window
    and historically left halo buffers ~76% padding."""
    from .sharding import slab_cuts
    pts = np.asarray(points, np.float64)
    order, cut_idx, _ = slab_cuts(pts, eps, n_shards)
    starts = np.concatenate([[0], cut_idx]).astype(np.int64)
    ends = np.concatenate([cut_idx, [len(pts)]]).astype(np.int64)
    x = pts[order, 0]
    worst = 0
    for s in range(n_shards):
        seg = x[starts[s]:ends[s]]
        if not seg.size:
            continue
        worst = max(worst,
                    int(np.sum(seg <= seg.min() + 2 * eps)),
                    int(np.sum(seg >= seg.max() - 2 * eps)))
    return worst


def _quarter_pow2_at_least(x: int, lo: int = 8) -> int:
    """Smallest value >= x on the quarter-pow2 ladder (1, 1.25, 1.5,
    1.75 x 2^e): few distinct shapes like a plain pow2 bucket, but the
    over-provisioning is bounded at 25% instead of 100% -- what keeps
    the halo padding-waste gate (<= 25%, BENCH_8) honest."""
    x = max(int(x), lo, 8)
    e = max((x - 1).bit_length() - 1, 3)
    for m in (5, 6, 7, 8):
        v = (1 << e) * m // 4
        if v >= x:
            return v
    return 1 << (e + 1)


def census_halo_cap(points: np.ndarray, eps: float, n_shards: int,
                    lo: int = 32) -> int:
    """Halo cap sized from the actual boundary-band census (see
    :func:`boundary_census`), bucket-quantized so similarly-sized fits
    share one compiled SPMD step."""
    return _quarter_pow2_at_least(boundary_census(points, eps, n_shards),
                                  lo=lo)


def halo_census(pts_sh: np.ndarray, valid_sh: np.ndarray, eps: float,
                cap: int) -> Tuple[int, int, int]:
    """Host-side mirror of :func:`halo_buffer`'s selection predicate
    over all shards and both sides.

    Returns ``(points_selected, buffer_slots, worst_side)`` where
    ``buffer_slots = 2 * n_shards * cap`` and ``worst_side`` is the
    largest single side's selection.  The cap-sizing padding waste is
    ``1 - worst_side / cap``: SPMD needs one shared buffer shape, so
    the cap must cover the worst side and the slack on lighter sides
    is irreducible -- only the worst-side slack is the cap estimator's
    to close (the ``dist.halo.padding_waste`` gauge, gated <= 25% by
    BENCH_8 via the quarter-pow2 cap ladder).  Pure numpy on the
    pre-packed slabs; never dispatches to the device.
    """
    pts_sh = np.asarray(pts_sh)
    valid_sh = np.asarray(valid_sh, bool)
    n_shards = pts_sh.shape[0]
    selected, worst = 0, 0
    for s in range(n_shards):
        v = valid_sh[s]
        if not v.any():
            continue
        x0 = pts_sh[s, :, 0]
        xv = x0[v]
        lo, hi = float(xv.min()), float(xv.max())
        n_lo = int(np.sum(v & (x0 <= lo + 2 * eps)))
        n_hi = int(np.sum(v & (x0 >= hi - 2 * eps)))
        selected += n_lo + n_hi
        worst = max(worst, n_lo, n_hi)
    return selected, 2 * n_shards * cap, worst
