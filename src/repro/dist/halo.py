"""Device-side halo compaction for the slab exchange.

Each shard ships the points within 2*eps of its slab boundary to the
adjacent shard (via ``jax.lax.ppermute``).  The 2*eps width guarantees a
shipped point's own eps-neighborhood is complete on the receiving side
for any point within eps of the boundary -- the width the reconciliation
exactness argument needs (DESIGN.md §5).

The buffers are fixed-cap (``ClusterCaps.halo_cap``) so the exchange is
a static-shape collective; selection overflow is reported, never
silently truncated (the adaptive driver grows the cap and retries).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.device_dbscan import PAD_COORD


def halo_buffer(pts, valid, eps, side: str, cap: int):
    """Compact the points within 2*eps of the slab's dim-0 edge into a
    fixed-cap buffer.

    Args:
      pts: [n, d] shard-local points (padding rows at ``PAD_COORD``).
      valid: [n] bool.
      side: "lo" (points near the slab's min edge) or "hi" (max edge).
      cap: static buffer size.  ``cap > n`` is legal: the buffer's tail
        beyond the ``n`` selectable points is explicit padding
        (``PAD_COORD`` coordinates, index -1), and overflow can then
        never fire (at most ``n`` points are selectable).

    Returns ``(buf [cap, d] f32, idx [cap] int32 rows into pts or -1,
    overflow [] bool)``.
    """
    x0 = pts[:, 0]
    lo = jnp.min(jnp.where(valid, x0, jnp.inf))
    hi = jnp.max(jnp.where(valid, x0, -jnp.inf))
    near = valid & ((x0 <= lo + 2 * eps) if side == "lo"
                    else (x0 >= hi - 2 * eps))
    # compact the selected points into a fixed-size buffer front
    n = pts.shape[0]
    order = jnp.argsort(~near, stable=True)
    if n < cap:
        order = jnp.concatenate(
            [order, jnp.zeros((cap - n,), order.dtype)])
        sel = jnp.concatenate([near[order[:n]],
                               jnp.zeros((cap - n,), bool)])
    else:
        order = order[:cap]
        sel = near[order]
    buf = jnp.where(sel[:, None], pts[order], PAD_COORD)
    idx = jnp.where(sel, order, -1)
    overflow = jnp.sum(near) > cap
    return buf.astype(jnp.float32), idx.astype(jnp.int32), overflow


def halo_census(pts_sh: np.ndarray, valid_sh: np.ndarray, eps: float,
                cap: int) -> Tuple[int, int]:
    """Host-side mirror of :func:`halo_buffer`'s selection predicate,
    summed over all shards and both sides.

    Returns ``(points_selected, buffer_slots)`` where ``buffer_slots =
    2 * n_shards * cap`` -- the fraction not selected is the halo
    exchange's padding waste, one of the traced distributed fit's
    attribution metrics (``repro.obs``).  Pure numpy on the pre-packed
    slabs; never dispatches to the device.
    """
    pts_sh = np.asarray(pts_sh)
    valid_sh = np.asarray(valid_sh, bool)
    n_shards = pts_sh.shape[0]
    selected = 0
    for s in range(n_shards):
        v = valid_sh[s]
        if not v.any():
            continue
        x0 = pts_sh[s, :, 0]
        xv = x0[v]
        lo, hi = float(xv.min()), float(xv.max())
        selected += int(np.sum(v & (x0 <= lo + 2 * eps)))
        selected += int(np.sum(v & (x0 >= hi - 2 * eps)))
    return selected, 2 * n_shards * cap
