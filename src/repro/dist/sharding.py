"""Host-side slab sharding for the distributed plane.

Points are slab-sharded along the leading (dim-0) grid coordinate with
cuts on *grid lines* (side eps/sqrt(d)), so a grid never straddles two
shards and every per-shard grid statistic is bounded by its global
counterpart (which is what lets ``estimate_caps`` run once, globally).

Everything here is vectorized numpy: the cut search is one
``searchsorted`` over the key-change boundaries and the per-shard pack /
unpack is a single scatter, so the host pre/post-processing stays
O(n log n) with no Python-level per-shard loops on the hot path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.device_dbscan import PAD_COORD


def slab_cuts(points: np.ndarray, eps: float, n_shards: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Grid-line slab cuts along dim 0 (equal counts up to granularity).

    Returns ``(order, cut_idx, cut_coords)``:

    * ``order``      -- [n] stable permutation sorting points by the
      dim-0 grid key;
    * ``cut_idx``    -- [n_shards - 1] positions in ``order`` where each
      slab begins (nondecreasing; an empty slab repeats its neighbor's
      position);
    * ``cut_coords`` -- [n_shards - 1] float64 dim-0 coordinates of the
      cuts (the left edge of the first grid column of the right slab):
      a point belongs to slab ``s`` iff
      ``cut_coords[s-1] <= x0 < cut_coords[s]`` (ends open to +-inf).
    """
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    side = float(eps) / np.sqrt(d)
    x0min = float(pts[:, 0].min())
    key = np.floor((pts[:, 0] - x0min) / side).astype(np.int64)
    order = np.argsort(key, kind="stable")
    skey = key[order]
    # valid cut positions: indices where the grid key changes
    bounds = np.flatnonzero(skey[1:] != skey[:-1]) + 1       # ascending
    tgts = (np.arange(1, n_shards) * n) // n_shards
    # move each equal-count target forward to the next grid line
    pos = np.searchsorted(bounds, tgts, side="left")
    cut_idx = np.where(pos < len(bounds),
                       bounds[np.minimum(pos, max(len(bounds) - 1, 0))]
                       if len(bounds) else n,
                       n).astype(np.int64)
    cut_idx = np.minimum(cut_idx, n)
    safe = np.minimum(cut_idx, n - 1)
    cut_coords = x0min + skey[safe] * side
    cut_coords = np.where(cut_idx >= n, np.inf, cut_coords)
    return order, cut_idx, cut_coords


def owner_of_slab(x0: np.ndarray, cut_coords: np.ndarray) -> np.ndarray:
    """Owning slab of each dim-0 coordinate (vectorized point location).

    de Berg et al.'s grid argument: point location in a slab partition
    is one binary search -- O(log shards), O(1) expected with the
    near-uniform cuts the equal-count policy produces.
    """
    return np.searchsorted(np.asarray(cut_coords, np.float64),
                           np.asarray(x0, np.float64),
                           side="right").astype(np.int64)


def shard_points_by_slab(points: np.ndarray, eps: float, n_shards: int,
                         pad_to: Optional[int] = None):
    """Host-side spatial pre-sharding (vectorized pack).

    Sorts by the dim-0 grid coordinate and cuts into ``n_shards`` slabs
    at grid-line boundaries (equal point counts up to grid granularity).
    Returns (padded [n_shards, cap, d] f32, valid [n_shards, cap] bool,
    perm with original indices [n_shards, cap]).
    """
    pts = np.asarray(points, np.float64)
    order, cut_idx, _ = slab_cuts(pts, eps, n_shards)
    return pack_slabs(pts, order, cut_idx, pad_to)


def pack_slabs(pts: np.ndarray, order: np.ndarray, cut_idx: np.ndarray,
               pad_to: Optional[int] = None):
    """Pack pre-computed slab cuts (:func:`slab_cuts` output) into the
    padded shard layout -- split out so a caller that also needs the
    cut coordinates sorts the points once, not twice."""
    n, d = pts.shape
    n_shards = len(cut_idx) + 1
    starts = np.concatenate([[0], cut_idx]).astype(np.int64)
    ends = np.concatenate([cut_idx, [n]]).astype(np.int64)
    counts = ends - starts
    need = int(max(counts.max(initial=0), 1))
    if pad_to is not None and pad_to < need:
        raise ValueError(
            f"pad_to={pad_to} is smaller than the largest slab ({need} "
            f"points); slab cuts land on grid lines, so per-shard counts "
            f"cannot be reduced below that")
    cap = pad_to or need
    out = np.full((n_shards, cap, d), PAD_COORD, np.float32)
    valid = np.zeros((n_shards, cap), bool)
    perm = np.full((n_shards, cap), -1, np.int64)
    # one scatter: sorted row i lands at (shard_of[i], slot[i])
    shard_of = np.searchsorted(cut_idx, np.arange(n), side="right")
    slot = np.arange(n) - starts[shard_of]
    out[shard_of, slot] = pts[order]
    valid[shard_of, slot] = True
    perm[shard_of, slot] = order
    return out, valid, perm


def unshard_by_perm(values: np.ndarray, perm: np.ndarray,
                    n: int, fill=-1) -> np.ndarray:
    """Invert :func:`shard_points_by_slab`'s permutation (vectorized).

    ``values`` is [n_shards, cap] (or [n_shards * cap]) in shard layout;
    returns [n] in original point order, ``fill`` where no shard row
    mapped (never happens for a complete perm).
    """
    vals = np.asarray(values).reshape(perm.shape[0], perm.shape[1], -1)
    out_shape = (n,) if vals.shape[-1] == 1 else (n, vals.shape[-1])
    out = np.full(out_shape, fill, vals.dtype)
    m = perm >= 0
    out[perm[m]] = vals[m].squeeze(-1) if vals.shape[-1] == 1 else vals[m]
    return out


def halo_bound(points: np.ndarray, eps: float) -> int:
    """Max number of points any 2*eps-wide dim-0 window can contain --
    an upper bound on one shard's halo shipment."""
    x = np.sort(np.asarray(points, np.float64)[:, 0])
    hi = np.searchsorted(x, x + 2.0 * eps, side="right")
    return int((hi - np.arange(len(x))).max())
