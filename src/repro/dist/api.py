"""Host-facing entry points of the distributed plane.

:func:`distributed_fit` is the full fit: pre-shard on the host, run the
cached SPMD step, unpermute -- returning, in original point order, the
globally reconciled labels *plus* the fitted provenance (core flags,
per-shard device grid rows) and the slab geometry (owning shard and cut
coordinates) that :class:`repro.index.ShardedGritIndex` builds from.

:func:`distributed_dbscan` keeps the legacy (labels, report) contract
on top of it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.device_dbscan import OverflowReport

from .sharding import pack_slabs, slab_cuts, unshard_by_perm
from .step import ClusterCaps, cached_cluster_step


@dataclasses.dataclass
class DistributedFitResult:
    """One distributed fit, unpermuted to original point order.

    ``point_grid`` is *per-shard* provenance: the device grid-table row
    of each point within its owning shard's local pipeline (f32
    identifiers -- provenance and diagnostics, not the float64 host
    partition, which the serving index rebuilds per slab).
    """

    labels: np.ndarray       # [n] int64 global cluster ids; -1 noise
    core: np.ndarray         # [n] bool core-point flags
    point_grid: np.ndarray   # [n] int32 per-shard device grid rows
    shard_of: np.ndarray     # [n] int64 owning shard of each point
    cut_coords: np.ndarray   # [n_shards - 1] float64 slab boundaries
    report: OverflowReport   # per-cap flags OR-ed over shards


def distributed_fit(points: np.ndarray, eps: float, min_pts: int,
                    mesh: Mesh, caps: Optional[ClusterCaps] = None,
                    pad_to: Optional[int] = None) -> DistributedFitResult:
    """Pre-shard, run the SPMD cluster step, unpermute (vectorized).

    The report is truthy iff any static cap overflowed on any shard; a
    truthy report means every array is a truncated artifact and must
    not be trusted (the adaptive driver in ``repro.engine`` grows the
    caps and retries before letting that escape).
    """
    caps = caps or ClusterCaps()
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    order, cut_idx, cut_coords = slab_cuts(pts, eps, n_shards)
    pts_sh, valid_sh, perm = pack_slabs(pts, order, cut_idx,
                                        pad_to=pad_to)
    cap = pts_sh.shape[1]
    step = cached_cluster_step(mesh, eps, min_pts, caps, cap,
                               pts.shape[1])
    flat_pts = jnp.asarray(pts_sh.reshape(n_shards * cap, -1))
    flat_valid = jnp.asarray(valid_sh.reshape(-1))
    sharding = NamedSharding(mesh, P(axes))
    flat_pts = jax.device_put(flat_pts, NamedSharding(mesh, P(axes, None)))
    flat_valid = jax.device_put(flat_valid, sharding)
    labels, core, point_grid, report = step(flat_pts, flat_valid)

    labels = unshard_by_perm(np.asarray(labels), perm, n).astype(np.int64)
    core = unshard_by_perm(np.asarray(core), perm, n, fill=False)
    point_grid = unshard_by_perm(np.asarray(point_grid), perm, n)
    shard_row = np.repeat(np.arange(n_shards, dtype=np.int64)[:, None],
                          cap, axis=1)
    shard_of = unshard_by_perm(shard_row, perm, n)
    return DistributedFitResult(labels=labels, core=core,
                                point_grid=point_grid, shard_of=shard_of,
                                cut_coords=cut_coords,
                                report=jax.device_get(report))


def distributed_dbscan(points: np.ndarray, eps: float, min_pts: int,
                       mesh: Mesh, caps: Optional[ClusterCaps] = None,
                       pad_to: Optional[int] = None
                       ) -> Tuple[np.ndarray, OverflowReport]:
    """Legacy wrapper: (labels in original point order, report).

    The report is a fresh host instance (``jax.device_get`` of the
    OR-reduced shard flags) -- callers may keep or mutate it freely.
    ``bool(report)`` keeps the legacy overflow-flag contract.
    """
    res = distributed_fit(points, eps, min_pts, mesh, caps=caps,
                          pad_to=pad_to)
    return res.labels, res.report
