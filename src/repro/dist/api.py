"""Host-facing entry points of the distributed plane.

:func:`distributed_fit` is the full fit: pre-shard on the host, run the
cached SPMD step, unpermute -- returning, in original point order, the
globally reconciled labels *plus* the fitted provenance (core flags,
per-shard device grid rows) and the slab geometry (owning shard and cut
coordinates) that :class:`repro.index.ShardedGritIndex` builds from.

:func:`distributed_dbscan` keeps the legacy (labels, report) contract
on top of it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.device_dbscan import OverflowReport

from .halo import census_halo_cap, halo_census
from .sharding import pack_slabs, slab_cuts, unshard_by_perm
from .step import (ClusterCaps, cached_cluster_step,
                   cached_staged_cluster_steps)


@dataclasses.dataclass
class DistributedFitResult:
    """One distributed fit, unpermuted to original point order.

    ``point_grid`` is *per-shard* provenance: the device grid-table row
    of each point within its owning shard's local pipeline (f32
    identifiers -- provenance and diagnostics, not the float64 host
    partition, which the serving index rebuilds per slab).
    """

    labels: np.ndarray       # [n] int64 global cluster ids; -1 noise
    core: np.ndarray         # [n] bool core-point flags
    point_grid: np.ndarray   # [n] int32 per-shard device grid rows
    shard_of: np.ndarray     # [n] int64 owning shard of each point
    cut_coords: np.ndarray   # [n_shards - 1] float64 slab boundaries
    report: OverflowReport   # per-cap flags OR-ed over shards


def _census_metrics(pts_sh, valid_sh, eps, caps, n_shards, cap) -> None:
    """Padding-waste counters of one traced fit: how much of the halo
    exchange and of the packed slab slots carries real points."""
    reg = obs.registry()
    reg.counter("dist.fit.count").inc()
    sel, slots, worst = halo_census(pts_sh, valid_sh, eps, caps.halo_cap)
    reg.counter("dist.halo.points_selected").inc(sel)
    reg.counter("dist.halo.buffer_slots").inc(slots)
    # cap-sizing waste: slack of the worst-populated side's buffer (the
    # shared SPMD cap must cover it; lighter sides' slack is irreducible
    # -- see halo_census)
    reg.gauge("dist.halo.padding_waste").set(
        1.0 - worst / caps.halo_cap if caps.halo_cap else 0.0)
    reg.gauge("dist.halo.fill").set(sel / slots if slots else 0.0)
    valid_total = int(np.sum(valid_sh))
    reg.counter("dist.pack.points").inc(valid_total)
    reg.counter("dist.pack.slots").inc(n_shards * cap)
    reg.gauge("dist.pack.padding_waste").set(
        1.0 - valid_total / (n_shards * cap) if cap else 0.0)


def distributed_fit(points: np.ndarray, eps: float, min_pts: int,
                    mesh: Mesh, caps: Optional[ClusterCaps] = None,
                    pad_to: Optional[int] = None,
                    traced: Optional[bool] = None) -> DistributedFitResult:
    """Pre-shard, run the SPMD cluster step, unpermute (vectorized).

    The report is truthy iff any static cap overflowed on any shard; a
    truthy report means every array is a truncated artifact and must
    not be trusted (the adaptive driver in ``repro.engine`` grows the
    caps and retries before letting that escape).

    ``traced`` (default: ``repro.obs`` tracing state) selects the
    *staged* SPMD step -- halo exchange / local cluster / reconcile as
    three dispatches with a span sync at each boundary -- so the trace
    attributes the fit's wall-clock per stage.  Staged and fused
    produce identical results; fused stays the untraced default
    because it saves two dispatch round-trips.
    """
    if traced is None:
        traced = obs.enabled()
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if caps is None:
        # default grit caps, but a halo cap sized from the actual
        # boundary-band census (the adaptive engine additionally sizes
        # the grit caps per shard; see repro.engine.estimate_shard_caps)
        caps = ClusterCaps(halo_cap=census_halo_cap(pts, eps, n_shards))
    with obs.span("dist.fit", n=n, shards=n_shards, staged=traced):
        with obs.span("dist.fit.pack"):
            order, cut_idx, cut_coords = slab_cuts(pts, eps, n_shards)
            pts_sh, valid_sh, perm = pack_slabs(pts, order, cut_idx,
                                                pad_to=pad_to)
        cap = pts_sh.shape[1]
        if traced:
            _census_metrics(pts_sh, valid_sh, eps, caps, n_shards, cap)
        with obs.span("dist.fit.transfer") as sp:
            flat_pts = jnp.asarray(pts_sh.reshape(n_shards * cap, -1))
            flat_valid = jnp.asarray(valid_sh.reshape(-1))
            sharding = NamedSharding(mesh, P(axes))
            flat_pts = jax.device_put(
                flat_pts, NamedSharding(mesh, P(axes, None)))
            flat_valid = jax.device_put(flat_valid, sharding)
            sp.sync(flat_pts, flat_valid)

        if traced:
            halo_fn, local_fn, reconcile_fn = cached_staged_cluster_steps(
                mesh, eps, min_pts, caps, cap, pts.shape[1])
            with obs.span("dist.fit.halo_exchange") as sp:
                gl, gr, lo_idx, hi_idx, hov = halo_fn(flat_pts,
                                                      flat_valid)
                sp.sync(gl, gr, lo_idx, hi_idx, hov)
            with obs.span("dist.fit.local_cluster") as sp:
                (labels, core, point_grid, gl_lab, gl_core, gr_lab,
                 gr_core, flags) = local_fn(flat_pts, flat_valid, gl, gr)
                sp.sync(labels, core, point_grid, flags)
            with obs.span("dist.fit.reconcile") as sp:
                labels = reconcile_fn(labels, core, gl_lab, gl_core,
                                      gr_lab, gr_core, lo_idx, hi_idx)
                sp.sync(labels)
            vec = np.asarray(jax.device_get(flags), bool).any(axis=0)
            vec[OverflowReport.FIELDS.index("halo")] |= bool(
                np.asarray(jax.device_get(hov), bool).any())
            report = OverflowReport.from_vector(vec)
        else:
            step = cached_cluster_step(mesh, eps, min_pts, caps, cap,
                                       pts.shape[1])
            with obs.span("dist.fit.spmd_step") as sp:
                labels, core, point_grid, report = step(flat_pts,
                                                        flat_valid)
                sp.sync(labels, core, point_grid)
            report = jax.device_get(report)

        with obs.span("dist.fit.unpack"):
            labels = unshard_by_perm(np.asarray(labels), perm,
                                     n).astype(np.int64)
            core = unshard_by_perm(np.asarray(core), perm, n, fill=False)
            point_grid = unshard_by_perm(np.asarray(point_grid), perm, n)
            shard_row = np.repeat(
                np.arange(n_shards, dtype=np.int64)[:, None], cap, axis=1)
            shard_of = unshard_by_perm(shard_row, perm, n)
    return DistributedFitResult(labels=labels, core=core,
                                point_grid=point_grid, shard_of=shard_of,
                                cut_coords=cut_coords, report=report)


def distributed_dbscan(points: np.ndarray, eps: float, min_pts: int,
                       mesh: Mesh, caps: Optional[ClusterCaps] = None,
                       pad_to: Optional[int] = None
                       ) -> Tuple[np.ndarray, OverflowReport]:
    """Legacy wrapper: (labels in original point order, report).

    The report is a fresh host instance (``jax.device_get`` of the
    OR-reduced shard flags) -- callers may keep or mutate it freely.
    ``bool(report)`` keeps the legacy overflow-flag contract.
    """
    res = distributed_fit(points, eps, min_pts, mesh, caps=caps,
                          pad_to=pad_to)
    return res.labels, res.report
