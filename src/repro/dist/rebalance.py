"""Load-triggered shard-topology rebalancing.

The 1-D slab topology is chosen once at fit time from the fit-time
point distribution.  Under drift the stream walks away from those
cuts: one slab balloons (its delta-engine mutation cost is
O(n_shard) -- the full-array re-splice dominates) while others empty
out.  The :class:`Rebalancer` closes the loop: the serve driver feeds
it per-shard *load* observations each step (owned routed queries +
mutated rows -- the quantities the slab gauges expose), it smooths
them with an EWMA, and between steps it applies **at most one**
topology op per ``period`` steps:

* the hottest shard's smoothed load exceeds ``hot_factor`` x the
  median  ->  ``index.split_shard(k_hot)``;
* else the coldest *adjacent pair's* combined load is under
  ``cold_factor`` x the *mean*  ->  ``index.merge_shards(k, k+1)``
  (the mean, not the median: cold shards drag the median down with
  them, which would mask exactly the imbalance a merge fixes).

Amortization is the point: a split is O(n_shard) once, the imbalance
it removes is O(n_hot) *every step*.  The period bounds topology churn
so the reconcile cost never competes with serving (BENCH_9 measures
the net win).  Splits that cannot make progress (single grid column,
< 2 own points) raise ``ValueError`` inside the index; the policy
marks that shard unsplittable until the topology changes again and
falls through to the merge arm.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["RebalancePolicy", "Rebalancer"]


@dataclasses.dataclass
class RebalancePolicy:
    """Knobs for load-triggered split/merge of slab shards."""

    period: int = 8           # steps between topology ops (amortization)
    hot_factor: float = 2.0   # split when max load > hot_factor * median
    cold_factor: float = 0.5  # merge when pair load < cold_factor * mean
    min_shards: int = 1
    max_shards: int = 32
    ewma: float = 0.5         # smoothing weight on the newest observation


class Rebalancer:
    """EWMA load tracker + bounded split/merge actuator."""

    def __init__(self, policy: Optional[RebalancePolicy] = None):
        self.policy = policy or RebalancePolicy()
        self.load: Optional[np.ndarray] = None
        self.steps = 0
        # starts at 0 (not -inf): the first op also waits out a full
        # period, so the EWMA has real signal before any topology op
        self.last_op_step = 0
        self.history: List[Dict[str, Any]] = []
        self._unsplittable: set = set()

    # ------------------------------------------------------------------

    def observe(self, loads: Sequence[float]) -> None:
        """Fold one step's per-shard loads into the EWMA.

        A shard-count change (someone else rebalanced, or a restore)
        resets the smoothed state: old per-shard loads do not map onto
        the new topology.
        """
        cur = np.asarray(loads, np.float64)
        self.steps += 1
        if self.load is None or len(self.load) != len(cur):
            self.load = cur.copy()
            self._unsplittable.clear()
            return
        a = self.policy.ewma
        self.load = a * cur + (1.0 - a) * self.load

    def imbalance(self) -> float:
        """max/mean of the smoothed load (1.0 == perfectly balanced)."""
        if self.load is None or len(self.load) == 0:
            return 1.0
        mean = float(self.load.mean())
        return float(self.load.max()) / mean if mean > 0 else 1.0

    # ------------------------------------------------------------------

    def maybe_rebalance(self, index) -> Optional[Dict[str, Any]]:
        """Apply at most one split/merge to ``index``; returns its stats.

        No-op (returns None) while inside the amortization period, when
        there is no load signal yet, or when neither trigger fires.
        """
        p = self.policy
        if self.load is None or len(self.load) != index.num_shards:
            return None
        if self.steps - self.last_op_step < p.period:
            return None
        med = float(np.median(self.load))
        if med <= 0:
            med = float(self.load.mean()) or 1.0

        st = self._try_split(index, med)
        if st is None:
            st = self._try_merge(index)
        if st is not None:
            self.last_op_step = self.steps
            self.load = None  # topology changed: re-learn loads
            self._unsplittable.clear()
            self.history.append(st)
        return st

    def _try_split(self, index, med: float) -> Optional[Dict[str, Any]]:
        p = self.policy
        if index.num_shards >= p.max_shards:
            return None
        assert self.load is not None
        order = np.argsort(self.load)[::-1]
        for k in order:
            k = int(k)
            if self.load[k] <= p.hot_factor * med:
                break  # sorted: nothing hotter remains
            if k in self._unsplittable:
                continue
            try:
                return index.split_shard(k)
            except ValueError:
                self._unsplittable.add(k)
        return None

    def _try_merge(self, index) -> Optional[Dict[str, Any]]:
        p = self.policy
        if index.num_shards <= max(p.min_shards, 1):
            return None
        assert self.load is not None
        pair = self.load[:-1] + self.load[1:]
        k = int(np.argmin(pair))
        # vs the mean, not ``med``: the cold shards themselves drag the
        # median toward zero, masking the imbalance a merge fixes
        if pair[k] >= p.cold_factor * float(self.load.mean()):
            return None
        return index.merge_shards(k, k + 1)
