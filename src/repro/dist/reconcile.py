"""Cross-shard label reconciliation (device side).

After every shard clusters its own + ghost points locally, cluster
identity must be stitched across slab boundaries.  The mechanism is the
paper's Theorem 4 plus the halo-width argument: any merge edge between
grids in adjacent slabs is witnessed by a core point within eps of the
boundary -- which is a *shared* point, clustered independently by both
shards.  Each shared core point therefore yields one edge
``(home shard label, remote shard label)`` between the two per-shard
label spaces; the edges are all-gathered and a replicated
pointer-jumping pass maps every ``(shard, local label)`` pair to its
global component.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.labels import label_propagation


def shared_point_edges(own_labels, own_core, local_idx, remote_labels,
                       me, remote_shard, label_space: int):
    """Edges between my label space and a neighbor's, one per shared
    core point.

    Args:
      own_labels / own_core: my shard-local labels and core flags.
      local_idx: [H] my row of each shipped halo point (-1 padding).
      remote_labels: [H] the label my shipped point received at the
        neighbor (-1 where it was not a labeled core there), aligned
        with ``local_idx``.
      me / remote_shard: shard indices (device scalars).
      label_space: per-shard label capacity L; global node id of
        (shard s, label l) is ``s * L + l``.

    Returns ``(edges [H, 2] int32 (-1 padding), valid [H] bool)``.  An
    edge requires the shared point to be a labeled core on *both*
    sides: border labels are order-dependent and must never stitch
    components together.
    """
    ok = (local_idx >= 0) & (remote_labels >= 0)
    safe = jnp.maximum(local_idx, 0)
    mine = own_labels[safe]
    ok = ok & (mine >= 0) & own_core[safe]
    a = me * label_space + mine
    b = remote_shard * label_space + remote_labels
    edges = jnp.where(ok[:, None], jnp.stack([a, b], axis=1), -1)
    return edges, ok


def global_component_map(edges, edge_valid, n_shards: int,
                         label_space: int, axes):
    """All-gather the per-shard edge lists and pointer-jump them into a
    replicated map ``(shard * L + local label) -> global component``."""
    all_edges = jax.lax.all_gather(edges, axes).reshape(-1, 2)
    all_ok = jax.lax.all_gather(edge_valid, axes).reshape(-1)
    n_nodes = n_shards * label_space
    node_valid = jnp.ones((n_nodes,), bool)
    return label_propagation(n_nodes,
                             jnp.maximum(all_edges, 0).astype(jnp.int32),
                             all_ok, node_valid)
