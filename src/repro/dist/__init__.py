"""dist: the distributed serving subsystem (slab sharding + halo
exchange + SPMD step + label reconciliation).

What used to be one file (``repro.core.distributed``, kept as a compat
shim) is now a package with one module per concern:

* :mod:`repro.dist.sharding`  -- host-side slab partition: grid-line
  cuts along dim 0, vectorized shard packing/unpacking, halo bound.
* :mod:`repro.dist.halo`      -- device-side halo compaction (the fixed
  cap buffers exchanged between neighbor shards).
* :mod:`repro.dist.rebalance` -- load-triggered topology policy: EWMA
  per-shard load, bounded split-hottest / merge-coldest actuation on a
  :class:`repro.index.ShardedGritIndex`.
* :mod:`repro.dist.reconcile` -- cross-shard label reconciliation: edge
  construction over shared core points + the replicated global
  component map.
* :mod:`repro.dist.step`      -- ``ClusterCaps`` and the ``shard_map``
  SPMD cluster step (jit cache with oldest-entry eviction); the
  shard-local pipeline is the full ``device_dbscan``, including the
  kernelized distance plane when ``caps.grit.use_kernels`` is set.
* :mod:`repro.dist.api`       -- the host-facing entry points:
  :func:`distributed_fit` (labels + core flags + grid provenance; feeds
  :class:`repro.index.ShardedGritIndex`) and the legacy
  :func:`distributed_dbscan` (labels, report).

See DESIGN.md §5 for the sharding strategy and exactness argument.
"""

from .sharding import (halo_bound, owner_of_slab, shard_points_by_slab,
                       slab_cuts)
from .halo import boundary_census, census_halo_cap, halo_buffer
from .rebalance import RebalancePolicy, Rebalancer
from .step import ClusterCaps, cached_cluster_step, make_cluster_step
from .api import DistributedFitResult, distributed_dbscan, distributed_fit

__all__ = [
    "ClusterCaps", "DistributedFitResult", "RebalancePolicy", "Rebalancer",
    "boundary_census", "cached_cluster_step", "census_halo_cap",
    "distributed_dbscan", "distributed_fit", "halo_bound", "halo_buffer",
    "make_cluster_step", "owner_of_slab", "shard_points_by_slab",
    "slab_cuts",
]
