"""The SPMD cluster step: one ``shard_map`` over the flattened mesh.

Per shard: halo exchange (``repro.dist.halo``), the exact local
GriT-DBSCAN pipeline on own + ghost points (``device_dbscan`` -- the
*full* device pipeline, so ``caps.grit.use_kernels`` routes the shard's
core/border distance plane through the batched Pallas kernels exactly
as on a single device), then cross-shard label reconciliation
(``repro.dist.reconcile``).

The step returns, per shard, the globally reconciled labels *and* the
fitted provenance the serving plane keeps: per-point core flags and the
device grid row of every own point (``point_grid``).  That is what lets
``distributed_fit`` feed a :class:`repro.index.ShardedGritIndex`
without re-deriving core status host-side.

Each shard sends its boundary buffers to the adjacent shard with
``jax.lax.ppermute`` (ring permutation; the slab ends are masked off --
shard 0 has no left neighbor) and the ghosts' locally assigned labels
travel back over the same permutation, reversed.

Compiled steps are cached by everything that shapes the program; the
cache evicts its *oldest* entry at capacity (insertion order, refreshed
on hit) so an adaptive-cap retry loop -- which alternates between at
most two keys -- can never evict the step it is about to reuse.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.device_dbscan import (GritCaps, OverflowReport, PAD_COORD,
                                      device_dbscan)

from .halo import halo_buffer
from .reconcile import global_component_map, shared_point_edges


@dataclasses.dataclass(frozen=True)
class ClusterCaps:
    """Static caps of the distributed pipeline: the per-shard device
    caps (including the ``use_kernels`` distance-plane switch, which is
    part of the same static jit key) plus the halo/edge exchange caps."""

    grit: GritCaps = GritCaps()
    halo_cap: int = 512          # max points shipped per boundary side;
                                 # also sizes the reconciliation edge
                                 # buffers (one edge per shipped point)


def make_cluster_step(mesh: Mesh, eps, min_pts: int, caps: ClusterCaps,
                      n_points_shard: int, d: int):
    """Build the SPMD cluster step for ``mesh`` (all axes flattened).

    Returns a jit-able fn: (points [N, d] f32, valid [N] bool) ->
    (labels [N] int32 global cluster ids (-1 noise),
     core [N] bool core-point flags,
     point_grid [N] int32 per-shard device grid rows (provenance),
     overflow ``OverflowReport`` with per-cap flags OR-ed over shards),
    with N = n_shards * n_points_shard sharded over all mesh axes.
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    L = caps.grit.grid_cap          # per-shard label space
    H = caps.halo_cap

    def local_step(pts, valid):
        # shard_map hands us the local block: [n_points_shard, d]
        me = jax.lax.axis_index(axes)
        # --- 1. halo exchange (both directions, ring) ---
        lo_buf, lo_idx, ov1 = halo_buffer(pts, valid, eps, "lo", H)
        hi_buf, hi_idx, ov2 = halo_buffer(pts, valid, eps, "hi", H)
        right = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        left = [((i + 1) % n_shards, i) for i in range(n_shards)]
        # my hi-edge points go to the right neighbor; lo-edge to the left
        ghosts_from_left = jax.lax.ppermute(hi_buf, axes, right)
        ghosts_from_right = jax.lax.ppermute(lo_buf, axes, left)
        # ring wrap: shard 0 has no left neighbor in a slab decomposition
        first = me == 0
        last = me == n_shards - 1
        ghosts_from_left = jnp.where(first, PAD_COORD, ghosts_from_left)
        ghosts_from_right = jnp.where(last, PAD_COORD, ghosts_from_right)

        # --- 2. local exact GriT-DBSCAN on own + ghosts ---
        all_pts = jnp.concatenate([pts, ghosts_from_left, ghosts_from_right])
        all_valid = jnp.concatenate([
            valid,
            jnp.any(ghosts_from_left < PAD_COORD / 2, axis=1),
            jnp.any(ghosts_from_right < PAD_COORD / 2, axis=1)])
        res = device_dbscan(all_pts.astype(jnp.float32), eps, min_pts,
                            caps.grit, point_valid=all_valid)
        n_own = pts.shape[0]
        own_labels = res.labels[:n_own]
        own_core = res.core[:n_own]
        own_grid = res.point_grid[:n_own]
        ghost_l_labels = res.labels[n_own:n_own + H]
        ghost_l_core = res.core[n_own:n_own + H]
        ghost_r_labels = res.labels[n_own + H:]
        ghost_r_core = res.core[n_own + H:]

        # --- 3. reconcile: my labels of the ghosts go back to their home
        back_to_left = jnp.where(ghost_l_core, ghost_l_labels, -1)
        back_to_right = jnp.where(ghost_r_core, ghost_r_labels, -1)
        # label the ghosts got at the neighbor, aligned with my halo idx
        hi_remote = jax.lax.ppermute(back_to_left, axes, left)
        lo_remote = jax.lax.ppermute(back_to_right, axes, right)

        e_hi, ok_hi = shared_point_edges(
            own_labels, own_core, hi_idx, hi_remote, me,
            jnp.minimum(me + 1, n_shards - 1), L)
        e_lo, ok_lo = shared_point_edges(
            own_labels, own_core, lo_idx, lo_remote, me,
            jnp.maximum(me - 1, 0), L)
        ok_hi = ok_hi & ~last
        ok_lo = ok_lo & ~first
        edges = jnp.concatenate([e_hi, e_lo])              # [2H, 2]
        edge_valid = jnp.concatenate([ok_hi, ok_lo])

        # --- 4. global components over (shard, label) space ---
        gmap = global_component_map(edges, edge_valid, n_shards, L, axes)
        glab = jnp.where(own_labels >= 0,
                         gmap[me * L + jnp.maximum(own_labels, 0)],
                         -1)
        # a fresh report: never mutate the pipeline result's own report
        report = dataclasses.replace(
            res.report, halo=res.report.halo | ov1 | ov2)
        return glab, own_core, own_grid, report.as_vector()[None, :]

    from jax.experimental.shard_map import shard_map
    spec = P(axes)
    fn = shard_map(local_step, mesh=mesh,
                   in_specs=(P(axes, None), spec),
                   out_specs=(spec, spec, spec, P(axes, None)),
                   check_rep=False)

    def cluster_step(points, valid):
        labels, core, point_grid, flags = fn(points, valid)
        return (labels, core, point_grid,
                OverflowReport.from_vector(jnp.any(flags, axis=0)))

    return cluster_step


def make_staged_cluster_steps(mesh: Mesh, eps, min_pts: int,
                              caps: ClusterCaps, n_points_shard: int,
                              d: int):
    """The SPMD step as three separately-jitted stage programs.

    Same math as :func:`make_cluster_step`, but the fused program is
    split at its stage boundaries -- (1) halo exchange, (2) local
    cluster, (3) reconcile -- so a *traced* distributed fit
    (``repro.obs``) can block between dispatches and attribute
    wall-clock to each stage (ROADMAP item 2: is the 20x gap
    recompilation, halo over-exchange, or cap over-padding?).  The
    stage outputs are exactly the fused step's intermediates, so
    staged and fused fits produce identical labels / core flags /
    grids (pinned by ``tests/test_obs.py``); the split costs two extra
    dispatch round-trips plus the materialized intermediates, which is
    why the fused step remains the untraced default.

    Returns ``(halo_fn, local_fn, reconcile_fn)``, all jitted:

    * ``halo_fn(points, valid) -> (ghosts_l, ghosts_r, lo_idx, hi_idx,
      halo_overflow)``
    * ``local_fn(points, valid, ghosts_l, ghosts_r) -> (labels, core,
      point_grid, gl_labels, gl_core, gr_labels, gr_core, report_vec)``
    * ``reconcile_fn(labels, core, gl_labels, gl_core, gr_labels,
      gr_core, lo_idx, hi_idx) -> global labels``
    """
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    L = caps.grit.grid_cap
    H = caps.halo_cap
    right = [(i, (i + 1) % n_shards) for i in range(n_shards)]
    left = [((i + 1) % n_shards, i) for i in range(n_shards)]

    def halo_step(pts, valid):
        me = jax.lax.axis_index(axes)
        lo_buf, lo_idx, ov1 = halo_buffer(pts, valid, eps, "lo", H)
        hi_buf, hi_idx, ov2 = halo_buffer(pts, valid, eps, "hi", H)
        ghosts_from_left = jax.lax.ppermute(hi_buf, axes, right)
        ghosts_from_right = jax.lax.ppermute(lo_buf, axes, left)
        ghosts_from_left = jnp.where(me == 0, PAD_COORD,
                                     ghosts_from_left)
        ghosts_from_right = jnp.where(me == n_shards - 1, PAD_COORD,
                                      ghosts_from_right)
        return (ghosts_from_left, ghosts_from_right, lo_idx, hi_idx,
                (ov1 | ov2)[None])

    def local_step(pts, valid, ghosts_l, ghosts_r):
        all_pts = jnp.concatenate([pts, ghosts_l, ghosts_r])
        all_valid = jnp.concatenate([
            valid,
            jnp.any(ghosts_l < PAD_COORD / 2, axis=1),
            jnp.any(ghosts_r < PAD_COORD / 2, axis=1)])
        res = device_dbscan(all_pts.astype(jnp.float32), eps, min_pts,
                            caps.grit, point_valid=all_valid)
        n_own = pts.shape[0]
        return (res.labels[:n_own], res.core[:n_own],
                res.point_grid[:n_own],
                res.labels[n_own:n_own + H], res.core[n_own:n_own + H],
                res.labels[n_own + H:], res.core[n_own + H:],
                res.report.as_vector()[None, :])

    def reconcile_step(own_labels, own_core, gl_lab, gl_core,
                       gr_lab, gr_core, lo_idx, hi_idx):
        me = jax.lax.axis_index(axes)
        first = me == 0
        last = me == n_shards - 1
        back_to_left = jnp.where(gl_core, gl_lab, -1)
        back_to_right = jnp.where(gr_core, gr_lab, -1)
        hi_remote = jax.lax.ppermute(back_to_left, axes, left)
        lo_remote = jax.lax.ppermute(back_to_right, axes, right)
        e_hi, ok_hi = shared_point_edges(
            own_labels, own_core, hi_idx, hi_remote, me,
            jnp.minimum(me + 1, n_shards - 1), L)
        e_lo, ok_lo = shared_point_edges(
            own_labels, own_core, lo_idx, lo_remote, me,
            jnp.maximum(me - 1, 0), L)
        ok_hi = ok_hi & ~last
        ok_lo = ok_lo & ~first
        edges = jnp.concatenate([e_hi, e_lo])
        edge_valid = jnp.concatenate([ok_hi, ok_lo])
        gmap = global_component_map(edges, edge_valid, n_shards, L, axes)
        return jnp.where(own_labels >= 0,
                         gmap[me * L + jnp.maximum(own_labels, 0)],
                         -1)

    from jax.experimental.shard_map import shard_map
    s1 = P(axes)
    s2 = P(axes, None)
    halo = shard_map(halo_step, mesh=mesh, in_specs=(s2, s1),
                     out_specs=(s2, s2, s1, s1, s1), check_rep=False)
    local = shard_map(local_step, mesh=mesh, in_specs=(s2, s1, s2, s2),
                      out_specs=(s1, s1, s1, s1, s1, s1, s1, s2),
                      check_rep=False)
    reconcile = shard_map(reconcile_step, mesh=mesh,
                          in_specs=(s1,) * 8, out_specs=s1,
                          check_rep=False)
    return jax.jit(halo), jax.jit(local), jax.jit(reconcile)


# jitted SPMD steps keyed by everything that shapes the program; reused
# across distributed fits so the adaptive driver's quantized cap
# retries (and repeated runs on similarly-sized data) don't recompile.
# Fused and staged (traced) programs share the cache, disambiguated by
# the key's trailing flavor tag.
_STEP_CACHE: dict = {}
_STEP_CACHE_MAX = 32


def _step_cache_get(key, build):
    if key in _STEP_CACHE:
        # refresh insertion order: a hit is the newest entry again
        _STEP_CACHE[key] = _STEP_CACHE.pop(key)
    else:
        while len(_STEP_CACHE) >= _STEP_CACHE_MAX:
            _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
        _STEP_CACHE[key] = build()
    return _STEP_CACHE[key]


def cached_cluster_step(mesh: Mesh, eps: float, min_pts: int,
                        caps: ClusterCaps, n_points_shard: int, d: int):
    key = (mesh, float(eps), int(min_pts), caps, int(n_points_shard),
           int(d), "fused")
    return _step_cache_get(
        key, lambda: jax.jit(make_cluster_step(
            mesh, eps, min_pts, caps, n_points_shard, d)))


def cached_staged_cluster_steps(mesh: Mesh, eps: float, min_pts: int,
                                caps: ClusterCaps, n_points_shard: int,
                                d: int):
    key = (mesh, float(eps), int(min_pts), caps, int(n_points_shard),
           int(d), "staged")
    return _step_cache_get(
        key, lambda: make_staged_cluster_steps(
            mesh, eps, min_pts, caps, n_points_shard, d))
