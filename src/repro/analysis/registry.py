"""Rule registry: the analyzer's analogue of the engine registry.

Every invariant rule registers itself here under a short kebab-case
name (the name pragmas and ``--select`` refer to).  A rule is a class
with two hooks; implement whichever granularity the invariant needs:

* :meth:`Rule.check_module` -- per-file findings (most rules);
* :meth:`Rule.check_project` -- whole-tree findings (rules that need a
  cross-file call graph, e.g. ``hot-path-sync``).

Registering a new rule::

    @register_rule
    class MyRule(Rule):
        name = "my-rule"
        description = "..."
        def check_module(self, mod, ctx): ...
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Tuple, Type

from .report import Violation

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .context import ModuleInfo, ProjectContext


class Rule:
    """Base class of one invariant rule (see module docstring)."""

    name: str = ""
    description: str = ""

    def check_module(self, mod: "ModuleInfo",
                     ctx: "ProjectContext") -> List[Violation]:
        return []

    def check_project(self, ctx: "ProjectContext") -> List[Violation]:
        return []


_REGISTRY: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"rule class {cls.__name__} has no name")
    if cls.name in _REGISTRY:
        raise ValueError(f"rule {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    return cls


def _ensure_loaded() -> None:
    # the built-in rules live in .rules; importing the package
    # populates the registry (same deferral idiom as engine.registry)
    from . import rules  # noqa: F401


def rule_names() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def get_rule(name: str) -> Rule:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown rule {name!r}; available: {rule_names()}")
    return _REGISTRY[name]()


def all_rules() -> List[Rule]:
    _ensure_loaded()
    return [_REGISTRY[n]() for n in sorted(_REGISTRY)]
