"""Shared AST plumbing for the invariant rules.

One pass over each file builds a :class:`ModuleInfo` (function units,
locally-jitted callables with their donation/static metadata, kernel-ops
import aliases); the :class:`ProjectContext` ties the files of one run
together for the rules that need cross-file knowledge (the hot-path
call graph, cross-module jit specs of the ``repro.kernels.ops``
wrappers).

Scope note: rules analyze *function units* (top-level functions and
class methods; nested functions and lambdas are part of their enclosing
unit's tree).  Module-level statements outside any function are not
scanned -- none of the guarded invariants can be violated at import
time in this codebase.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

#: names a ``jax.jit`` call/decorator goes by in this codebase
_JIT_CALLEES = frozenset({"jax.jit", "jit"})
#: ``functools.partial`` spellings (``from functools import partial``)
_PARTIAL_CALLEES = frozenset({"functools.partial", "partial"})
#: module paths whose public callables are jitted kernel entry points
_KERNEL_OPS_MODULES = frozenset(
    {"repro.kernels.ops", "repro.kernels"})


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base is not None else None
    return None


def _const_strings(node: ast.AST) -> Tuple[str, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return tuple(out)
    return ()


def _const_ints(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[int] = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return tuple(out)
    return ()


@dataclasses.dataclass(frozen=True)
class JitSpec:
    """Donation / static metadata of one jitted callable."""

    donate_argnums: Tuple[int, ...] = ()
    donate_argnames: Tuple[str, ...] = ()
    static_argnames: Tuple[str, ...] = ()

    @property
    def donates(self) -> bool:
        return bool(self.donate_argnums or self.donate_argnames)


def jit_spec_of_call(call: ast.Call) -> Optional[JitSpec]:
    """The :class:`JitSpec` of a ``jax.jit(...)`` /
    ``functools.partial(jax.jit, ...)`` expression, else None."""
    callee = dotted_name(call.func)
    is_jit = callee in _JIT_CALLEES
    is_partial_jit = (
        callee in _PARTIAL_CALLEES and bool(call.args)
        and dotted_name(call.args[0]) in _JIT_CALLEES)
    if not (is_jit or is_partial_jit):
        return None
    nums: Tuple[int, ...] = ()
    dnames: Tuple[str, ...] = ()
    snames: Tuple[str, ...] = ()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            nums = _const_ints(kw.value)
        elif kw.arg == "donate_argnames":
            dnames = _const_strings(kw.value)
        elif kw.arg == "static_argnames":
            snames = _const_strings(kw.value)
    return JitSpec(donate_argnums=nums, donate_argnames=dnames,
                   static_argnames=snames)


def jit_spec_of_def(node: ast.FunctionDef) -> Optional[JitSpec]:
    """The jit decoration of a function definition, else None."""
    for dec in node.decorator_list:
        if dotted_name(dec) in _JIT_CALLEES:
            return JitSpec()
        if isinstance(dec, ast.Call):
            spec = jit_spec_of_call(dec)
            if spec is not None:
                return spec
    return None


@dataclasses.dataclass
class FunctionUnit:
    """One analyzable function: a top-level def or a class method.

    ``node`` includes any nested defs/lambdas -- rules walk the whole
    unit, so closures are analyzed in their enclosing unit's scope."""

    qualname: str              # "func" or "Class.method"
    node: ast.FunctionDef
    module_relpath: str
    jit: Optional[JitSpec] = None
    called_names: Set[str] = dataclasses.field(default_factory=set)

    @property
    def simple_name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def param_names(self) -> List[str]:
        a = self.node.args
        params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg is not None:
            params.append(a.vararg.arg)
        if a.kwarg is not None:
            params.append(a.kwarg.arg)
        return params


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus the lookups the rules share."""

    path: str                  # display path (as reported)
    relpath: str               # posix path relative to the scan root
    tree: ast.Module
    lines: List[str]
    units: List[FunctionUnit] = dataclasses.field(default_factory=list)
    #: locally-defined jitted callables (decorated defs and
    #: ``f = jax.jit(g, ...)`` bindings), by local name
    jitted: Dict[str, JitSpec] = dataclasses.field(default_factory=dict)
    #: local names bound to the kernel-ops *module* (``kernel_ops.x``)
    kernel_module_aliases: Set[str] = dataclasses.field(
        default_factory=set)
    #: local names bound to individual kernel-ops callables
    kernel_func_aliases: Set[str] = dataclasses.field(default_factory=set)

    def path_parts(self) -> Tuple[str, ...]:
        return tuple(self.relpath.split("/"))


def _collect_units(mod: ModuleInfo) -> None:
    def add(node: ast.FunctionDef, qual: str) -> None:
        unit = FunctionUnit(qualname=qual, node=node,
                            module_relpath=mod.relpath,
                            jit=jit_spec_of_def(node))
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                callee = sub.func
                if isinstance(callee, ast.Name):
                    unit.called_names.add(callee.id)
                elif isinstance(callee, ast.Attribute):
                    unit.called_names.add(callee.attr)
        mod.units.append(unit)
        if unit.jit is not None:
            mod.jitted[node.name] = unit.jit

    for stmt in mod.tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            add(stmt, stmt.name)  # type: ignore[arg-type]
        elif isinstance(stmt, ast.ClassDef):
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    add(sub,  # type: ignore[arg-type]
                        f"{stmt.name}.{sub.name}")


def _collect_jit_bindings(mod: ModuleInfo) -> None:
    # ``f = jax.jit(g, donate_argnums=...)`` anywhere in the file binds
    # a donating/static callee under a plain name
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value, ast.Call):
            continue
        spec = jit_spec_of_call(node.value)
        if spec is None:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                mod.jitted[tgt.id] = spec


def _collect_kernel_aliases(mod: ModuleInfo) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module in _KERNEL_OPS_MODULES:
                for alias in node.names:
                    local = alias.asname or alias.name
                    if alias.name == "ops":
                        mod.kernel_module_aliases.add(local)
                    else:
                        mod.kernel_func_aliases.add(local)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in _KERNEL_OPS_MODULES and \
                        alias.name.endswith("ops"):
                    mod.kernel_module_aliases.add(
                        alias.asname or alias.name)


def build_module(path: str, relpath: str, source: str) -> ModuleInfo:
    """Parse one file into a :class:`ModuleInfo` (raises SyntaxError)."""
    tree = ast.parse(source, filename=path)
    mod = ModuleInfo(path=path, relpath=relpath, tree=tree,
                     lines=source.splitlines())
    _collect_units(mod)
    _collect_jit_bindings(mod)
    _collect_kernel_aliases(mod)
    return mod


@dataclasses.dataclass
class ProjectContext:
    """Cross-file view of one analysis run."""

    modules: List[ModuleInfo]
    units_by_simple: Dict[str, List[FunctionUnit]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self) -> None:
        for mod in self.modules:
            for unit in mod.units:
                self.units_by_simple.setdefault(
                    unit.simple_name, []).append(unit)

    def module_of(self, unit: FunctionUnit) -> ModuleInfo:
        for mod in self.modules:
            if mod.relpath == unit.module_relpath:
                return mod
        raise KeyError(unit.module_relpath)

    def _kernel_ops_module(self) -> Optional[ModuleInfo]:
        for mod in self.modules:
            if mod.relpath.endswith("kernels/ops.py"):
                return mod
        return None

    def resolve_jitted_callee(self, mod: ModuleInfo,
                              call: ast.Call) -> Optional[JitSpec]:
        """The :class:`JitSpec` of a call site whose callee is a known
        jitted entry point: a locally-jitted def/binding, or one of the
        ``repro.kernels.ops`` wrappers (module-alias or direct import).
        Kernel-ops wrappers that are plain functions *wrapping* a jit
        resolve to an empty spec -- still a jitted entry.  Returns None
        for everything else."""
        callee = call.func
        name = dotted_name(callee)
        if name is not None and name in mod.jitted:
            return mod.jitted[name]
        target: Optional[str] = None
        if isinstance(callee, ast.Attribute):
            base = dotted_name(callee.value)
            if base is not None and base in mod.kernel_module_aliases:
                target = callee.attr
        elif isinstance(callee, ast.Name) and \
                callee.id in mod.kernel_func_aliases:
            target = callee.id
        if target is None:
            return None
        ops_mod = self._kernel_ops_module()
        if ops_mod is not None:
            if target in ops_mod.jitted:
                return ops_mod.jitted[target]
        return JitSpec()


def iter_assignments(node: ast.AST) -> Iterator[
        Tuple[List[str], ast.AST, int]]:
    """Yield ``(target_names, value_expr, lineno)`` for every simple
    assignment in ``node`` (tuple unpacking flattened; attribute and
    subscript targets reported by their dotted name when available)."""
    for sub in ast.walk(node):
        value: Optional[ast.AST] = None
        targets: List[ast.AST] = []
        if isinstance(sub, ast.Assign):
            value, targets = sub.value, list(sub.targets)
        elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
            value, targets = sub.value, [sub.target]
        elif isinstance(sub, ast.AugAssign):
            value, targets = sub.value, [sub.target]
        elif isinstance(sub, ast.NamedExpr):
            value, targets = sub.value, [sub.target]
        if value is None:
            continue
        names: List[str] = []
        stack = list(targets)
        while stack:
            tgt = stack.pop()
            if isinstance(tgt, (ast.Tuple, ast.List)):
                stack.extend(tgt.elts)
            elif isinstance(tgt, ast.Starred):
                stack.append(tgt.value)
            else:
                dn = dotted_name(tgt)
                if dn is not None:
                    names.append(dn)
        if names:
            yield names, value, sub.lineno


def subtree_has_call(node: ast.AST, simple_names: Set[str]) -> bool:
    """True when ``node`` contains a call whose callee's simple name
    (final attribute for dotted callees) is in ``simple_names``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            callee = sub.func
            if isinstance(callee, ast.Name) and \
                    callee.id in simple_names:
                return True
            if isinstance(callee, ast.Attribute) and \
                    callee.attr in simple_names:
                return True
    return False
