"""``recompile-hazard``: jit keys must route through pow2 bucketing.

Every distinct input shape (and every distinct static value) is a new
XLA compile.  The serving stack keeps compile counts bounded by padding
data-dependent sizes through the pow2/bucketing helpers
(``_pow2_at_least`` / ``_pad_pow2`` / ``_pad_rows`` / ``_pad_feat``)
and the persisted ``*_cap`` attributes before anything reaches a jitted
callable.  This rule flags two ways a change can silently reintroduce
per-request compiles:

* a jitted callee fed ``jnp.asarray(x)`` / ``jnp.array(x)`` where ``x``
  involves a locally-assigned array that never went through a bucketing
  helper (raw data-dependent shape -> one compile per batch size);
* a ``static_argnames`` keyword receiving an array-constructor value
  (arrays are unhashable -- a guaranteed ``TypeError`` at trace time,
  or worse, a compile per value if converted).
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..context import (FunctionUnit, JitSpec, ModuleInfo,
                       ProjectContext, dotted_name, iter_assignments)
from ..registry import Rule, register_rule
from ..report import Violation

#: helpers whose output is shape-bucketed by construction
BUCKETING_HELPERS = frozenset({
    "_pow2_at_least", "_pad_pow2", "_pad_rows", "_pad_feat",
})

_CONVERTERS = frozenset({
    "jnp.asarray", "jnp.array", "jax.numpy.asarray", "jax.numpy.array",
})

_ARRAY_CTORS = frozenset({
    "np.array", "np.asarray", "np.zeros", "np.ones", "np.empty",
    "jnp.array", "jnp.asarray", "jnp.zeros", "jnp.ones",
    "numpy.array", "numpy.asarray", "numpy.zeros", "numpy.ones",
})


def _bucketed_names(unit: FunctionUnit) -> Set[str]:
    """Names assigned (in source order) from a bucketing helper, a
    ``*_cap`` attribute, or another bucketed name."""
    bucketed: Set[str] = set()

    def value_is_bucketed(value: ast.AST) -> bool:
        for sub in ast.walk(value):
            if isinstance(sub, ast.Call):
                callee = sub.func
                simple = (callee.id if isinstance(callee, ast.Name)
                          else callee.attr
                          if isinstance(callee, ast.Attribute) else "")
                if simple in BUCKETING_HELPERS:
                    return True
            if isinstance(sub, ast.Attribute) and \
                    sub.attr.endswith("_cap"):
                return True
            if isinstance(sub, ast.Name) and sub.id in bucketed:
                return True
        return False

    for names, value, _line in sorted(
            iter_assignments(unit.node), key=lambda t: t[2]):
        if value_is_bucketed(value):
            bucketed.update(n for n in names if "." not in n)
    return bucketed


def _assigned_names(unit: FunctionUnit) -> Set[str]:
    out: Set[str] = set()
    for names, _value, _line in iter_assignments(unit.node):
        out.update(n for n in names if "." not in n)
    return out


@register_rule
class RecompileHazard(Rule):
    name = "recompile-hazard"
    description = ("jitted callable fed raw data-dependent shapes that "
                   "skip pow2 bucketing, or an array-typed "
                   "static_argnames value")

    def check_module(self, mod: ModuleInfo,
                     ctx: ProjectContext) -> List[Violation]:
        out: List[Violation] = []
        for unit in mod.units:
            out.extend(self._check_unit(mod, ctx, unit))
        return out

    def _check_unit(self, mod: ModuleInfo, ctx: ProjectContext,
                    unit: FunctionUnit) -> List[Violation]:
        out: List[Violation] = []
        bucketed = _bucketed_names(unit)
        assigned = _assigned_names(unit)
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Call):
                continue
            spec = ctx.resolve_jitted_callee(mod, node)
            if spec is None:
                continue
            callee = dotted_name(node.func) or "<jitted>"
            out.extend(self._check_raw_shapes(
                mod, node, callee, bucketed, assigned))
            out.extend(self._check_static_args(mod, node, callee, spec))
        return out

    def _check_raw_shapes(self, mod: ModuleInfo, call: ast.Call,
                          callee: str, bucketed: Set[str],
                          assigned: Set[str]) -> List[Violation]:
        out: List[Violation] = []
        args = list(call.args) + [kw.value for kw in call.keywords]
        for arg in args:
            for sub in ast.walk(arg):
                if not isinstance(sub, ast.Call):
                    continue
                if dotted_name(sub.func) not in _CONVERTERS:
                    continue
                raw = self._raw_name(sub, bucketed, assigned)
                if raw is not None:
                    out.append(Violation(
                        rule=self.name, path=mod.path,
                        line=sub.lineno, col=sub.col_offset,
                        message=(f"{callee}() is fed a device array "
                                 f"built from '{raw}', whose shape "
                                 "never went through a bucketing "
                                 "helper (_pad_pow2/_pow2_at_least); "
                                 "each distinct size is a fresh XLA "
                                 "compile")))
        return out

    @staticmethod
    def _raw_name(conv: ast.Call, bucketed: Set[str],
                  assigned: Set[str]) -> Optional[str]:
        for sub in ast.walk(conv):
            if isinstance(sub, ast.Name) and sub.id in assigned and \
                    sub.id not in bucketed:
                return sub.id
        return None

    def _check_static_args(self, mod: ModuleInfo, call: ast.Call,
                           callee: str,
                           spec: JitSpec) -> List[Violation]:
        out: List[Violation] = []
        statics = set(spec.static_argnames)
        if not statics:
            return out
        for kw in call.keywords:
            if kw.arg not in statics:
                continue
            if isinstance(kw.value, ast.Call) and \
                    dotted_name(kw.value.func) in _ARRAY_CTORS:
                out.append(Violation(
                    rule=self.name, path=mod.path,
                    line=kw.value.lineno, col=kw.value.col_offset,
                    message=(f"static argument '{kw.arg}' of "
                             f"{callee}() receives an array value; "
                             "static_argnames must be hashable and "
                             "low-cardinality (this is a trace-time "
                             "TypeError or a compile per value)")))
        return out
