"""Built-in invariant rules.

Importing this package registers every rule with
``repro.analysis.registry`` (the registry defers this import, mirroring
the engine registry's idiom).
"""

from __future__ import annotations

from . import donation, hostsync, precision, recompile, sentinel

__all__ = ["donation", "hostsync", "precision", "recompile", "sentinel"]
