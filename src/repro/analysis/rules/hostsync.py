"""``hot-path-sync``: the serving hot loop must not host-sync.

The whole point of the device-resident serving plane is that
``ClusterServer.step`` and the ``DeviceState`` dispatch stages enqueue
device work and defer materialization to each stage's single intended
block point.  One stray ``np.asarray(device_value)``, ``.item()``,
``float(tracer)`` or ``block_until_ready()`` in that call graph
serializes the pipeline and silently halves throughput -- and nothing
crashes, so nothing catches it.

This is a project-level rule: it builds a call graph (simple-name
matching, BFS) from the hot-path roots and flags host-sync operations
in every reachable function.  ``.item()`` / ``block_until_ready`` /
``jax.device_get`` always flag; ``np.asarray`` / ``float`` / ``int``
flag only when their operand is device-derived (a ``*dev`` name, a
``*_res`` resident buffer, or a value assigned from a jitted/kernel
call).  The intended block points carry justified pragmas.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..context import (FunctionUnit, ModuleInfo, ProjectContext,
                       dotted_name, iter_assignments)
from ..registry import Rule, register_rule
from ..report import Violation

#: dispatch stages in index/device_state.py that are hot-path roots
STAGE_ROOTS = frozenset({
    "predict_device_async", "predict_device", "recompute_cores_device",
    "decide_edges_device", "border_pass_device",
})

#: modules that can never be on the serving hot path -- name collisions
#: with their functions must not drag them into the reachable set
_EXCLUDED_PARTS = frozenset({
    "train", "launch", "bench", "examples", "scripts", "tests",
    "analysis",
})

_MATERIALIZERS = frozenset({
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "float", "int",
})


def _excluded(mod: ModuleInfo) -> bool:
    return bool(set(mod.path_parts()) & _EXCLUDED_PARTS)


def _is_root(mod: ModuleInfo, unit: FunctionUnit) -> bool:
    # roots are ClusterServer.step and the DeviceState *dispatch*
    # stages; audit helpers like DeviceState.mirror_matches are only
    # covered if some root actually reaches them
    if unit.qualname == "ClusterServer.step":
        return True
    return (mod.relpath.endswith("index/device_state.py")
            and unit.simple_name in STAGE_ROOTS)


def _device_producers(ctx: ProjectContext) -> Set[str]:
    """Simple names of functions whose return value lives on device:
    jitted defs, plus (to fixpoint) functions returning jnp values or
    the result of another producer."""
    producers: Set[str] = set()
    for mod in ctx.modules:
        for unit in mod.units:
            if unit.jit is not None or \
                    mod.relpath.endswith("kernels/ops.py"):
                producers.add(unit.simple_name)
    for _ in range(4):
        grew = False
        for mod in ctx.modules:
            for unit in mod.units:
                if unit.simple_name in producers:
                    continue
                for node in ast.walk(unit.node):
                    if isinstance(node, ast.Return) and \
                            node.value is not None and \
                            _device_expr(node.value, producers, set()):
                        producers.add(unit.simple_name)
                        grew = True
                        break
        if not grew:
            break
    return producers


def _device_expr(expr: ast.AST, producers: Set[str],
                 tainted: Set[str]) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name):
            if sub.id.endswith("dev") or sub.id.endswith("_res") or \
                    sub.id in tainted:
                return True
        elif isinstance(sub, ast.Attribute):
            if sub.attr.endswith("_res"):
                return True
        if isinstance(sub, ast.Call):
            dn = dotted_name(sub.func)
            if dn is not None and (dn.startswith("jnp.") or
                                   dn.startswith("jax.numpy.")):
                return True
            simple = (sub.func.id if isinstance(sub.func, ast.Name)
                      else sub.func.attr
                      if isinstance(sub.func, ast.Attribute) else "")
            if simple in producers:
                return True
    return False


def _device_tainted_names(unit: FunctionUnit,
                          producers: Set[str]) -> Set[str]:
    tainted: Set[str] = set()
    for names, value, _line in sorted(
            iter_assignments(unit.node), key=lambda t: t[2]):
        if _device_expr(value, producers, tainted):
            tainted.update(n for n in names if "." not in n)
    return tainted


@register_rule
class HotPathSync(Rule):
    name = "hot-path-sync"
    description = ("host synchronization inside the call graph of "
                   "ClusterServer.step / DeviceState dispatch")

    def check_project(self, ctx: ProjectContext) -> List[Violation]:
        mod_of: Dict[int, ModuleInfo] = {}
        roots: List[FunctionUnit] = []
        for mod in ctx.modules:
            for unit in mod.units:
                mod_of[id(unit)] = mod
                if not _excluded(mod) and _is_root(mod, unit):
                    roots.append(unit)
        if not roots:
            return []

        reachable: Dict[int, FunctionUnit] = {}
        frontier = list(roots)
        while frontier:
            unit = frontier.pop()
            if id(unit) in reachable:
                continue
            reachable[id(unit)] = unit
            for name in unit.called_names:
                for callee in ctx.units_by_simple.get(name, []):
                    cmod = mod_of[id(callee)]
                    if not _excluded(cmod) and \
                            id(callee) not in reachable:
                        frontier.append(callee)

        producers = _device_producers(ctx)
        out: List[Violation] = []
        for unit in reachable.values():
            out.extend(self._check_unit(
                mod_of[id(unit)], unit, producers))
        return out

    def _check_unit(self, mod: ModuleInfo, unit: FunctionUnit,
                    producers: Set[str]) -> List[Violation]:
        tainted = _device_tainted_names(unit, producers)
        out: List[Violation] = []
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Call):
                continue
            v = self._check_call(mod, unit, node, producers, tainted)
            if v is not None:
                out.append(v)
        return out

    def _check_call(self, mod: ModuleInfo, unit: FunctionUnit,
                    node: ast.Call, producers: Set[str],
                    tainted: Set[str]) -> Optional[Violation]:
        where = (f"in {unit.qualname}() on the serving hot path; "
                 "route through the stage's intended block point or "
                 "pragma with the reason")
        if isinstance(node.func, ast.Attribute):
            if node.func.attr == "block_until_ready":
                return self._v(mod, node,
                               f"block_until_ready() {where}")
            if node.func.attr == "item" and not node.args:
                return self._v(mod, node, f".item() host sync {where}")
        dn = dotted_name(node.func)
        if dn == "jax.device_get":
            return self._v(mod, node, f"jax.device_get() {where}")
        if dn in _MATERIALIZERS and node.args:
            if _device_expr(node.args[0], producers, tainted):
                return self._v(
                    mod, node,
                    f"{dn}() materializes a device value {where}")
        return None

    def _v(self, mod: ModuleInfo, node: ast.Call,
           message: str) -> Violation:
        return Violation(rule=self.name, path=mod.path,
                         line=node.lineno, col=node.col_offset,
                         message=message)
