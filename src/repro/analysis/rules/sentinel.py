"""``sentinel-mask``: reductions over padded buffers must mask first.

Kernel inputs are padded to pow2 capacities with FAR/PAD sentinels
(``FAR = 1e15``, squared ``FAR_D2 ~ 1e29``); a ``min``/``argmin``
straight over such a buffer happily returns a sentinel slot whenever
the valid prefix is empty -- or, worse, a *wrong* argmin when sentinel
rows compare equal.  The kernel wrappers therefore fold a validity mask
(``jnp.where(valid, d2, inf)``) before every reduction.

This rule flags, in ``kernels/``, any ``min`` / ``argmin`` (function or
method form) whose operand does not derive from a ``jnp.where`` /
``np.where`` fold -- directly, or via a name assigned (with one
propagation step) from such a fold.  Pallas kernel *bodies* (functions
taking ``*_ref`` parameters) are exempt: their operands are FAR-folded
by the wrapper contract before the kernel launches, and ``where``
inside the grid loop is exactly what the tiling is avoiding.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from ..context import (FunctionUnit, ModuleInfo, ProjectContext,
                       dotted_name, iter_assignments)
from ..registry import Rule, register_rule
from ..report import Violation

_REDUCERS = frozenset({"min", "argmin", "nanmin", "nanargmin"})
_REDUCER_MODULES = ("jnp.", "np.", "jax.numpy.", "numpy.")


def _in_scope(mod: ModuleInfo) -> bool:
    return "kernels" in mod.path_parts()


def _is_kernel_body(unit: FunctionUnit) -> bool:
    return any(p.endswith("_ref") for p in unit.param_names())


def _has_where(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            callee = sub.func
            simple = (callee.id if isinstance(callee, ast.Name)
                      else callee.attr
                      if isinstance(callee, ast.Attribute) else "")
            if simple == "where":
                return True
    return False


def _masked_names(unit: FunctionUnit) -> Set[str]:
    """Names assigned from a where-fold, plus one propagation step
    (a name assigned from an expression mentioning a masked name)."""
    masked: Set[str] = set()
    assignments = sorted(iter_assignments(unit.node),
                         key=lambda t: t[2])
    for _pass in range(2):
        for names, value, _line in assignments:
            if _has_where(value) or any(
                    isinstance(s, ast.Name) and s.id in masked
                    for s in ast.walk(value)):
                masked.update(n for n in names if "." not in n)
    return masked


def _operand_masked(operand: ast.AST, masked: Set[str]) -> bool:
    if _has_where(operand):
        return True
    return any(isinstance(s, ast.Name) and s.id in masked
               for s in ast.walk(operand))


@register_rule
class SentinelMask(Rule):
    name = "sentinel-mask"
    description = ("raw min/argmin over a PAD/FAR-padded buffer in "
                   "kernels/ without a preceding validity-mask fold")

    def check_module(self, mod: ModuleInfo,
                     ctx: ProjectContext) -> List[Violation]:
        if not _in_scope(mod):
            return []
        out: List[Violation] = []
        for unit in mod.units:
            if _is_kernel_body(unit):
                continue
            out.extend(self._check_unit(mod, unit))
        return out

    def _check_unit(self, mod: ModuleInfo,
                    unit: FunctionUnit) -> List[Violation]:
        masked = _masked_names(unit)
        out: List[Violation] = []
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Call):
                continue
            operand = self._reduction_operand(node)
            if operand is None:
                continue
            if not _operand_masked(operand, masked):
                out.append(Violation(
                    rule=self.name, path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=("raw reduction over a possibly "
                             "FAR/PAD-padded buffer; fold the validity "
                             "mask first (jnp.where(valid, d2, inf)) "
                             "or the sentinel slots can win")))
        return out

    @staticmethod
    def _reduction_operand(node: ast.Call) -> Optional[ast.expr]:
        callee = node.func
        if isinstance(callee, ast.Attribute) and \
                callee.attr in _REDUCERS:
            dn = dotted_name(callee)
            if dn is not None and any(
                    dn.startswith(p) for p in _REDUCER_MODULES):
                return node.args[0] if node.args else None
            # method form: buf.min() / buf.argmin()
            if not node.args:
                return callee.value
        return None
