"""``donation-aliasing``: stale reads of donated buffers.

``jax.jit(..., donate_argnums=...)`` hands the argument's device buffer
to the callee; the caller's binding now aliases freed storage and any
subsequent read is undefined (jax only warns at runtime, and only
sometimes).  The codebase's convention is to rebind the donated name at
the donating call statement itself::

    self.alive_res, self.core_res = _scatter_dead(self.alive_res,
                                                  self.core_res, idx)

This rule flags a *load* of a donated argument's dotted name after the
donating call and before any rebind.  Control flow is approximated
linearly by source position (a read earlier in a loop body is not
caught -- the rule is a tripwire for the common straight-line bug, not
a dataflow engine).
"""

from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from ..context import (FunctionUnit, JitSpec, ModuleInfo,
                       ProjectContext, dotted_name)
from ..registry import Rule, register_rule
from ..report import Violation

_SIMPLE_STMTS = (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Expr,
                 ast.Return)


def _enclosing_stmt(unit: FunctionUnit,
                    call: ast.Call) -> Optional[ast.stmt]:
    for node in ast.walk(unit.node):
        if isinstance(node, _SIMPLE_STMTS):
            for sub in ast.walk(node):
                if sub is call:
                    return node
    return None


def _stmt_rebinds(stmt: ast.stmt, name: str) -> bool:
    if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        stack: List[ast.AST] = list(targets)
        while stack:
            tgt = stack.pop()
            if isinstance(tgt, (ast.Tuple, ast.List)):
                stack.extend(tgt.elts)
            elif isinstance(tgt, ast.Starred):
                stack.append(tgt.value)
            elif dotted_name(tgt) == name:
                return True
    return False


def _pos(node: ast.AST) -> Tuple[int, int]:
    return (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))


def _end_pos(node: ast.AST) -> Tuple[int, int]:
    return (getattr(node, "end_lineno", 0) or 0,
            getattr(node, "end_col_offset", 0) or 0)


@register_rule
class DonationAliasing(Rule):
    name = "donation-aliasing"
    description = ("read of a donated argument's binding after a "
                   "donate_argnums call site, before rebinding")

    def check_module(self, mod: ModuleInfo,
                     ctx: ProjectContext) -> List[Violation]:
        out: List[Violation] = []
        for unit in mod.units:
            out.extend(self._check_unit(mod, ctx, unit))
        return out

    def _check_unit(self, mod: ModuleInfo, ctx: ProjectContext,
                    unit: FunctionUnit) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Call):
                continue
            spec = ctx.resolve_jitted_callee(mod, node)
            if spec is None or not spec.donates:
                continue
            callee = dotted_name(node.func) or "<callee>"
            for donated in self._donated_names(node, spec):
                v = self._first_stale_read(mod, unit, node, callee,
                                           donated)
                if v is not None:
                    out.append(v)
        return out

    @staticmethod
    def _donated_names(call: ast.Call, spec: JitSpec) -> List[str]:
        names: List[str] = []
        for idx in spec.donate_argnums:
            if 0 <= idx < len(call.args):
                dn = dotted_name(call.args[idx])
                if dn is not None:
                    names.append(dn)
        for arg in spec.donate_argnames:
            for kw in call.keywords:
                if kw.arg == arg:
                    dn = dotted_name(kw.value)
                    if dn is not None:
                        names.append(dn)
        return names

    def _first_stale_read(self, mod: ModuleInfo, unit: FunctionUnit,
                          call: ast.Call, callee: str,
                          name: str) -> Optional[Violation]:
        # the conventional pattern -- rebinding at the call statement
        # itself -- is always safe regardless of source positions
        stmt = _enclosing_stmt(unit, call)
        if stmt is not None and _stmt_rebinds(stmt, name):
            return None
        after = _end_pos(call)
        events: List[Tuple[Tuple[int, int], str, ast.AST]] = []
        for sub in ast.walk(unit.node):
            if not isinstance(sub, (ast.Name, ast.Attribute)):
                continue
            if dotted_name(sub) != name:
                continue
            if _pos(sub) <= after:
                continue
            kind = ("store" if isinstance(sub.ctx, ast.Store)
                    else "load")
            events.append((_pos(sub), kind, sub))
        for pos, kind, sub in sorted(events, key=lambda e: e[0]):
            if kind == "store":
                return None  # rebound before any read
            return Violation(
                rule=self.name, path=mod.path, line=pos[0],
                col=pos[1],
                message=(f"'{name}' was donated to {callee}() at line "
                         f"{call.lineno} and is read here before being "
                         "rebound; the buffer may already be freed"))
        return None
