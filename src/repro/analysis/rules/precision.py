"""``f64-discipline``: float32 must not leak into exactness-critical code.

The guard-band contract (DESIGN.md) is that ``core/`` and ``index/``
decide clustering *exactly* in float64; float32 appears only inside the
designated kernel-dispatch functions, which center coordinates and
apply the guard band so that f32 only decides provably-certain cases.
A stray ``np.float32`` cast or an f32-vs-f64 comparison anywhere else
silently converts "exact DBSCAN" into "approximately DBSCAN".

Flags, inside ``core/`` and ``index/`` but outside the allowlisted
dispatch functions:

* calls to / references of ``np.float32`` / ``jnp.float32``;
* ``.astype("float32")`` and ``dtype="float32"`` string dtypes;
* comparisons where exactly one side is f32-tainted (a name assigned
  from an expression involving float32) -- the classic mixed-precision
  threshold bug.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from ..context import (FunctionUnit, ModuleInfo, ProjectContext,
                       dotted_name, iter_assignments)
from ..registry import Rule, register_rule
from ..report import Violation

_F32_NAMES = frozenset({
    "np.float32", "jnp.float32", "numpy.float32", "jax.numpy.float32",
})

#: (module relpath suffix, unit qualname) pairs where float32 is the
#: point: the kernel-dispatch layer that owns the guard-band contract.
ALLOWLIST: Set[Tuple[str, str]] = {
    ("core/merging.py", "fast_merging_masked"),
    ("core/grids.py", "build_grids_device"),
    ("index/grit_index.py", "GritIndex._predict_kernel"),
    ("index/device_state.py", "DeviceState.refresh_rows"),
    ("index/device_state.py", "DeviceState.mirror_matches"),
    ("index/device_state.py", "_d2_flat_res"),
    ("index/device_state.py", "_anchors"),
    ("index/device_state.py", "predict_device_async"),
}


def _in_scope(mod: ModuleInfo) -> bool:
    parts = mod.path_parts()
    return "core" in parts or "index" in parts


def _allowlisted(mod: ModuleInfo, unit: FunctionUnit) -> bool:
    for suffix, qual in ALLOWLIST:
        if mod.relpath.endswith(suffix) and unit.qualname == qual:
            return True
    return False


def _mentions_f32(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and \
                dotted_name(sub) in _F32_NAMES:
            return True
        if isinstance(sub, ast.Call):
            if isinstance(sub.func, ast.Attribute) and \
                    sub.func.attr == "astype":
                for arg in sub.args:
                    if isinstance(arg, ast.Constant) and \
                            arg.value == "float32":
                        return True
    return False


@register_rule
class F64Discipline(Rule):
    name = "f64-discipline"
    description = ("float32 cast or mixed f32/f64 comparison in core/ "
                   "or index/ outside the kernel-dispatch allowlist")

    def check_module(self, mod: ModuleInfo,
                     ctx: ProjectContext) -> List[Violation]:
        if not _in_scope(mod):
            return []
        out: List[Violation] = []
        for unit in mod.units:
            if _allowlisted(mod, unit):
                continue
            out.extend(self._check_unit(mod, unit))
        return out

    def _check_unit(self, mod: ModuleInfo,
                    unit: FunctionUnit) -> List[Violation]:
        out: List[Violation] = []
        flagged_funcs: Set[int] = set()
        for node in ast.walk(unit.node):
            if isinstance(node, ast.Call):
                v = self._check_call(mod, node, flagged_funcs)
                if v is not None:
                    out.append(v)
        for node in ast.walk(unit.node):
            if isinstance(node, ast.Attribute) and \
                    id(node) not in flagged_funcs and \
                    dotted_name(node) in _F32_NAMES:
                out.append(Violation(
                    rule=self.name, path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message=f"float32 dtype '{dotted_name(node)}' in "
                            "exactness-critical code; f64 is the "
                            "reference here (guard-band contract)"))
        out.extend(self._check_mixed_compares(mod, unit))
        return out

    def _check_call(self, mod: ModuleInfo, node: ast.Call,
                    flagged_funcs: Set[int]) -> Optional[Violation]:
        func_name = dotted_name(node.func)
        if func_name in _F32_NAMES:
            flagged_funcs.add(id(node.func))
            return Violation(
                rule=self.name, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=f"float32 cast via {func_name}() in "
                        "exactness-critical code; keep core/index "
                        "decisions in f64 or move this into an "
                        "allowlisted dispatch function")
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "astype":
            for arg in node.args:
                if isinstance(arg, ast.Constant) and \
                        arg.value == "float32":
                    return Violation(
                        rule=self.name, path=mod.path,
                        line=node.lineno, col=node.col_offset,
                        message="astype('float32') in "
                                "exactness-critical code")
        for kw in node.keywords:
            if kw.arg == "dtype" and \
                    isinstance(kw.value, ast.Constant) and \
                    kw.value.value == "float32":
                return Violation(
                    rule=self.name, path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message="dtype='float32' in exactness-critical "
                            "code")
        return None

    def _check_mixed_compares(self, mod: ModuleInfo,
                              unit: FunctionUnit) -> List[Violation]:
        tainted: Set[str] = set()
        for names, value, _line in sorted(
                iter_assignments(unit.node), key=lambda t: t[2]):
            if _mentions_f32(value) or any(
                    isinstance(s, ast.Name) and s.id in tainted
                    for s in ast.walk(value)):
                tainted.update(names)

        def side_f32(expr: ast.AST) -> bool:
            if _mentions_f32(expr):
                return True
            return any(isinstance(s, ast.Name) and s.id in tainted
                       for s in ast.walk(expr))

        out: List[Violation] = []
        for node in ast.walk(unit.node):
            if not isinstance(node, ast.Compare):
                continue
            if len(node.comparators) != 1:
                continue
            lhs, rhs = node.left, node.comparators[0]
            if side_f32(lhs) != side_f32(rhs):
                out.append(Violation(
                    rule=self.name, path=mod.path, line=node.lineno,
                    col=node.col_offset,
                    message="comparison mixes an f32-tainted operand "
                            "with an untainted one; mixed-precision "
                            "thresholds break the exactness contract"))
        return out
