"""``repro.analysis``: AST-based invariant linter for the serving stack.

The serving hot path is fast because it layers *conventions* on top of
jax that jax itself cannot enforce: donated buffers must never be read
through a stale alias, float32 may only decide provably-certain cases
(the guard-band contract -- float64 stays the reference), jit keys must
route through pow2 bucketing so recompiles converge, the hot loop must
not host-sync outside its intended block points, and reductions over
PAD/FAR-padded buffers must fold a validity mask first.  Nothing but
reviewer vigilance stops a future change from violating these in a way
the differential tests only catch probabilistically -- so this package
turns each convention into a static rule (stdlib ``ast``, no deps):

* ``donation-aliasing``  -- reads of a donated argument's binding after
  the donating call without reassignment (``rules/donation.py``);
* ``f64-discipline``     -- float32 casts / mixed-precision comparisons
  in ``core/`` and ``index/`` outside the allowlisted kernel-dispatch
  functions (``rules/precision.py``);
* ``recompile-hazard``   -- jitted callables fed raw data-dependent
  shapes that skip the pow2/bucketing helpers, and array-typed values
  in ``static_argnames`` (``rules/recompile.py``);
* ``hot-path-sync``      -- host syncs (``np.asarray`` of a device
  value, ``.item()``, ``block_until_ready``, ``jax.device_get``) inside
  functions reachable from ``ClusterServer.step`` or the ``DeviceState``
  dispatch stages (``rules/hostsync.py``);
* ``sentinel-mask``      -- raw ``min``/``argmin`` reductions in
  ``kernels/`` without a preceding validity-mask fold
  (``rules/sentinel.py``).

Violations are suppressed line by line with a *justified* pragma::

    risky_expression()  # grit-lint: disable=<rule> -- <reason>

(also honoured on the immediately preceding line).  A pragma without a
reason, or naming an unknown rule, never suppresses -- it is itself
reported under the ``pragma`` meta-rule.  Suppressed violations stay in
the report with their reason, so ``--show-suppressed`` is an audit of
every escape hatch in the tree.

CLI: ``python -m repro.analysis --check src`` (exit 0 iff no
unsuppressed violations); the tier-1 suite runs it over the live
``src/repro`` tree, so a PR that breaks an invariant fails fast
(DESIGN.md §8).
"""

from __future__ import annotations

from .registry import Rule, all_rules, get_rule, register_rule, rule_names
from .report import Report, Violation
from .runner import analyze_paths, collect_py_files

__all__ = [
    "Report",
    "Rule",
    "Violation",
    "all_rules",
    "analyze_paths",
    "collect_py_files",
    "get_rule",
    "register_rule",
    "rule_names",
]
