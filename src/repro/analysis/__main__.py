"""CLI: ``python -m repro.analysis --check <path>...``.

Exit codes: 0 -- no unsuppressed violations; 1 -- violations found;
2 -- usage error (no paths / unknown rule).
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .registry import all_rules
from .runner import analyze_paths, split_selection


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the repro serving "
                    "stack (see repro/analysis/__init__.py).")
    parser.add_argument(
        "--check", nargs="+", metavar="PATH", default=None,
        help="files or directories to analyze (e.g. src)")
    parser.add_argument(
        "--select", metavar="RULES", default=None,
        help="comma-separated rule names to run (default: all)")
    parser.add_argument(
        "--show-suppressed", action="store_true",
        help="also print pragma-suppressed findings with their reasons")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name}: {rule.description}")
        return 0
    if not args.check:
        parser.print_usage(sys.stderr)
        print("error: --check PATH... is required "
              "(or --list-rules)", file=sys.stderr)
        return 2

    select = split_selection(args.select) if args.select else None
    try:
        report = analyze_paths(args.check, select=select)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2
    print(report.format(show_suppressed=args.show_suppressed))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
