"""Drive one analysis run: collect files, parse, run rules, suppress.

``analyze_paths`` is the single entry point the CLI and the tier-1
self-run test share.  Unparseable files surface as a ``parse``-rule
violation rather than crashing the run, so one broken file cannot mask
findings elsewhere.
"""

from __future__ import annotations

import os
from typing import Dict, Iterable, List, Optional, Tuple

from .context import ModuleInfo, ProjectContext, build_module
from .pragmas import Pragma, apply_pragmas, parse_pragmas
from .registry import Rule, all_rules, get_rule
from .report import Report, Violation

_SKIP_DIRS = frozenset({"__pycache__", ".git", ".mypy_cache",
                        ".ruff_cache", ".pytest_cache"})


def collect_py_files(paths: Iterable[str]) -> List[str]:
    """Every ``.py`` file under ``paths`` (files pass through verbatim),
    sorted, hidden and cache directories skipped."""
    out: List[str] = []
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                out.append(path)
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(
                d for d in dirs
                if d not in _SKIP_DIRS and not d.startswith("."))
            for fn in sorted(files):
                if fn.endswith(".py"):
                    out.append(os.path.join(root, fn))
    return sorted(dict.fromkeys(out))


def _relpath(path: str, roots: List[str]) -> str:
    best: Optional[str] = None
    for root in roots:
        if os.path.isdir(root):
            try:
                rel = os.path.relpath(path, root)
            except ValueError:  # pragma: no cover - windows drives
                continue
            if not rel.startswith(".."):
                if best is None or len(rel) < len(best):
                    best = rel
    rel = best if best is not None else path
    return rel.replace(os.sep, "/")


def analyze_paths(paths: Iterable[str],
                  select: Optional[Iterable[str]] = None) -> Report:
    """Run every rule (or just ``select``) over the tree under ``paths``
    and return the full :class:`Report`, pragmas applied."""
    roots = [p for p in paths if os.path.isdir(p)]
    files = collect_py_files(paths)
    rules: List[Rule] = (
        [get_rule(n) for n in select] if select else all_rules())
    known = frozenset(r.name for r in rules) | frozenset(
        r.name for r in all_rules())

    modules: List[ModuleInfo] = []
    violations: List[Violation] = []
    pragmas_by_path: Dict[str, Dict[int, Pragma]] = {}
    for path in files:
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
        except OSError as exc:
            violations.append(Violation(
                rule="parse", path=path, line=1, col=0,
                message=f"cannot read file: {exc}"))
            continue
        try:
            mod = build_module(path, _relpath(path, roots), source)
        except SyntaxError as exc:
            violations.append(Violation(
                rule="parse", path=path, line=exc.lineno or 1,
                col=exc.offset or 0,
                message=f"syntax error: {exc.msg}"))
            continue
        modules.append(mod)
        pragmas, malformed = parse_pragmas(
            path, mod.lines, known_rules=known)
        pragmas_by_path[path] = pragmas
        violations.extend(malformed)

    ctx = ProjectContext(modules=modules)
    for rule in rules:
        for mod in modules:
            violations.extend(rule.check_module(mod, ctx))
        violations.extend(rule.check_project(ctx))

    out: List[Violation] = []
    by_path: Dict[str, List[Violation]] = {}
    for v in violations:
        by_path.setdefault(v.path, []).append(v)
    for path, vs in by_path.items():
        out.extend(apply_pragmas(vs, pragmas_by_path.get(path, {})))
    return Report(violations=out, files_checked=len(files))


def split_selection(spec: str) -> Tuple[str, ...]:
    """``"a,b , c"`` -> ``("a", "b", "c")`` (for ``--select``)."""
    return tuple(p.strip() for p in spec.split(",") if p.strip())
