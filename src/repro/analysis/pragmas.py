"""The per-rule pragma escape hatch.

Syntax (one comment, end of the violating line or the line above it)::

    x = f32_thing()  # grit-lint: disable=f64-discipline -- reason here
    # grit-lint: disable=hot-path-sync,recompile-hazard -- shared reason

The reason after ``--`` is *mandatory*: a pragma without one (or naming
an unknown rule) suppresses nothing and is reported under the
``pragma`` meta-rule, so every escape hatch in the tree carries a
written justification the report can surface (``--show-suppressed``).
``disable=all`` suppresses every rule on that line.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, FrozenSet, List, Optional, Tuple

from .report import Violation

_PRAGMA_RE = re.compile(
    r"#\s*grit-lint:\s*disable=([A-Za-z0-9_,\- ]+?)"
    r"(?:\s+--\s*(.*?))?\s*$")


@dataclasses.dataclass(frozen=True)
class Pragma:
    """One parsed ``# grit-lint: disable=...`` comment."""

    line: int
    rules: FrozenSet[str]
    reason: str

    def covers(self, rule: str) -> bool:
        return rule in self.rules or "all" in self.rules


def parse_pragmas(path: str, lines: List[str],
                  known_rules: FrozenSet[str],
                  ) -> Tuple[Dict[int, Pragma], List[Violation]]:
    """Scan source lines for pragmas.

    Returns ``(pragmas_by_line, malformed)``: well-formed pragmas keyed
    by their 1-based line, and a ``pragma``-rule violation for each
    malformed one (missing reason / unknown rule) -- malformed pragmas
    never suppress anything.
    """
    pragmas: Dict[int, Pragma] = {}
    malformed: List[Violation] = []
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if m is None:
            continue
        names = frozenset(
            p.strip() for p in m.group(1).split(",") if p.strip())
        reason = (m.group(2) or "").strip()
        unknown = sorted(n for n in names
                         if n != "all" and n not in known_rules)
        if not reason:
            malformed.append(Violation(
                rule="pragma", path=path, line=i, col=text.index("#"),
                message="pragma has no justification: write "
                        "'# grit-lint: disable=<rule> -- <reason>' "
                        "(a reasonless pragma suppresses nothing)"))
            continue
        if unknown:
            malformed.append(Violation(
                rule="pragma", path=path, line=i, col=text.index("#"),
                message=f"pragma names unknown rule(s) {unknown}; "
                        "it suppresses nothing"))
            continue
        pragmas[i] = Pragma(line=i, rules=names, reason=reason)
    return pragmas, malformed


def find_suppression(pragmas: Dict[int, Pragma], rule: str,
                     line: int) -> Optional[Pragma]:
    """The pragma covering ``rule`` at ``line``, if any.

    A pragma applies to its own line and to the line directly below it
    (so multi-line statements can carry the comment above them).
    """
    for cand in (pragmas.get(line), pragmas.get(line - 1)):
        if cand is not None and cand.covers(rule):
            return cand
    return None


def apply_pragmas(violations: List[Violation],
                  pragmas: Dict[int, Pragma]) -> List[Violation]:
    """Mark each violation suppressed when a justified pragma covers it."""
    out: List[Violation] = []
    for v in violations:
        p = find_suppression(pragmas, v.rule, v.line)
        if p is None:
            out.append(v)
        else:
            out.append(dataclasses.replace(
                v, suppressed=True, reason=p.reason))
    return out
