"""Diagnostics: the :class:`Violation` record and the :class:`Report`.

A violation is one ``file:line:col`` finding of one rule.  Suppression
(via a justified pragma, see ``pragmas.py``) does not delete the
finding -- it stays in the report with ``suppressed=True`` and the
pragma's written reason, so the set of escape hatches in the tree is
itself auditable.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding of one rule at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str = ""  # the pragma's justification when suppressed

    def format(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        tag = f"[{self.rule}]"
        if self.suppressed:
            return f"{loc}: {tag} suppressed ({self.reason}): {self.message}"
        return f"{loc}: {tag} {self.message}"


@dataclasses.dataclass
class Report:
    """Every finding of one analysis run, suppressed ones included."""

    violations: List[Violation] = dataclasses.field(default_factory=list)
    files_checked: int = 0

    @property
    def active(self) -> List[Violation]:
        """Unsuppressed findings -- what fails the check."""
        return [v for v in self.violations if not v.suppressed]

    @property
    def suppressed(self) -> List[Violation]:
        """Findings silenced by a justified pragma (reason attached)."""
        return [v for v in self.violations if v.suppressed]

    @property
    def ok(self) -> bool:
        return not self.active

    def counts_by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.active:
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def format(self, show_suppressed: bool = False) -> str:
        lines: List[str] = []
        ordered = sorted(self.violations,
                         key=lambda v: (v.path, v.line, v.col, v.rule))
        for v in ordered:
            if v.suppressed and not show_suppressed:
                continue
            lines.append(v.format())
        n_act, n_sup = len(self.active), len(self.suppressed)
        if n_act:
            per_rule = ", ".join(f"{k}: {n}" for k, n in
                                 sorted(self.counts_by_rule().items()))
            lines.append(
                f"{n_act} violation(s) in {self.files_checked} file(s) "
                f"({per_rule}); {n_sup} suppressed")
        else:
            lines.append(
                f"clean: {self.files_checked} file(s), 0 violations "
                f"({n_sup} suppressed by justified pragma)")
        return "\n".join(lines)
