"""Unified mutation plane for a fitted :class:`GritIndex`: one *delta
engine* behind both :meth:`insert` and :meth:`delete`.

Both mutation directions perturb a fitted state the same way -- through
the offset-stencil of the grids they touch -- so both run the same
direction-parameterized stages:

1. **touched -> stencil closure**: the grids holding mutated rows, plus
   their grid-tree neighborhood ``Nei(touched)`` (any point within eps
   of a mutated point lives there -- the paper's stencil bound).
2. **core recompute** over the closure, from scratch against full
   own+stencil candidate sets, filtered to live rows.  Direction prunes
   the candidates: insertion is monotone up (only non-core rows can
   gain), deletion monotone down (only core rows can lose); a grid with
   ``live_count >= MinPts`` short-circuits either way (its diagonal is
   eps, so every live member is core from the own count alone).
3. **merge re-decision** at *changed-core-set* grids, maintaining the
   persistent core-grid **merge graph** (``GritIndex.merge_edges``): a
   MinDist decision depends on nothing but the two core sets and is
   monotone in them, so under insertion existing edges stay valid and
   only missing candidate pairs are decided, while under deletion no
   new edge can appear and only the *present* edges incident to a
   changed grid are re-decided.
4. **label reconciliation** by connected components over the merge
   graph (grid-level, hence cheap: min-label propagation over G nodes).
   Every core takes its component's label; components keep the smallest
   previous label they contain, splits keep it on the smallest-root
   side and mint fresh ids for the rest, brand-new components mint
   fresh ids -- so unaffected clusters keep their ids bit-stably.
5. **border pass**: the nearest-live-core test for exactly the rows a
   mutation can flip -- new non-core rows and noise in the changed
   stencil under insertion; labeled non-core rows in the changed
   stencil plus any row whose previous cluster id split or vanished
   under deletion.

Exactness under deletion (DESIGN.md §7).  DBSCAN is **not** monotone
under deletion -- removing one bridge point can split a cluster in two
-- but the perturbation is still local at the *grid* level: counts
shrink only in touched grids, so cores demote only in
``touched ∪ Nei(touched)``; a MinDist decision changes only where a
core *set* changed, so merge edges vanish only at changed grids; and
because the merge graph is persistent and complete (every true edge is
stored, not just a spanning subset), recomputing connected components
over it after the local edge repair is *globally* exhaustive -- a split
anywhere manifests as the component falling apart, even when the two
halves are far from the deleted rows.  Borders are exhaustive by the
same stencil argument: a border's witness core lies in its own stencil,
so a border outside ``Nei(changed)`` whose cluster id survived intact
needs no distance work at all (its witness provably survived), and
every other candidate is re-tested.  Deleted rows tombstone first
(``alive=False``; physical rows keep the CSR layout intact) and a
threshold-triggered :func:`compact` re-packs the flat arrays -- an
order-preserving mask compress, cheaper than insert's re-sort.

Everything runs in float64 with the brute oracle's distance expression,
so either mutation followed by a read-out is label-conformant with a
from-scratch ``cluster()`` on the surviving set.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.grids import group_rows
from repro.core.merging import fast_merging

__all__ = ["MutationLog", "build_merge_graph", "grid_components",
           "insert_batch", "delete_ids", "compact",
           "relabel_local_components"]


# --------------------------------------------------------------------------
# mutation log (replica replay)
# --------------------------------------------------------------------------

class MutationLog:
    """Ordered record of an index's *top-level* mutation batches.

    The delta engine is deterministic: applying the same ``(insert,
    delete)`` batches in the same order to the same starting state
    reproduces the fitted state bit for bit.  That makes the mutation
    *arguments* a sufficient replication log -- no per-row state diffs
    on the wire -- and the engine itself the replay operator.  A
    read-only :class:`~repro.index.replica.ReplicaIndex` clones the
    primary's snapshot and then replays ``since(cursor)``.

    Records are ``(op, payload)`` with ``op`` in ``{"insert",
    "delete", "split", "merge"}`` and ``payload`` the verbatim batch
    (``[m, d]`` float64 coordinates / raw requested arrival ids --
    rejected ids replay to the same rejections, so they stay in the
    record / the ``[1]`` shard index of a sharded topology op, which
    must replay too: in the localized regime a topology op re-mints
    label ids, and a replica that skipped it would drift in the id
    space even though the partition agrees).  ``base`` is the
    op sequence number of the first retained record: :meth:`truncate`
    drops a replayed prefix without renumbering, so replica cursors
    stay valid as long as they are >= ``base``.
    """

    def __init__(self, base: int = 0):
        self.base = int(base)
        self.records: List[Tuple[str, np.ndarray]] = []

    @property
    def end(self) -> int:
        """Sequence number one past the last recorded op."""
        return self.base + len(self.records)

    def __len__(self) -> int:
        return len(self.records)

    def append(self, op: str, payload: np.ndarray) -> None:
        if op not in ("insert", "delete", "split", "merge"):
            raise ValueError(f"unknown mutation-log op {op!r}")
        self.records.append((op, np.asarray(payload).copy()))

    def since(self, cursor: int) -> List[Tuple[str, np.ndarray]]:
        """The records a replica at ``cursor`` still has to replay.

        Raises ``ValueError`` when the prefix up to ``cursor`` was
        already truncated away -- the replica is too stale to catch up
        and must re-clone from a fresh snapshot.
        """
        if cursor < self.base:
            raise ValueError(
                f"mutation-log cursor {cursor} predates the log base "
                f"{self.base}: the prefix was truncated; re-clone the "
                f"replica from a fresh snapshot")
        return self.records[cursor - self.base:]

    def truncate(self, keep_from: int) -> int:
        """Drop records before op ``keep_from`` (bounded retention);
        returns how many were dropped.  Sequence numbers are stable:
        ``base`` advances instead of renumbering."""
        drop = min(max(keep_from - self.base, 0), len(self.records))
        if drop:
            del self.records[:drop]
            self.base += drop
        return drop


# --------------------------------------------------------------------------
# persistent merge graph
# --------------------------------------------------------------------------

def _core_count_per_grid(index) -> np.ndarray:
    """Live core points per grid (from the core CSR cache)."""
    _, _, ccounts = index._core_ranges()
    return ccounts


_PROD_CAP = 4096       # |S_a|*|S_b| beyond which FastMerging wins
_FLAT_CHUNK = 2 ** 21  # flat distance evals per vectorized chunk


def _bbox_survivors(index, pairs: np.ndarray) -> np.ndarray:
    """Tier-1 axis-aligned core-bbox gap reject, shared by the host and
    device edge deciders: per-grid core sets are eps-diameter-bounded,
    so the bound is tight and kills most far-offset stencil pairs
    without any distance work.  The reject threshold carries a 1+1e-12
    guard so a knife-edge pair can never be lost to the sum's rounding;
    survivors must be decided by the exact expression.  Returns the
    indices into ``pairs`` that survive.
    """
    core_rows, cstarts, ccounts = index._core_ranges()
    pts, eps = index.points, index.eps
    cpts = pts[core_rows]
    # per-grid core bboxes: reduceat over the core-bearing grids only
    # -- their cstarts are exactly the segment starts of the core CSR,
    # so the last segment runs to len(core_rows) (clamping zero-core
    # grids' starts instead would shear the final grid's segment and
    # shrink its bbox, falsely rejecting true edges)
    cg = np.flatnonzero(ccounts > 0)
    if len(cg) == 0:
        return np.empty(0, np.int64)
    lo = np.empty((len(ccounts), pts.shape[1]))
    hi = np.empty_like(lo)
    lo[cg] = np.minimum.reduceat(cpts, cstarts[cg], axis=0)
    hi[cg] = np.maximum.reduceat(cpts, cstarts[cg], axis=0)
    a, b = pairs[:, 0], pairs[:, 1]
    gap = np.maximum(0.0, np.maximum(lo[a] - hi[b], lo[b] - hi[a]))
    return np.flatnonzero(
        (gap * gap).sum(1) <= eps * eps * (1 + 1e-12))


def _decide_edges_batch(index, pairs: np.ndarray,
                        ctr: Dict[str, int]) -> np.ndarray:
    """Exact MinDist(S_a, S_b) <= eps for many grid pairs at once.

    Three tiers, cheapest first, all on the oracle's float64 d2
    expression: (1) the vectorized core-bbox gap reject
    (:func:`_bbox_survivors`); (2) one flat broadcast over every
    surviving pair with a small core-set product (the common case --
    one numpy call per ~2M evals instead of one Python call per pair);
    (3) FastMerging (Algorithm 5) for the rare huge products, where
    its pruning wins.  Returns a bool hit mask aligned with ``pairs``.
    """
    if len(pairs) == 0:
        return np.zeros(0, bool)
    core_rows, cstarts, ccounts = index._core_ranges()
    pts, eps = index.points, index.eps
    eps2 = eps * eps
    a, b = pairs[:, 0], pairs[:, 1]
    hit = np.zeros(len(pairs), bool)
    rem = _bbox_survivors(index, pairs)
    if len(rem) == 0:
        return hit
    # fixed-shape sample accept: ANY pair of cores within eps proves
    # the edge, so an 8x8 probe (clamped repeats for smaller sets)
    # confirms most true edges in one vectorized shot
    sa = core_rows[cstarts[a[rem]][:, None]
                   + np.minimum(np.arange(8)[None, :],
                                ccounts[a[rem]][:, None] - 1)]
    sb = core_rows[cstarts[b[rem]][:, None]
                   + np.minimum(np.arange(8)[None, :],
                                ccounts[b[rem]][:, None] - 1)]
    d2s = ((pts[sa][:, :, None, :] - pts[sb][:, None, :, :]) ** 2
           ).sum(-1)
    ctr["dist_evals"] += d2s.size
    confirmed = d2s.reshape(len(rem), -1).min(1) <= eps2
    hit[rem[confirmed]] = True
    rem = rem[~confirmed]
    if len(rem) == 0:
        return hit
    prod = ccounts[a[rem]] * ccounts[b[rem]]
    big = prod > _PROD_CAP
    for i in rem[big]:
        hit[i] = fast_merging(pts[index.grid_core_rows(pairs[i, 0])],
                              pts[index.grid_core_rows(pairs[i, 1])],
                              eps)
    sm = rem[~big]
    prod = prod[~big]
    bounds = np.searchsorted(np.cumsum(prod), np.arange(
        _FLAT_CHUNK, int(prod.sum()) + _FLAT_CHUNK, _FLAT_CHUNK))
    for s, e in zip(np.concatenate([[0], bounds[:-1]]), bounds):
        if s == e:
            continue
        p = sm[s:e]
        na, nb_ = ccounts[a[p]], ccounts[b[p]]
        pp = na * nb_
        off = np.cumsum(pp) - pp
        total = int(pp.sum())
        pair_of = np.repeat(np.arange(len(p)), pp)
        within = np.arange(total) - off[pair_of]
        ai = within // nb_[pair_of]
        bi = within - ai * nb_[pair_of]
        A = core_rows[cstarts[a[p]][pair_of] + ai]
        B = core_rows[cstarts[b[p]][pair_of] + bi]
        d2 = ((pts[A] - pts[B]) ** 2).sum(1)
        ctr["dist_evals"] += d2.size
        hit[p] = np.minimum.reduceat(d2, off) <= eps2
    return hit


def _decide_edges(index, pairs: np.ndarray,
                  ctr: Dict[str, int]) -> np.ndarray:
    """Route MinDist decisions to the device plane when the index holds
    a resident :class:`~repro.index.device_state.DeviceState` (kernel
    pair-minima under the guard band, host float64 for the uncertain
    pairs -- decision-identical by construction)."""
    ds = getattr(index, "device_state", None)
    if ds is None:
        return _decide_edges_batch(index, pairs, ctr)
    from . import device_state
    return device_state.decide_edges_device(index, ds, pairs, ctr)


def build_merge_graph(index) -> np.ndarray:
    """Decide the full core-grid merge graph of the current state.

    One MinDist decision per unordered neighbor pair of core grids --
    the cost shape of a fit's merging phase.  Run once (lazily) per
    index lifetime; mutations maintain the result incrementally.
    """
    ccnt = _core_count_per_grid(index)
    cg = np.flatnonzero(ccnt > 0)
    if len(cg) == 0:
        return np.zeros((0, 2), np.int64)
    G = index.num_grids
    ip, nb, _ = index.tree.query(index.ids[cg], include_self=False)
    src = np.repeat(cg, np.diff(ip))
    ok = (ccnt[nb] > 0) & (src < nb)       # each unordered pair once
    key = np.unique(src[ok] * G + nb[ok])
    pairs = np.stack([key // G, key % G], 1)
    if len(pairs) == 0:
        return np.zeros((0, 2), np.int64)
    ctr: Dict[str, int] = {"dist_evals": 0}
    return pairs[_decide_edges(index, pairs, ctr)]


def grid_components(num_grids: int,
                    edges: Optional[np.ndarray]) -> np.ndarray:
    """Connected components over the grid merge graph.

    Vectorized min-label propagation with pointer jumping (the host
    twin of ``repro.core.labels.label_propagation``): O(E) work per
    round, O(log G) rounds.  Returns [G] component representative =
    smallest grid index in the component (isolated grids map to self).
    """
    lab = np.arange(num_grids, dtype=np.int64)
    if edges is None or len(edges) == 0:
        return lab
    a, b = edges[:, 0], edges[:, 1]
    while True:
        m = np.minimum(lab[a], lab[b])
        new = lab.copy()
        np.minimum.at(new, a, m)
        np.minimum.at(new, b, m)
        new = new[new]
        new = new[new]                     # pointer jumping
        if np.array_equal(new, lab):
            return lab
        lab = new


# --------------------------------------------------------------------------
# shared stages (direction: +1 insert, -1 delete)
# --------------------------------------------------------------------------

def _recompute_cores(index, affected, direction: int,
                     ctr: Dict[str, int]) -> np.ndarray:
    """Stage 2 dispatcher: device twin when a resident state is
    attached (flip-set-identical -- see ``recompute_cores_device``),
    host float64 loop otherwise."""
    ds = getattr(index, "device_state", None)
    if ds is None:
        return _recompute_cores_host(index, affected, direction, ctr)
    from . import device_state
    return device_state.recompute_cores_device(
        index, ds, affected, direction, ctr)


def _recompute_cores_host(index, affected, direction: int,
                          ctr: Dict[str, int]) -> np.ndarray:
    """Stage 2: re-derive core status inside the stencil closure.

    Returns the sorted-order rows whose flag flipped (promotions under
    +1, demotions under -1); flips are applied to ``index.core`` in
    place.  Counts run against *live* rows only, neighbor grids in
    offset-ascending order with the MinPts early exit.  Monotonicity
    prunes the closure up front: under insertion only grids holding a
    live non-core row can change, under deletion only grids below the
    all-core bar (``live_count < MinPts``) that still hold a core.
    """
    pts, core, alive = index.points, index.core, index.alive
    starts, counts = index.starts, index.counts
    live_counts, min_pts = index.live_counts, index.min_pts
    eps2 = index.eps * index.eps
    ccnt = _core_count_per_grid(index)
    if direction > 0:
        need = affected[live_counts[affected] > ccnt[affected]]
    else:
        need = affected[(live_counts[affected] < min_pts)
                        & (ccnt[affected] > 0)]
    if len(need) == 0:
        return np.empty(0, np.int64)
    ip, nb, _ = index.tree.query(index.ids[need], include_self=False)
    flips = []
    for k, g in enumerate(need):
        own = np.arange(starts[g], starts[g] + counts[g])
        own = own[alive[own]]
        if direction > 0:
            cand = own[~core[own]]
            if live_counts[g] >= min_pts:      # all-live-core shortcut
                if len(cand):
                    core[cand] = True
                    flips.append(cand)
                continue
        else:
            cand = own[core[own]]
        if len(cand) == 0:
            continue
        p = pts[cand]
        cnt = np.full(len(cand), live_counts[g], np.int64)
        undecided = cnt < min_pts
        for ng in nb[ip[k]:ip[k + 1]]:         # offset-ascending
            if not undecided.any():
                break
            crows = np.arange(starts[ng], starts[ng] + counts[ng])
            crows = crows[alive[crows]]
            if len(crows) == 0:
                continue
            d2 = ((p[undecided][:, None, :]
                   - pts[crows][None, :, :]) ** 2).sum(-1)
            ctr["dist_evals"] += d2.size
            cnt[undecided] += (d2 <= eps2).sum(1)
            undecided = cnt < min_pts
        flip = cand[cnt >= min_pts] if direction > 0 \
            else cand[cnt < min_pts]
        if len(flip):
            core[flip] = not (direction < 0)
            flips.append(flip)
    return (np.concatenate(flips) if flips
            else np.empty(0, np.int64))


def _update_merge_edges(index, changed: np.ndarray, direction: int,
                        ctr: Dict[str, int]) -> None:
    """Stage 3: repair the persistent merge graph at changed grids.

    Both directions exploit monotonicity of MinDist over the core
    sets.  Insert: cores were only added, so every stored edge stays
    valid and only *missing* candidate pairs (changed grid x core
    neighbor, from the tree) are decided.  Delete: cores were only
    removed, so no new edge can appear and only the *present* edges
    incident to a changed grid are re-decided -- no stencil sweep at
    all.
    """
    G = index.num_grids
    edges = index.merge_edges
    ccnt = _core_count_per_grid(index)
    in_changed = np.zeros(G, bool)
    in_changed[changed] = True
    if direction < 0:
        if not len(edges):
            return
        inc = in_changed[edges[:, 0]] | in_changed[edges[:, 1]]
        keep, pairs = edges[~inc], edges[inc]
        # an endpoint with no surviving cores loses its edges outright
        pairs = pairs[(ccnt[pairs[:, 0]] > 0) & (ccnt[pairs[:, 1]] > 0)]
    else:
        keep = edges
        ch = changed[ccnt[changed] > 0]
        pairs = np.zeros((0, 2), np.int64)
        if len(ch):
            ip, nb, _ = index.tree.query(index.ids[ch],
                                         include_self=False)
            src = np.repeat(ch, np.diff(ip))
            ok = (ccnt[nb] > 0) & (src != nb)
            a = np.minimum(src[ok], nb[ok])
            b = np.maximum(src[ok], nb[ok])
            if len(a):
                key = np.unique(a * G + b)
                pairs = np.stack([key // G, key % G], 1)
        if len(keep) and len(pairs):
            known = np.isin(pairs[:, 0] * G + pairs[:, 1],
                            keep[:, 0] * G + keep[:, 1])
            pairs = pairs[~known]
    ctr["merge_checks"] += len(pairs)
    new = pairs[_decide_edges(index, pairs, ctr)]
    merged = np.concatenate([keep, new])
    if len(merged):
        # keep ∪ new is duplicate-free by construction (insert decides
        # only missing pairs; delete's re-decided pairs are disjoint
        # from keep) -- a key argsort restores canonical order without
        # the structured-unique sort
        merged = merged[np.argsort(merged[:, 0] * G + merged[:, 1],
                                   kind="stable")]
    index.merge_edges = merged


def _relabel_components(index, grid_of: np.ndarray,
                        ctr: Dict[str, int]) -> np.ndarray:
    """Stage 4: core labels from connected components over the graph.

    Returns ``remap`` ([old_next_label] int64): for every previous
    cluster id, its new id, ``-1`` if the cluster vanished, or ``-2``
    if it split across components (borders carrying such an id must be
    re-tested -- direct remapping would glue them to one half blindly).
    """
    G = index.num_grids
    lab = index.labels
    core_rows = np.flatnonzero(index.core)
    comp = grid_components(G, index.merge_edges)
    old_next = index.next_label
    remap = np.full(old_next, -1, np.int64)
    final = np.full(G, -1, np.int64)
    roots = np.unique(comp[grid_of[core_rows]]) if len(core_rows) \
        else np.empty(0, np.int64)
    lc = core_rows[lab[core_rows] >= 0]
    if len(lc):
        # dedupe (root, label) pairs through one flat int64 key: a
        # single 1-D sort, much cheaper than a structured axis-unique
        key = np.unique(comp[grid_of[lc]] * np.int64(old_next)
                        + lab[lc])
        pairs = np.stack([key // old_next, key % old_next], 1)
    else:
        pairs = np.zeros((0, 2), np.int64)
    if len(pairs):
        # keeper(L) = smallest component root containing old label L
        o = np.lexsort((pairs[:, 0], pairs[:, 1]))
        pl = pairs[o]
        first = np.ones(len(pl), bool)
        first[1:] = pl[1:, 1] != pl[:-1, 1]
        keeper = np.full(old_next, -1, np.int64)
        keeper[pl[first, 1]] = pl[first, 0]
        n_roots = np.zeros(old_next, np.int64)
        np.add.at(n_roots, pairs[:, 1], 1)
        # a root's final label: the smallest old label it keeps
        kept = pairs[keeper[pairs[:, 1]] == pairs[:, 0]]
        sent = np.iinfo(np.int64).max
        best = np.full(G, sent, np.int64)
        np.minimum.at(best, kept[:, 0], kept[:, 1])
        final[best < sent] = best[best < sent]
        labs = np.unique(pairs[:, 1])
        remap[labs] = np.where(n_roots[labs] == 1,
                               final[keeper[labs]], -2)
    fresh = roots[final[roots] < 0]
    final[fresh] = old_next + np.arange(len(fresh))
    index.next_label = old_next + len(fresh)
    if len(core_rows):
        old = lab[core_rows]
        lab[core_rows] = final[comp[grid_of[core_rows]]]
        ctr["relabeled"] += int((old != lab[core_rows]).sum())
    return remap


def _reconcile_noncore(index, grid_of: np.ndarray, changed: np.ndarray,
                       remap: np.ndarray, direction: int,
                       new_rows: Optional[np.ndarray],
                       ctr: Dict[str, int]) -> None:
    """Stage 4b/5: remap surviving border labels, re-test the rest.

    Splits the live non-core rows into direct remaps (their previous
    cluster id survived intact AND their stencil holds no changed grid,
    so their witness core provably survived) and suspects that take the
    nearest-live-core test from scratch.
    """
    G = index.num_grids
    lab, core, alive = index.labels, index.core, index.alive
    in_stencil = np.zeros(G, bool)
    if len(changed):
        in_stencil[changed] = True
        ip, nb, _ = index.tree.query(index.ids[changed],
                                     include_self=False)
        in_stencil[nb] = True
    nc = np.flatnonzero(alive & ~core & (lab >= 0))
    suspects = []
    if len(nc):
        mapped = remap[lab[nc]]
        if direction > 0:
            # insertion never splits or vanishes a cluster within one
            # fit lineage, so labeled borders remap directly -- EXCEPT
            # in a shard freshly built by a topology op (split/merge
            # pools a slab-local view), where one pooled cluster id can
            # span several *local* components: those borders arrive
            # here with a negative remap and must take the
            # from-scratch nearest-core test instead of inheriting the
            # sentinel verbatim
            risky = mapped < 0
            ctr["relabeled"] += int((mapped[~risky]
                                     != lab[nc[~risky]]).sum())
            lab[nc[~risky]] = mapped[~risky]
            if risky.any():
                suspects.append(nc[risky])
        else:
            risky = (mapped < 0) | in_stencil[grid_of[nc]]
            ctr["relabeled"] += int((mapped[~risky]
                                     != lab[nc[~risky]]).sum())
            lab[nc[~risky]] = mapped[~risky]
            suspects.append(nc[risky])
    if direction > 0:
        noise = np.flatnonzero(alive & ~core & (lab < 0)
                               & in_stencil[grid_of])
        suspects.append(noise)
        if new_rows is not None:
            suspects.append(new_rows[~core[new_rows]])
    rows = (np.unique(np.concatenate(suspects)) if suspects
            else np.empty(0, np.int64))
    _border_pass(index, rows, grid_of, ctr)


def _border_pass(index, rows: np.ndarray, grid_of: np.ndarray,
                 ctr: Dict[str, int]) -> None:
    """Stage 5 dispatcher: device twin when a resident state is
    attached (label-identical -- see ``border_pass_device``), host
    float64 loop otherwise."""
    ds = getattr(index, "device_state", None)
    if ds is None:
        return _border_pass_host(index, rows, grid_of, ctr)
    from . import device_state
    return device_state.border_pass_device(index, ds, rows, grid_of, ctr)


def _border_pass_host(index, rows: np.ndarray, grid_of: np.ndarray,
                      ctr: Dict[str, int]) -> None:
    """Nearest-live-core test for ``rows`` (sorted, non-core, live):
    within eps of a core -> that core's (already final) label, else
    noise.  Candidates from the own+stencil core CSR -- complete by
    the stencil bound."""
    if len(rows) == 0:
        return
    pts, lab = index.points, index.labels
    starts, counts = index.starts, index.counts
    eps2 = index.eps * index.eps
    lab[rows] = -1
    cgrids = np.unique(grid_of[rows])
    ip, nb, _ = index.tree.query(index.ids[cgrids], include_self=False)
    for k, g in enumerate(cgrids):
        rr = rows[(rows >= starts[g]) & (rows < starts[g] + counts[g])]
        crows = np.concatenate(
            [index.grid_core_rows(g)]
            + [index.grid_core_rows(g2) for g2 in nb[ip[k]:ip[k + 1]]])
        if len(crows) == 0:
            continue
        d2 = ((pts[rr][:, None, :] - pts[crows][None, :, :]) ** 2).sum(-1)
        ctr["dist_evals"] += d2.size
        j = d2.argmin(axis=1)
        hit = d2[np.arange(len(rr)), j] <= eps2
        lab[rr[hit]] = lab[crows[j[hit]]]


def _grid_of_rows(index) -> np.ndarray:
    return np.repeat(np.arange(index.num_grids, dtype=np.int64),
                     index.counts)


def _ensure_graph(index, ctr: Dict[str, Any]) -> None:
    """Lazy-build the merge graph when a mutation first needs it.

    Called *after* the core flags are current, so the from-scratch
    build IS the repaired graph and stage 3 can be skipped for this
    mutation (``merge_graph_built`` marks the one-time cost)."""
    index.merge_edges = build_merge_graph(index)
    ctr["merge_graph_built"] = True


# --------------------------------------------------------------------------
# insert
# --------------------------------------------------------------------------

def insert_batch(index, batch) -> Dict[str, Any]:
    """Splice ``batch`` ([m, d]) into ``index`` in place.

    Returns the **unified mutation stats schema** (shared key-for-key
    with ``ShardedGritIndex.insert``, which shard-sums the counters):

    * ``op``: ``"insert"``.
    * ``inserted``: points spliced in (== len(batch)).
    * ``n`` / ``n_live``: physical rows / live points after the splice.
    * ``touched_grids`` / ``affected_grids`` / ``changed_grids``: grids
      holding new rows / their stencil closure / grids whose core set
      changed.
    * ``newly_core``: points promoted to core.
    * ``merge_checks`` / ``dist_evals``: FastMerging decisions and
      float64 distance evaluations spent.
    * ``relabeled``: rows whose cluster id changed (splices/merges).
    * ``t_total``: wall seconds.

    Single-index extras (not part of the shared schema):
    ``newly_core_arrival`` (arrival ids of the promotions -- what a
    multi-shard caller dedupes ghost copies with), ``id_shifted``
    (lattice translation happened), ``merge_graph_built`` (this call
    paid the one-time lazy graph build).

    Raises ``ValueError`` on shape/NaN problems, mirroring
    ``cluster()``'s input validation.
    """
    t0 = time.perf_counter()
    B = np.asarray(batch, np.float64)
    if B.ndim != 2 or B.shape[1] != index.d:
        raise ValueError(f"insert batch must be [m, {index.d}], "
                         f"got {B.shape}")
    m = B.shape[0]
    ctr: Dict[str, Any] = dict(merge_checks=0, dist_evals=0, relabeled=0,
                               merge_graph_built=False)
    if m == 0:
        return _insert_stats(index, t0, ctr, inserted=0, touched=0,
                             affected=0, changed=0,
                             newly_core=np.empty(0, np.int64),
                             shifted=False)
    if not np.isfinite(B).all():
        raise ValueError("insert batch contains non-finite coordinates")

    # ---- 1. identifiers (fit-time formula) + origin shift ---------------
    with obs.span("delta.insert.identifiers"):
        new_ids = index.query_ids(B)
        neg = np.minimum(new_ids.min(axis=0), 0)
        shifted = bool((neg < 0).any())
        if shifted:
            # keep the stored-ids >= 0 invariant by translating the
            # integer lattice -- never by moving the float origin, which
            # could re-cell existing points through rounding.  A uniform
            # shift preserves lex order, so grid numbering (and the merge
            # graph's endpoints) are untouched.
            shift = (-neg).astype(np.int64)
            index.ids = index.ids + shift[None, :]
            new_ids = new_ids + shift[None, :]
            index.id_shift = index.id_shift + shift

    # ---- 2. merge into the sorted structure -----------------------------
    with obs.span("delta.insert.splice"):
        n_old, G_old = index.n, index.num_grids
        old_grid_of = _grid_of_rows(index)
        old_pt_ids = np.repeat(index.ids, index.counts, axis=0)   # [n, d]
        all_ids = np.concatenate([old_pt_ids, new_ids])
        order, sids, starts, counts, grid_of = group_rows(all_ids)
        index.points = np.concatenate([index.points, B])[order]
        index.arrival = np.concatenate(
            [index.arrival,
             index.next_arrival + np.arange(m, dtype=np.int64)])[order]
        index.next_arrival += m
        index.core = np.concatenate([index.core, np.zeros(m, bool)])[order]
        index.alive = np.concatenate([index.alive, np.ones(m, bool)])[order]
        index.labels = np.concatenate(
            [index.labels, np.full(m, -1, np.int64)])[order]
        index.ids = sids[starts]
        index.starts, index.counts = starts, counts
        index.live_counts = np.bincount(
            grid_of, weights=index.alive, minlength=len(starts)
            ).astype(np.int64)
        if index.merge_edges is not None and G_old:
            # re-sorting renumbers grids; old grids survive (their rows
            # do), so map each old index to its new one through any of
            # its rows and carry the edge list over
            old_rows = order < n_old
            old_to_new = np.empty(G_old, np.int64)
            old_to_new[old_grid_of[order[old_rows]]] = grid_of[old_rows]
            if len(index.merge_edges):
                index.merge_edges = old_to_new[index.merge_edges]
        index.invalidate()
        is_new = order >= n_old                                   # sorted
        ds = getattr(index, "device_state", None)
        if ds is not None:
            # splice rewrote the row layout: structural re-upload (also
            # folds the new coordinates into the error-band span)
            ds.refresh_rows(index)

    # ---- 3. core recompute over the touched stencil ---------------------
    with obs.span("delta.insert.cores"):
        tree = index.tree
        touched = np.unique(grid_of[is_new])
        ip_t, nb_t, _ = tree.query(index.ids[touched], include_self=False)
        affected = np.unique(np.concatenate([touched, nb_t]))
        newly_core = _recompute_cores(index, affected, +1, ctr)
        index.invalidate(keep_tree=True)  # core CSR is stale now

    # ---- 4. merge-graph repair at changed-core-set grids ----------------
    with obs.span("delta.insert.merge_repair"):
        changed = (np.unique(grid_of[newly_core]) if len(newly_core)
                   else np.empty(0, np.int64))
        if index.merge_edges is None:
            _ensure_graph(index, ctr)     # post-splice state == repaired
        elif len(changed):
            _update_merge_edges(index, changed, +1, ctr)

    # ---- 5. label reconciliation + border pass --------------------------
    with obs.span("delta.insert.reconcile"):
        remap = _relabel_components(index, grid_of, ctr)
        _reconcile_noncore(index, grid_of, changed, remap, +1,
                           np.flatnonzero(is_new), ctr)
        if ds is not None:
            ds.refresh_small(index)       # CSR + merge-edge mirrors

    reg = obs.registry()
    reg.counter("delta.insert.count").inc()
    reg.counter("delta.insert.points").inc(m)
    reg.counter("delta.dist_evals").inc(int(ctr["dist_evals"]))
    reg.counter("delta.merge_checks").inc(int(ctr["merge_checks"]))
    return _insert_stats(index, t0, ctr, inserted=m,
                         touched=len(touched), affected=len(affected),
                         changed=len(changed), newly_core=newly_core,
                         shifted=shifted)


def _insert_stats(index, t0, ctr, *, inserted, touched, affected,
                  changed, newly_core, shifted) -> Dict[str, Any]:
    return {
        "op": "insert", "inserted": int(inserted),
        "n": index.n, "n_live": index.n_live,
        "touched_grids": int(touched), "affected_grids": int(affected),
        "changed_grids": int(changed),
        "newly_core": int(len(newly_core)),
        "newly_core_arrival": index.arrival[newly_core],
        "merge_checks": int(ctr["merge_checks"]),
        "dist_evals": int(ctr["dist_evals"]),
        "relabeled": int(ctr["relabeled"]),
        "id_shifted": bool(shifted),
        "merge_graph_built": bool(ctr["merge_graph_built"]),
        # device-path timing split (0.0 on the host path); excluded
        # from the differential stats comparison, like t_total
        "t_pack": float(ctr.get("t_pack", 0.0)),
        "t_kernel": float(ctr.get("t_kernel", 0.0)),
        "band_fallback": int(ctr.get("band_fallback", 0)),
        "t_total": time.perf_counter() - t0,
    }


# --------------------------------------------------------------------------
# delete
# --------------------------------------------------------------------------

def delete_ids(index, arrival_ids) -> Dict[str, Any]:
    """Exactly remove the points with the given arrival ids, in place.

    Ids that are unknown or already deleted are *rejected* (reported,
    not raised): deployed delete traffic -- TTL expiry racing explicit
    erasure, replayed requests -- carries them routinely.

    Returns the unified mutation stats schema (see
    :func:`insert_batch`) with ``op="delete"`` and the delete-specific
    keys: ``requested`` / ``deleted`` / ``rejected`` /
    ``rejected_ids``, ``demoted`` + ``demoted_arrival`` (cores that
    lost the MinPts bar; the direction twin of insert's
    ``newly_core``/``newly_core_arrival``), and ``compacted`` (this
    call crossed ``compact_threshold`` and re-packed).
    """
    t0 = time.perf_counter()
    ids = np.unique(np.asarray(arrival_ids, np.int64).ravel())
    ctr: Dict[str, Any] = dict(merge_checks=0, dist_evals=0, relabeled=0,
                               merge_graph_built=False)
    rows = index.rows_of_arrival(ids)
    ok = rows >= 0
    rejected = ids[~ok]
    rows = np.sort(rows[ok])
    if len(rows) == 0:
        return _delete_stats(index, t0, ctr, requested=len(ids),
                             deleted=0, rejected=rejected, touched=0,
                             affected=0, changed=0,
                             demoted=np.empty(0, np.int64),
                             compacted=False)

    # ---- 1. tombstone -----------------------------------------------------
    with obs.span("delta.delete.tombstone"):
        grid_of = _grid_of_rows(index)
        was_core_grids = np.unique(grid_of[rows[index.core[rows]]])
        index.alive[rows] = False
        index.core[rows] = False
        index.labels[rows] = -1
        np.subtract.at(index.live_counts, grid_of[rows], 1)
        index.invalidate(keep_tree=True)  # ids untouched: tree survives
        ds = getattr(index, "device_state", None)
        if ds is not None:
            ds.mark_dead(rows)            # donated tombstone scatter

    # ---- 2. demotions over the touched stencil --------------------------
    with obs.span("delta.delete.demotions"):
        tree = index.tree
        touched = np.unique(grid_of[rows])
        ip_t, nb_t, _ = tree.query(index.ids[touched], include_self=False)
        affected = np.unique(np.concatenate([touched, nb_t]))
        demoted = _recompute_cores(index, affected, -1, ctr)
        demoted_arrival = index.arrival[demoted]
        index.invalidate(keep_tree=True)

    # ---- 3. merge-graph repair at changed-core-set grids ----------------
    # (a grid whose core was deleted outright changed too, even with no
    # demotion -- its surviving core set is smaller)
    with obs.span("delta.delete.merge_repair"):
        changed = np.unique(np.concatenate(
            [was_core_grids,
             grid_of[demoted] if len(demoted) else np.empty(0, np.int64)]))
        if index.merge_edges is None:
            _ensure_graph(index, ctr)
        elif len(changed):
            _update_merge_edges(index, changed, -1, ctr)

    # ---- 4. components + border reconciliation --------------------------
    with obs.span("delta.delete.components"):
        remap = _relabel_components(index, grid_of, ctr)
        _reconcile_noncore(index, grid_of, changed, remap, -1, None, ctr)

    # ---- 5. threshold-triggered compaction ------------------------------
    with obs.span("delta.delete.compaction"):
        compacted = False
        if index.dead_fraction > index.compact_threshold:
            compact(index)                # refreshes the mirror itself
            compacted = True
        elif ds is not None:
            ds.refresh_small(index)

    reg = obs.registry()
    reg.counter("delta.delete.count").inc()
    reg.counter("delta.delete.points").inc(len(rows))
    reg.counter("delta.dist_evals").inc(int(ctr["dist_evals"]))
    reg.counter("delta.merge_checks").inc(int(ctr["merge_checks"]))
    if compacted:
        reg.counter("delta.compactions").inc()
    return _delete_stats(index, t0, ctr, requested=len(ids),
                         deleted=len(rows), rejected=rejected,
                         touched=len(touched), affected=len(affected),
                         changed=len(changed), demoted=demoted_arrival,
                         compacted=compacted)


def _delete_stats(index, t0, ctr, *, requested, deleted, rejected,
                  touched, affected, changed, demoted,
                  compacted) -> Dict[str, Any]:
    return {
        "op": "delete", "requested": int(requested),
        "deleted": int(deleted), "rejected": int(len(rejected)),
        "rejected_ids": np.asarray(rejected, np.int64),
        "n": index.n, "n_live": index.n_live,
        "touched_grids": int(touched), "affected_grids": int(affected),
        "changed_grids": int(changed), "demoted": int(len(demoted)),
        # arrival ids of the demotions (direction twin of insert's
        # newly_core_arrival): lets a multi-shard caller attribute
        # demotions to owned vs ghost copies
        "demoted_arrival": np.asarray(demoted, np.int64),
        "merge_checks": int(ctr["merge_checks"]),
        "dist_evals": int(ctr["dist_evals"]),
        "relabeled": int(ctr["relabeled"]),
        "compacted": bool(compacted),
        "merge_graph_built": bool(ctr["merge_graph_built"]),
        "t_pack": float(ctr.get("t_pack", 0.0)),
        "t_kernel": float(ctr.get("t_kernel", 0.0)),
        "band_fallback": int(ctr.get("band_fallback", 0)),
        "t_total": time.perf_counter() - t0,
    }


# --------------------------------------------------------------------------
# label localization (multi-shard support)
# --------------------------------------------------------------------------

def relabel_local_components(index) -> Dict[str, Any]:
    """Re-mint every cluster id as a fresh per-*local*-component id.

    A sharded caller needs the invariant that one raw label means one
    connected component of *this* index's merge graph (and label
    arenas are disjoint across shards): a raw id shared by two shards
    -- or by two locally-disconnected pieces whose connection runs
    through another shard's coverage -- cannot be split by any global
    map once a deletion severs it.  This pass renames: each cored
    component takes a fresh id from ``next_label`` and every labeled
    non-core row re-takes the nearest-core test (its previous witness
    is still within eps, so it stays labeled -- by whichever local
    component that witness landed in).  Pure rename + witness-map
    rebuild on the caller's side: the read-out partition is unchanged.
    """
    t0 = time.perf_counter()
    ctr: Dict[str, Any] = dict(merge_checks=0, dist_evals=0, relabeled=0,
                               merge_graph_built=index.merge_edges is None)
    index.ensure_merge_graph()
    grid_of = _grid_of_rows(index)
    comp = grid_components(index.num_grids, index.merge_edges)
    core_rows = np.flatnonzero(index.core)
    roots = (np.unique(comp[grid_of[core_rows]]) if len(core_rows)
             else np.empty(0, np.int64))
    final = np.full(index.num_grids, -1, np.int64)
    final[roots] = index.next_label + np.arange(len(roots))
    index.next_label += len(roots)
    if len(core_rows):
        index.labels[core_rows] = final[comp[grid_of[core_rows]]]
    nc = np.flatnonzero(index.alive & ~index.core & (index.labels >= 0))
    _border_pass(index, nc, grid_of, ctr)
    return {"op": "localize", "components": int(len(roots)),
            "merge_graph_built": bool(ctr["merge_graph_built"]),
            "dist_evals": int(ctr["dist_evals"]),
            "t_total": time.perf_counter() - t0}


# --------------------------------------------------------------------------
# compaction
# --------------------------------------------------------------------------

def compact(index) -> Dict[str, Any]:
    """Re-pack the flat arrays, dropping tombstoned rows and empty grids.

    An order-preserving mask compress: rows stay lex-sorted, so no
    re-sort is needed; grid renumbering is a cumulative sum over the
    kept-grid mask and the merge graph's endpoints ride through it
    (an edge endpoint always holds live cores, so it is never
    dropped).  Arrival ids are preserved -- they are never reused, so
    ``delete`` and the sharded registries stay unambiguous across
    compactions.
    """
    t0 = time.perf_counter()
    removed = index.n - index.n_live
    if removed == 0:
        return {"op": "compact", "removed": 0, "grids_dropped": 0,
                "n": index.n, "t_total": time.perf_counter() - t0}
    keep = index.alive
    keep_grid = index.live_counts > 0
    new_of_old = np.cumsum(keep_grid) - 1
    if index.merge_edges is not None and len(index.merge_edges):
        index.merge_edges = new_of_old[index.merge_edges]
    grids_dropped = int((~keep_grid).sum())
    index.points = index.points[keep]
    index.arrival = index.arrival[keep]
    index.core = index.core[keep]
    index.labels = index.labels[keep]
    index.alive = np.ones(int(keep.sum()), bool)
    index.ids = index.ids[keep_grid]
    index.counts = index.live_counts[keep_grid].copy()
    index.live_counts = index.counts.copy()
    index.starts = np.cumsum(index.counts) - index.counts
    index.invalidate()
    ds = getattr(index, "device_state", None)
    if ds is not None:
        ds.refresh_rows(index)            # row layout rewritten
        ds.refresh_small(index)
    return {"op": "compact", "removed": int(removed),
            "grids_dropped": grids_dropped, "n": index.n,
            "t_total": time.perf_counter() - t0}
