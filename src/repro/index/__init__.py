"""index: the fitted ``GritIndex`` (fit once, serve point queries and
micro-batch inserts without refitting).

    from repro.engine import cluster
    res = cluster(points, eps=3000.0, min_pts=10, return_index=True)
    labels = res.index.predict(new_points)       # exact, no refit
    res.index.insert(micro_batch)                # incremental splice
    snap = res.index.snapshot()                  # flat arrays, savez-able

See DESIGN.md §7 for the state layout and exactness arguments.
"""

from .grit_index import GritIndex, PredictCaps
from .insert import insert_batch

__all__ = ["GritIndex", "PredictCaps", "insert_batch", "fit_index"]


def fit_index(points, eps: float, min_pts: int, *, engine: str = "auto",
              **opts) -> GritIndex:
    """Fit-and-index in one call: ``cluster(..., return_index=True).index``."""
    from repro.engine import cluster
    return cluster(points, eps, min_pts, engine=engine, return_index=True,
                   **opts).index
