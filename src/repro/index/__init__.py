"""index: the fitted ``GritIndex`` (fit once, serve point queries and
micro-batch inserts without refitting) and its multi-shard sibling
``ShardedGritIndex`` (the serving artifact of a distributed fit).

    from repro.engine import cluster
    res = cluster(points, eps=3000.0, min_pts=10, return_index=True)
    labels = res.index.predict(new_points)       # exact, no refit
    res.index.insert(micro_batch)                # incremental splice
    res.index.delete(arrival_ids)                # exact removal
    snap = res.index.snapshot()                  # flat arrays, savez-able

    from repro.index import fit_sharded
    sidx = fit_sharded(points, eps, min_pts, mesh=mesh)  # per-slab shards
    labels = sidx.predict(new_points)            # slab-routed, exact
    sidx.delete(arrival_ids)                     # owner + ghost removal

Both mutation directions run through one delta engine
(``repro.index.delta``) that maintains the persistent core-grid merge
graph.  See DESIGN.md §7 for the state layouts and exactness
arguments.
"""

from .delta import (MutationLog, build_merge_graph, compact, delete_ids,
                    insert_batch)
from .grit_index import GritIndex, PredictCaps
from .replica import ReplicaIndex, make_replicas
from .sharded import LabelMap, ShardedGritIndex, fit_sharded

__all__ = ["GritIndex", "LabelMap", "MutationLog", "PredictCaps",
           "ReplicaIndex", "ShardedGritIndex", "build_merge_graph",
           "compact", "delete_ids", "fit_index", "fit_sharded",
           "insert_batch", "make_replicas"]


def fit_index(points, eps: float, min_pts: int, *, engine: str = "auto",
              **opts) -> GritIndex:
    """Fit-and-index in one call: ``cluster(..., return_index=True).index``."""
    from repro.engine import cluster
    return cluster(points, eps, min_pts, engine=engine, return_index=True,
                   **opts).index
