"""Sharded fitted index: the serving artifact of a *distributed* fit.

One host's :class:`GritIndex` stops fitting exactly in the regime the
paper targets ("very large databases"), so the sharded index keeps the
fitted state *per slab*: one ``GritIndex`` per dim-0 slab (the same
slab partition the distributed fit used -- Wang/Gu/Shun's observation
that the fitted spatial structure is the artifact worth keeping across
machines), plus a global label map stitching the slabs' cluster ids
together.  de Berg et al.'s grid argument makes the routing cheap:
locating a query's owning slab is one binary search over the cut
coordinates.

**Ghost bands.**  Each shard stores its own slab's points *plus* ghost
copies of every foreign point within ``2 * eps`` of its slab range --
the same halo width as the distributed fit.  The width argument
(DESIGN.md §5) carries over verbatim: any point of slab k has its whole
eps-neighborhood inside [slab - eps, slab + eps) ⊂ shard k's coverage,
so every *own*-point decision (core status, merges, border assignment)
a shard makes is exact using only its local state -- at fit time and
under every later :meth:`insert`.

**Routing exactness** (predict).  A query owned by slab k can only have
core points within eps inside shard k's coverage, and every such core
carries an exact flag there (its neighborhood is complete in shard k),
so the owner's answer is already the brute-oracle assignment rule.
Queries within ``2 * eps`` of a cut additionally consult the adjacent
shard(s); answers combine by smallest squared distance with owner
priority on exact ties -- the neighbor can only confirm (its candidate
set is a subset of the true core set), so the combined answer stays
pinned bit-identical to the oracle rule (host mode: same float64
expression).

**Insert + re-reconciliation.**  A micro-batch is bucketed by owning
slab; each new point is spliced into its owner shard and, when it lies
in a neighbor's ghost band, into that neighbor too -- so every shard's
local state stays self-consistently exact (the fit-time invariant).
Label arenas never collide: each touched shard allocates fresh cluster
ids from the shared ``next_label`` sequence.  What *can* diverge is
cluster identity across shards (a merge deep inside one slab is
invisible to its neighbor), and exactly as in the distributed fit every
such divergence is witnessed by a shared core point near a cut: the
re-reconciliation pass walks the shared copies adjacent to the touched
shards and unions their label pairs into the global label map (edges
only at genuinely core shared points -- border labels are
order-dependent and must never stitch clusters).  Read-outs and
predictions resolve raw per-shard labels through the map.

**Delete.**  A delete removes a point's authoritative copy *and* every
ghost copy in one call, so each shard's local state stays
self-consistently exact (the same invariant insert maintains); the
shard-local removals run through the delta engine
(``repro.index.delta``), which handles demotions, merge-edge loss and
component splits per shard.  Cross-shard identity can now *split* --
a union-only map cannot express that -- so after a delete the global
``LabelMap`` is **rebuilt from the surviving shared-core witness
edges**: exactly the pairs the incremental pass would union, collected
over every boundary registry.  Any cross-shard connection that
survived the delete is still witnessed by a shared core near a cut
(the fit-time argument, unchanged), so the rebuilt map is exhaustive;
anything no longer witnessed falls apart into the per-shard components
the delta engine already split.  The registries are boundary-sized, so
the rebuild costs O(ghost copies), not O(n).

**Topology ops** (split / merge).  The slab partition itself is
mutable: :meth:`split_shard` re-cuts one slab at a fresh interior
grid line and :meth:`merge_shards` concatenates two adjacent slabs --
the load-adaptive rebalancing primitive (``repro.dist.rebalance``).
Both are *pure re-partitions of existing physical copies*: shard k's
own points plus its ghost band cover every sub-slab's coverage
([sub - 2eps, sub + 2eps) ⊂ [slab - 2eps, slab + 2eps)), so the new
shard(s) are built by ``GritIndex.from_fit`` over the pooled copies
with their *canonical* (map-resolved) labels and owner-exact core
flags -- no distance work, no identity change.  Cross-shard identity
is then re-derived by the same witness-edge map rebuild the delete
path uses: exhaustive in the insert-only regime because witnesses only
accumulate (so read-outs stay **bit-identical**), and exhaustive under
the localization invariant otherwise (the new shards re-mint per local
component, so the partition is preserved while ids may re-mint, same
as any delete).  Every op is recorded in ``cut_history`` (snapshot v3).

**Mutation log.**  ``enable_mutation_log()`` attaches a
:class:`~repro.index.delta.MutationLog`: every top-level insert /
delete / topology batch is appended verbatim, and ``ops_applied`` is
the replay cursor a read-only :class:`~repro.index.replica.ReplicaIndex`
catches up from.  The delta engine is deterministic, so a replica that
cloned this index's snapshot and replayed the log serves ``predict``
bit-identically to the primary.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.dist.sharding import owner_of_slab, slab_cuts

from .delta import MutationLog
from .grit_index import GritIndex
from .snapshot_io import check_version, load_snapshot, save_snapshot

# v2 carries deletions (tombstoned global ids appear as owner_shard ==
# -1 and the per-shard sub-snapshots are v2); v3 adds the topology-op
# cut history and the mutation-log cursor (``ops_applied``); v1/v2
# snapshots restore unchanged (empty history, cursor 0).
_SHARDED_SNAPSHOT_VERSION = 3
_SHARDED_ACCEPTED = (1, 2, 3)


class LabelMap:
    """Union-find over global cluster ids (root = smallest id).

    The global label map of the sharded index: per-shard labels stay
    raw; merges discovered by cross-shard reconciliation only touch
    this map, so re-reconciliation never rewrites per-shard arrays.
    """

    def __init__(self, n: int, parent: Optional[np.ndarray] = None):
        self.parent = (np.arange(n, dtype=np.int64) if parent is None
                       else np.asarray(parent, np.int64).copy())

    def __len__(self) -> int:
        return len(self.parent)

    def grow(self, n: int) -> None:
        if n > len(self.parent):
            self.parent = np.concatenate(
                [self.parent,
                 np.arange(len(self.parent), n, dtype=np.int64)])

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:            # path compression
            p[x], x = root, p[x]
        return int(root)

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if rb < ra:                    # smallest id wins: deterministic
            ra, rb = rb, ra
        self.parent[rb] = ra
        return True

    def resolve(self, labels: np.ndarray) -> np.ndarray:
        """Map raw labels to canonical roots (vectorized; -1 passes)."""
        lab = np.asarray(labels, np.int64)
        out = lab.copy()
        m = lab >= 0
        cur = out[m]
        while True:
            nxt = self.parent[cur]
            if np.array_equal(nxt, cur):
                break
            cur = nxt
        out[m] = cur
        return out


@dataclasses.dataclass
class ShardedGritIndex:
    """Per-slab ``GritIndex`` shards + the global label map.

    Bookkeeping (all arrival-order):

    * ``own_rows[k]`` / ``own_gids[k]`` -- shard k's rows that are
      *owned* points, and the global arrival index of each (the
      original point order of the fit, inserts appended);
    * ``ghost_rows[k]`` / ``ghost_gids[k]`` -- shard k's ghost copies
      and the global ids they duplicate (the shared-point registry the
      re-reconciliation walks);
    * ``owner_shard`` / ``owner_row`` -- for every global id, where its
      authoritative (owner) copy lives.
    """

    shards: List[GritIndex]
    cuts: np.ndarray               # [K-1] float64 dim-0 slab boundaries
    eps: float
    min_pts: int
    next_label: int                # shared fresh-cluster-id sequence
    label_map: LabelMap
    own_rows: List[np.ndarray]
    own_gids: List[np.ndarray]
    ghost_rows: List[np.ndarray]
    ghost_gids: List[np.ndarray]
    owner_shard: np.ndarray        # [n] int64 (-1 = deleted)
    owner_row: np.ndarray          # [n] int64
    # True once per-shard labels are per-local-component with disjoint
    # arenas (the invariant deletion needs; see _ensure_localized)
    localized: bool = False
    # Topology-op provenance: ("split" | "merge", shard, cut coordinate)
    # in application order.  Snapshot v3 carries it (with the mutation-
    # log cursor below), so a restored index knows how its cuts evolved
    # from the fit-time partition.
    cut_history: List[Tuple[str, int, float]] = dataclasses.field(
        default_factory=list)
    # Replication plane: ops_applied counts the top-level mutation /
    # topology batches absorbed (the replica replay cursor, snapshot
    # v3); the attached log itself is runtime state, never snapshotted.
    ops_applied: int = 0
    mutation_log: Optional[MutationLog] = dataclasses.field(
        default=None, repr=False, compare=False)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_global_fit(cls, points, eps: float, min_pts: int, labels,
                        core=None, n_shards: int = 4
                        ) -> "ShardedGritIndex":
        """Shard one finished global fit (arrival-order labels/core).

        ``labels`` must be globally reconciled cluster ids (what the
        distributed engine returns); ``core`` the exact global core
        flags (``None`` falls back to per-shard grid-based
        identification -- exact for owned points, whose neighborhoods
        are complete per shard).  Slabs are cut on grid lines along
        dim 0 (the distributed fit's partition); empty slabs are
        coalesced into their neighbor, so every shard is non-empty.
        """
        pts = np.asarray(points, np.float64)
        n, _ = pts.shape
        labels = np.asarray(labels, np.int64)
        core = None if core is None else np.asarray(core, bool)
        _, _, cut_coords = slab_cuts(pts, eps, max(int(n_shards), 1))
        cuts = np.asarray(cut_coords, np.float64)
        cuts = np.unique(cuts[np.isfinite(cuts)])
        owner = owner_of_slab(pts[:, 0], cuts)
        present = np.unique(owner)
        if len(present) < len(cuts) + 1:
            # drop cuts bounding empty slabs: the boundary between two
            # consecutive *present* slabs is the left edge of the later
            cuts = np.asarray([cuts[b - 1] for b in present[1:]],
                              np.float64)
            owner = owner_of_slab(pts[:, 0], cuts)
        K = len(cuts) + 1
        band = 2.0 * float(eps)
        x0 = pts[:, 0]
        shards, own_rows, own_gids = [], [], []
        ghost_rows, ghost_gids = [], []
        owner_row = np.empty(n, np.int64)
        for k in range(K):
            lo = cuts[k - 1] if k > 0 else -np.inf
            hi = cuts[k] if k < K - 1 else np.inf
            own_sel = owner == k
            ghost_sel = (~own_sel) & (x0 >= lo - band) & (x0 < hi + band)
            oidx = np.flatnonzero(own_sel)
            gidx = np.flatnonzero(ghost_sel)
            sel = np.concatenate([oidx, gidx])
            shards.append(GritIndex.from_fit(
                pts[sel], eps, min_pts, labels=labels[sel],
                core=None if core is None else core[sel]))
            own_rows.append(np.arange(len(oidx), dtype=np.int64))
            own_gids.append(oidx)
            ghost_rows.append(len(oidx) + np.arange(len(gidx),
                                                    dtype=np.int64))
            ghost_gids.append(gidx)
            owner_row[oidx] = np.arange(len(oidx), dtype=np.int64)
        next_label = int(labels.max(initial=-1)) + 1
        return cls(shards=shards, cuts=cuts, eps=float(eps),
                   min_pts=int(min_pts), next_label=next_label,
                   label_map=LabelMap(next_label), own_rows=own_rows,
                   own_gids=own_gids, ghost_rows=ghost_rows,
                   ghost_gids=ghost_gids,
                   owner_shard=owner.astype(np.int64),
                   owner_row=owner_row)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    @property
    def n(self) -> int:
        """Global ids ever assigned (deleted ids included -- ids are
        never reused, so this is also the next fresh id)."""
        return int(len(self.owner_shard))

    @property
    def n_live(self) -> int:
        """Surviving owned points (each physical point counted once)."""
        return int((self.owner_shard >= 0).sum())

    @property
    def d(self) -> int:
        return self.shards[0].d

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def num_grids(self) -> int:
        """Total non-empty grids over all shards (ghost bands double-
        count boundary grids -- a capacity figure, not a partition)."""
        return int(sum(s.num_grids for s in self.shards))

    def _slab_bounds(self, k: int):
        lo = self.cuts[k - 1] if k > 0 else -np.inf
        hi = self.cuts[k] if k < self.num_shards - 1 else np.inf
        return lo, hi

    def labels_arrival(self) -> np.ndarray:
        """Canonical labels of the *live* points in global arrival
        order (fit order, inserts appended, deleted ids omitted) --
        per-shard raw labels resolved through the map."""
        out = np.full(self.n, -1, np.int64)
        for k, idx in enumerate(self.shards):
            out[self.own_gids[k]] = idx.labels_at(self.own_rows[k])
        return self.label_map.resolve(out[self.owner_shard >= 0])

    def core_arrival(self) -> np.ndarray:
        """Core flags of the live points in global arrival order
        (owner copies: exact)."""
        out = np.zeros(self.n, bool)
        for k, idx in enumerate(self.shards):
            out[self.own_gids[k]] = idx.core_at(self.own_rows[k])
        return out[self.owner_shard >= 0]

    def arrival_live(self) -> np.ndarray:
        """Sorted global ids of the surviving points (what
        :meth:`labels_arrival` rows correspond to)."""
        return np.flatnonzero(self.owner_shard >= 0)

    # ------------------------------------------------------------------
    # mutation log (replica replay)
    # ------------------------------------------------------------------

    def enable_mutation_log(self) -> MutationLog:
        """Attach (or return) the replication log.

        From this call on, every top-level :meth:`insert` /
        :meth:`delete` / topology batch is appended verbatim; the log
        base is the current :attr:`ops_applied`, so a replica cloned
        from a snapshot taken *now* starts exactly at the log base."""
        if self.mutation_log is None:
            self.mutation_log = MutationLog(base=self.ops_applied)
        return self.mutation_log

    def _log_mutation(self, op: str, payload: np.ndarray) -> None:
        self.ops_applied += 1
        if self.mutation_log is not None:
            self.mutation_log.append(op, payload)

    # ------------------------------------------------------------------
    # predict
    # ------------------------------------------------------------------

    def predict(self, queries, *, mode: str = "auto", chunk: int = 2048,
                stats: Optional[dict] = None) -> np.ndarray:
        """Slab-routed exact predict (see module docstring).

        Buckets queries by owning slab, consults the adjacent shard(s)
        for queries within ``2 * eps`` of a cut, runs *one* batched
        per-shard predict per consulted shard, and combines by nearest
        core (owner priority on exact ties).  Returns [m] int64
        canonical labels; -1 noise.
        """
        q = np.asarray(queries, np.float64)
        if q.ndim != 2 or q.shape[1] != self.d:
            raise ValueError(
                f"queries must be [m, {self.d}], got {q.shape}")
        if q.shape[0] == 0:
            return np.empty(0, np.int64)
        if not np.isfinite(q).all():
            raise ValueError("queries contain non-finite coordinates")
        m = q.shape[0]
        x0 = q[:, 0]
        owner = owner_of_slab(x0, self.cuts)
        band = 2.0 * self.eps
        out = np.full(m, -1, np.int64)
        best_d2 = np.full(m, np.inf, np.float64)
        per_shard: List[int] = []
        consulted = 0
        shard_mode = None
        for k in range(self.num_shards):
            lo, hi = self._slab_bounds(k)
            sel = np.flatnonzero((x0 >= lo - band) & (x0 < hi + band))
            per_shard.append(int(len(sel)))
            if len(sel) == 0:
                continue
            pstats: Dict[str, Any] = {}
            lab_k, d2_k = self.shards[k].predict(
                q[sel], mode=mode, chunk=chunk, stats=pstats,
                return_d2=True)
            shard_mode = pstats.get("mode", shard_mode)
            consulted += len(sel)
            is_owner = owner[sel] == k
            # the owner's answer is exact; a neighbor may only confirm
            # (strict improvement is impossible -- defensively allowed)
            take = is_owner | (d2_k < best_d2[sel])
            rows = sel[take]
            out[rows] = lab_k[take]
            best_d2[rows] = d2_k[take]
        if stats is not None:
            owned = np.bincount(owner, minlength=self.num_shards)
            stats.update(
                mode=shard_mode, n_queries=m,
                shards=self.num_shards, consulted=consulted,
                multi_routed=int(consulted - m),
                per_shard=per_shard,
                owned_per_shard=[int(c) for c in owned])
        return self.label_map.resolve(out)

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------

    _SUMMED = ("touched_grids", "affected_grids", "changed_grids",
               "merge_checks", "dist_evals", "relabeled")

    def insert(self, batch) -> Dict[str, Any]:
        """Micro-batch insert confined to the touched shards.

        Buckets by owning slab, splices each sub-batch into its owner
        shard (plus ghost copies into neighbors whose band contains the
        point), then re-reconciles cluster identity over the shared
        points adjacent to the touched shards (module docstring).

        Returns the **unified mutation stats schema** -- the same keys
        as ``GritIndex.insert`` (see
        :func:`repro.index.delta.insert_batch`), with the per-grid /
        per-eval counters summed over the touched shards,
        ``newly_core`` deduplicated to owned copies, and ``id_shifted``
        true if any shard translated its lattice.  Sharded extras:
        ``shards_touched``, ``reconcile_unions`` and ``per_shard``
        (the raw per-shard breakdowns).
        """
        t0 = time.perf_counter()
        B = np.asarray(batch, np.float64)
        if B.ndim != 2 or B.shape[1] != self.d:
            raise ValueError(f"insert batch must be [m, {self.d}], "
                             f"got {B.shape}")
        m = B.shape[0]
        if m == 0:
            return {"op": "insert", "inserted": 0, "n": self.n,
                    "n_live": self.n_live,
                    **{f: 0 for f in self._SUMMED},
                    "newly_core": 0, "id_shifted": False,
                    "shards_touched": [], "reconcile_unions": 0,
                    "per_shard": [],
                    "t_total": time.perf_counter() - t0}
        if not np.isfinite(B).all():
            raise ValueError("insert batch contains non-finite "
                             "coordinates")
        x0 = B[:, 0]
        owner = owner_of_slab(x0, self.cuts)
        gid0 = self.n
        band = 2.0 * self.eps
        owner_row_new = np.empty(m, np.int64)
        touched: List[int] = []
        per_shard: List[Dict[str, Any]] = []
        for k in range(self.num_shards):
            lo, hi = self._slab_bounds(k)
            own_sel = owner == k
            ghost_sel = (~own_sel) & (x0 >= lo - band) & (x0 < hi + band)
            if not (own_sel.any() or ghost_sel.any()):
                continue
            oidx = np.flatnonzero(own_sel)
            gidx = np.flatnonzero(ghost_sel)
            shard = self.shards[k]
            # the delta engine assigns shard-local arrival ids from
            # next_arrival (NOT from n: after a delete + compaction the
            # two diverge, ids are never reused)
            n_before = shard.next_arrival
            # fresh cluster ids come from the shared global sequence,
            # so two shards can never mint the same id
            shard.next_label = self.next_label
            st = shard.insert(B[np.concatenate([oidx, gidx])])
            self.next_label = shard.next_label
            rows = n_before + np.arange(len(oidx) + len(gidx),
                                        dtype=np.int64)
            self.own_rows[k] = np.concatenate(
                [self.own_rows[k], rows[:len(oidx)]])
            self.own_gids[k] = np.concatenate(
                [self.own_gids[k], gid0 + oidx])
            self.ghost_rows[k] = np.concatenate(
                [self.ghost_rows[k], rows[len(oidx):]])
            self.ghost_gids[k] = np.concatenate(
                [self.ghost_gids[k], gid0 + gidx])
            owner_row_new[oidx] = rows[:len(oidx)]
            touched.append(k)
            # count promotions on owned copies only -- a shared (ghost)
            # copy is promoted in every shard that holds it, and summing
            # raw per-shard counts would double-count those points
            nc_own = int((~np.isin(st["newly_core_arrival"],
                                   self.ghost_rows[k])).sum())
            per_shard.append({
                "shard": k, "own": int(len(oidx)),
                "ghost": int(len(gidx)), "newly_core_own": nc_own,
                "newly_core": st["newly_core"],
                "id_shifted": st["id_shifted"],
                **{f: st[f] for f in self._SUMMED}})
        self.owner_shard = np.concatenate([self.owner_shard, owner])
        self.owner_row = np.concatenate([self.owner_row, owner_row_new])
        self.label_map.grow(self.next_label)
        unions = self._reconcile(touched)
        self._log_mutation("insert", B)
        return {"op": "insert", "inserted": m, "n": self.n,
                "n_live": self.n_live,
                **{f: sum(s[f] for s in per_shard)
                   for f in self._SUMMED},
                "newly_core": int(sum(s["newly_core_own"]
                                      for s in per_shard)),
                "id_shifted": any(s["id_shifted"] for s in per_shard),
                "shards_touched": touched,
                "reconcile_unions": unions, "per_shard": per_shard,
                "t_total": time.perf_counter() - t0}

    def _reconcile(self, touched: List[int]) -> int:
        """Incremental edge re-reconciliation over shared points.

        For every ghost copy in (or owned by) a touched shard whose
        authoritative copy is core, union the two copies' raw labels in
        the global map.  Core witnesses only: a non-core shared point's
        border labels are legitimately order-dependent and must never
        merge clusters.
        """
        if not touched:
            return 0
        return self._union_witness_edges(self.label_map, set(touched))

    def _union_witness_edges(self, lm: LabelMap,
                             touched: Optional[set] = None) -> int:
        """Union every surviving shared-core witness pair into ``lm``.

        The one enumeration both reconciliation directions share: walk
        the ghost registries, and for every ghost copy whose
        authoritative (owner) copy is core and both copies carry
        labels, union the (owner label, ghost label) pair.  Core
        witnesses only -- border labels are order-dependent and must
        never stitch clusters.  ``touched`` restricts the walk to
        ghosts in (or owned by) those shards -- insert's incremental
        patch; ``None`` walks every registry -- delete's rebuild.
        Returns the union count.
        """
        unions = 0
        for k, shard in enumerate(self.shards):
            gg = self.ghost_gids[k]
            if len(gg) == 0:
                continue
            own_s = self.owner_shard[gg]
            if touched is None or k in touched:
                mask = np.ones(len(gg), bool)
            else:
                mask = np.isin(own_s, np.asarray(sorted(touched)))
            if not mask.any():
                continue
            glab = shard.labels_at(self.ghost_rows[k][mask])
            gid = gg[mask]
            own_s = own_s[mask]
            for o in np.unique(own_s):
                sel = own_s == o
                orow = self.owner_row[gid[sel]]
                olab = self.shards[int(o)].labels_at(orow)
                ocore = self.shards[int(o)].core_at(orow)
                ok = ocore & (olab >= 0) & (glab[sel] >= 0) \
                    & (olab != glab[sel])
                for a, b in zip(olab[ok], glab[sel][ok]):
                    unions += lm.union(int(a), int(b))
        return int(unions)

    # ------------------------------------------------------------------
    # delete
    # ------------------------------------------------------------------

    def _ensure_localized(self) -> None:
        """Re-mint per-shard labels as per-local-component ids (once).

        A global fit hands every shard the *global* cluster ids, which
        is fine for insert-only traffic (components only ever merge,
        and the union-only map absorbs that).  Deletion breaks it: a
        raw id shared by two shards -- or spanning two locally
        disconnected pieces whose connection runs through a third
        shard's coverage -- cannot be split by any label *map* once the
        connection is severed, because both uses resolve through the
        same id.  So before the first delete, every shard re-mints its
        labels per local merge-graph component from the shared fresh
        sequence (arenas disjoint forever after), and cross-shard
        identity moves entirely into the witness-edge map, where a
        rebuild CAN express splits.  A pure rename: the read-out
        partition is unchanged.  Mutations maintain the invariant
        inductively (insert merges keep one id per component; delete
        splits mint fresh ids for the non-keeper sides).
        """
        if self.localized:
            return
        from .delta import relabel_local_components
        for shard in self.shards:
            shard.next_label = self.next_label
            relabel_local_components(shard)
            self.next_label = shard.next_label
        self.localized = True
        self._rebuild_label_map()

    def delete(self, arrival_ids) -> Dict[str, Any]:
        """Exactly remove points by global arrival id, across shards.

        Every physical copy goes at once -- the owner copy and each
        ghost copy in a neighbor's band -- so per-shard local state
        stays self-consistently exact; shard-local removal runs through
        the delta engine (demotions, merge-edge loss, component
        splits, threshold compaction).  Because deletion can *split*
        cross-shard clusters, the global label map is then rebuilt from
        the surviving shared-core witness edges (module docstring),
        not union-patched.

        Unknown / already-deleted ids are rejected (reported, not
        raised).  Returns the unified mutation stats schema with
        ``op="delete"`` (per-grid counters shard-summed, ``demoted``
        deduplicated to owned copies) plus ``rejected`` /
        ``rejected_ids``, ``shards_touched``, ``reconcile_unions``
        (unions in the rebuilt map) and ``per_shard``.
        """
        t0 = time.perf_counter()
        self._ensure_localized()
        ids = np.unique(np.asarray(arrival_ids, np.int64).ravel())
        valid = (ids >= 0) & (ids < self.n)
        valid[valid] = self.owner_shard[ids[valid]] >= 0
        gids, rejected = ids[valid], ids[~valid]
        kill = np.zeros(self.n, bool)
        kill[gids] = True
        touched: List[int] = []
        per_shard: List[Dict[str, Any]] = []
        for k, shard in enumerate(self.shards):
            own_m = kill[self.own_gids[k]]
            ghost_m = kill[self.ghost_gids[k]]
            if not (own_m.any() or ghost_m.any()):
                continue
            shard.next_label = self.next_label
            st = shard.delete(np.concatenate(
                [self.own_rows[k][own_m], self.ghost_rows[k][ghost_m]]))
            self.next_label = shard.next_label
            # count demotions on owned copies only -- a shared (ghost)
            # copy demotes in every shard holding it, and summing raw
            # per-shard counts would double-count (same dedupe as
            # insert's newly_core)
            demoted_own = int((~np.isin(st["demoted_arrival"],
                                        self.ghost_rows[k])).sum())
            self.own_rows[k] = self.own_rows[k][~own_m]
            self.own_gids[k] = self.own_gids[k][~own_m]
            self.ghost_rows[k] = self.ghost_rows[k][~ghost_m]
            self.ghost_gids[k] = self.ghost_gids[k][~ghost_m]
            touched.append(k)
            per_shard.append({
                "shard": k, "own": int(own_m.sum()),
                "ghost": int(ghost_m.sum()),
                "deleted": st["deleted"], "demoted": st["demoted"],
                "demoted_own": demoted_own,
                "compacted": st["compacted"],
                **{f: st[f] for f in self._SUMMED}})
        self.owner_shard[gids] = -1
        self.owner_row[gids] = -1
        unions = self._rebuild_label_map()
        self._log_mutation("delete", ids)
        return {"op": "delete", "requested": int(len(ids)),
                "deleted": int(len(gids)),
                "rejected": int(len(rejected)), "rejected_ids": rejected,
                "n": self.n, "n_live": self.n_live,
                **{f: sum(s[f] for s in per_shard)
                   for f in self._SUMMED},
                "demoted": int(sum(s["demoted_own"] for s in per_shard)),
                "compacted": any(s["compacted"] for s in per_shard),
                "shards_touched": touched,
                "reconcile_unions": unions, "per_shard": per_shard,
                "t_total": time.perf_counter() - t0}

    def _rebuild_label_map(self) -> int:
        """Reconstruct the global map from surviving witness edges.

        The delete-direction twin of :meth:`_reconcile`: instead of
        union-patching (which cannot express a split), start from a
        fresh identity map over the shared ``next_label`` arena and
        union exactly the (owner label, ghost label) pairs still
        witnessed by a core shared point.  Returns the union count.
        """
        lm = LabelMap(self.next_label)
        unions = self._union_witness_edges(lm)
        self.label_map = lm
        return unions

    # ------------------------------------------------------------------
    # topology ops (split / merge -- see module docstring)
    # ------------------------------------------------------------------

    def _copy_state(self, k: int):
        """Every physical copy shard k holds (own block first, then
        ghosts): global ids, coordinates, *canonical* (map-resolved)
        labels and owner-exact core flags -- the pooled state a
        topology op re-partitions.  Labels and core flags come from the
        authoritative (owner) copy of each point, so they are exact for
        ghosts too."""
        shard = self.shards[k]
        gids = np.concatenate([self.own_gids[k], self.ghost_gids[k]])
        arr = np.concatenate([self.own_rows[k], self.ghost_rows[k]])
        # registries are pruned on delete, so every registered copy is
        # live and rows_of_arrival cannot return -1 here
        pts = shard.points[shard.rows_of_arrival(arr)]
        labels = np.full(len(gids), -1, np.int64)
        core = np.zeros(len(gids), bool)
        own_s = self.owner_shard[gids]
        for o in np.unique(own_s):
            sel = own_s == o
            orow = self.owner_row[gids[sel]]
            labels[sel] = self.shards[int(o)].labels_at(orow)
            core[sel] = self.shards[int(o)].core_at(orow)
        return gids, pts, self.label_map.resolve(labels), core

    def _install_shards(self, k: int, j: int, subs, pools) -> None:
        """Replace shards ``k..j`` with ``subs`` (built from ``pools``
        of (gids, oidx, gidx) selections): splice the shard list and
        registries, rewrite the owner router, re-localize the new
        shards when the localization invariant is on, and rebuild the
        global map from the surviving witness edges."""
        delta_k = len(subs) - (j - k + 1)
        shift = self.owner_shard > j
        self.shards[k:j + 1] = subs
        self.own_rows[k:j + 1] = [np.arange(len(oidx), dtype=np.int64)
                                  for _, oidx, _ in pools]
        self.own_gids[k:j + 1] = [gids[oidx] for gids, oidx, _ in pools]
        self.ghost_rows[k:j + 1] = [
            len(oidx) + np.arange(len(gidx), dtype=np.int64)
            for _, oidx, gidx in pools]
        self.ghost_gids[k:j + 1] = [gids[gidx] for gids, _, gidx in pools]
        # router: shift the shards beyond the spliced range first (the
        # -1 tombstones are excluded by the > j mask), then point the
        # re-partitioned owners at their new shard / arrival id
        self.owner_shard[shift] += delta_k
        for h, (gids, oidx, _) in enumerate(pools):
            og = gids[oidx]
            self.owner_shard[og] = k + h
            self.owner_row[og] = np.arange(len(oidx), dtype=np.int64)
        if self.localized:
            # the sub-shards carry canonical labels; re-mint per local
            # component so the localization invariant (one raw label ==
            # one local component, disjoint arenas) survives the op
            from .delta import relabel_local_components
            for sub in subs:
                sub.next_label = self.next_label
                relabel_local_components(sub)
                self.next_label = sub.next_label

    def split_shard(self, k: int) -> Dict[str, Any]:
        """Split shard ``k`` at a fresh interior grid-line cut.

        The cut comes from :func:`repro.dist.sharding.slab_cuts` over
        the slab's *own* points (the same equal-count-on-grid-lines
        policy as the fit-time partition), so both halves are
        non-empty; a slab whose own points share a single dim-0 grid
        column has no interior grid line and raises ``ValueError``
        (the caller -- e.g. the rebalancer -- treats that slab as
        unsplittable).  Pure re-partition of existing physical copies:
        read-outs are bit-identical in the insert-only regime and
        partition-identical under localization (module docstring).

        Returns an op-stats dict (``op="split"``, the new ``cut``, the
        two half sizes, ``reconcile_unions`` of the map rebuild).
        """
        t0 = time.perf_counter()
        K = self.num_shards
        if not 0 <= k < K:
            raise ValueError(f"split_shard: no shard {k} (have {K})")
        lo, hi = self._slab_bounds(k)
        n_own = len(self.own_gids[k])
        gids, pts, labels, core = self._copy_state(k)
        if n_own >= 2:
            _, cut_idx, cut_coords = slab_cuts(pts[:n_own], self.eps, 2)
        if n_own < 2 or not np.isfinite(cut_coords[0]) \
                or not 0 < int(cut_idx[0]) < n_own:
            raise ValueError(
                f"split_shard({k}): slab has no interior grid-line cut "
                f"({n_own} own points"
                + ("" if n_own < 2 else " in one dim-0 grid column")
                + "); shard is unsplittable")
        c = float(cut_coords[0])
        band = 2.0 * self.eps
        x0 = pts[:, 0]
        is_own = np.zeros(len(gids), bool)
        is_own[:n_own] = True
        subs, pools = [], []
        for slo, shi in ((lo, c), (c, hi)):
            own_sel = is_own & (x0 >= slo) & (x0 < shi)
            ghost_sel = (~own_sel) & (x0 >= slo - band) & (x0 < shi + band)
            oidx = np.flatnonzero(own_sel)
            gidx = np.flatnonzero(ghost_sel)
            sel = np.concatenate([oidx, gidx])
            sub = GritIndex.from_fit(
                pts[sel], self.eps, self.min_pts, labels=labels[sel],
                core=core[sel])
            # eager: a topology op is amortized by the rebalance period,
            # so the merge-graph build belongs here, not in the first
            # serving-path insert to touch the fresh shard
            sub.ensure_merge_graph()
            subs.append(sub)
            pools.append((gids, oidx, gidx))
        self.cuts = np.concatenate(
            [self.cuts[:k], np.asarray([c], np.float64), self.cuts[k:]])
        self._install_shards(k, k, subs, pools)
        unions = self._rebuild_label_map()
        self.cut_history.append(("split", int(k), c))
        self._log_mutation("split", np.asarray([k], np.int64))
        return {"op": "split", "shard": int(k), "cut": c,
                "n_left": int(len(pools[0][1])),
                "n_right": int(len(pools[1][1])),
                "num_shards": self.num_shards,
                "reconcile_unions": unions,
                "t_total": time.perf_counter() - t0}

    def merge_shards(self, k: int, j: Optional[int] = None
                     ) -> Dict[str, Any]:
        """Merge adjacent shards ``k`` and ``k + 1`` (the split
        inverse): pool both shards' physical copies (deduplicated by
        global id -- a point can be own in one and ghost in the other),
        build one shard over the union slab, drop the cut between
        them.  Pure re-partition, same exactness contract as
        :meth:`split_shard`.

        Returns an op-stats dict (``op="merge"``, the ``cut`` removed,
        the merged size, ``reconcile_unions`` of the map rebuild).
        """
        t0 = time.perf_counter()
        K = self.num_shards
        if j is None:
            j = k + 1
        if j != k + 1 or not 0 <= k < j < K:
            raise ValueError(
                f"merge_shards: need adjacent shards (k, k+1) within "
                f"0..{K - 1}, got ({k}, {j})")
        lo, _ = self._slab_bounds(k)
        _, hi = self._slab_bounds(j)
        removed = float(self.cuts[k])
        g0, p0, l0, c0 = self._copy_state(k)
        g1, p1, l1, c1 = self._copy_state(j)
        gids = np.concatenate([g0, g1])
        # dedupe to one physical copy per global id (ghost copies are
        # verbatim splices of the owner's coordinates, so any copy is
        # authoritative for the pooled build)
        gids, first = np.unique(gids, return_index=True)
        pts = np.concatenate([p0, p1])[first]
        labels = np.concatenate([l0, l1])[first]
        core = np.concatenate([c0, c1])[first]
        band = 2.0 * self.eps
        x0 = pts[:, 0]
        own_sel = np.isin(self.owner_shard[gids], (k, j))
        ghost_sel = (~own_sel) & (x0 >= lo - band) & (x0 < hi + band)
        oidx = np.flatnonzero(own_sel)
        gidx = np.flatnonzero(ghost_sel)
        sel = np.concatenate([oidx, gidx])
        sub = GritIndex.from_fit(pts[sel], self.eps, self.min_pts,
                                 labels=labels[sel], core=core[sel])
        sub.ensure_merge_graph()  # charge the build to the amortized op
        self.cuts = np.concatenate([self.cuts[:k], self.cuts[k + 1:]])
        self._install_shards(k, j, [sub], [(gids, oidx, gidx)])
        unions = self._rebuild_label_map()
        self.cut_history.append(("merge", int(k), removed))
        self._log_mutation("merge", np.asarray([k], np.int64))
        return {"op": "merge", "shard": int(k), "cut": removed,
                "n_merged": int(len(oidx)),
                "num_shards": self.num_shards,
                "reconcile_unions": unions,
                "t_total": time.perf_counter() - t0}

    # ------------------------------------------------------------------
    # snapshot / restore
    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, np.ndarray]:
        """Flat-array serialization: per-shard ``GritIndex`` snapshots
        (key-prefixed) + the routing/reconciliation state.  Directly
        ``np.savez``-able, like the single-shard snapshot."""
        snap: Dict[str, np.ndarray] = {
            "sharded_version": np.asarray([_SHARDED_SNAPSHOT_VERSION],
                                          np.int64),
            "cuts": np.asarray(self.cuts, np.float64),
            "scalars_f": np.asarray([self.eps], np.float64),
            "scalars_i": np.asarray(
                [self.min_pts, self.next_label, self.num_shards,
                 int(self.localized), self.ops_applied], np.int64),
            "label_parent": self.label_map.parent.copy(),
            "owner_shard": self.owner_shard.copy(),
            "owner_row": self.owner_row.copy(),
            # v3: topology-op provenance (kind 0=split, 1=merge)
            "cut_hist_kind": np.asarray(
                [0 if op == "split" else 1
                 for op, _, _ in self.cut_history], np.int64),
            "cut_hist_shard": np.asarray(
                [s for _, s, _ in self.cut_history], np.int64),
            "cut_hist_coord": np.asarray(
                [c for _, _, c in self.cut_history], np.float64),
        }
        for k, idx in enumerate(self.shards):
            for key, v in idx.snapshot().items():
                snap[f"shard{k}.{key}"] = v
            snap[f"shard{k}.own_rows"] = self.own_rows[k].copy()
            snap[f"shard{k}.own_gids"] = self.own_gids[k].copy()
            snap[f"shard{k}.ghost_rows"] = self.ghost_rows[k].copy()
            snap[f"shard{k}.ghost_gids"] = self.ghost_gids[k].copy()
        return snap

    _EXTRA = ("own_rows", "own_gids", "ghost_rows", "ghost_gids")

    @classmethod
    def restore(cls, snap: Dict[str, np.ndarray]) -> "ShardedGritIndex":
        check_version(snap, "sharded_version", _SHARDED_ACCEPTED,
                      "sharded snapshot")
        sf = np.asarray(snap["scalars_f"], np.float64)
        si = np.asarray(snap["scalars_i"], np.int64)
        K = int(si[2])
        shards, own_rows, own_gids, ghost_rows, ghost_gids = \
            [], [], [], [], []
        for k in range(K):
            prefix = f"shard{k}."
            sub = {key[len(prefix):]: v for key, v in snap.items()
                   if key.startswith(prefix)
                   and key[len(prefix):] not in cls._EXTRA}
            shards.append(GritIndex.restore(sub))
            own_rows.append(np.asarray(snap[f"shard{k}.own_rows"],
                                       np.int64))
            own_gids.append(np.asarray(snap[f"shard{k}.own_gids"],
                                       np.int64))
            ghost_rows.append(np.asarray(snap[f"shard{k}.ghost_rows"],
                                         np.int64))
            ghost_gids.append(np.asarray(snap[f"shard{k}.ghost_gids"],
                                         np.int64))
        # v1/v2 snapshots carry no topology history or replay cursor
        hist: List[Tuple[str, int, float]] = []
        if "cut_hist_kind" in snap:
            hist = [("split" if int(kk) == 0 else "merge", int(s),
                     float(c))
                    for kk, s, c in zip(snap["cut_hist_kind"],
                                        snap["cut_hist_shard"],
                                        snap["cut_hist_coord"])]
        return cls(shards=shards,
                   cuts=np.asarray(snap["cuts"], np.float64),
                   eps=float(sf[0]), min_pts=int(si[0]),
                   next_label=int(si[1]),
                   label_map=LabelMap(int(si[1]),
                                      parent=snap["label_parent"]),
                   own_rows=own_rows, own_gids=own_gids,
                   ghost_rows=ghost_rows, ghost_gids=ghost_gids,
                   owner_shard=np.asarray(snap["owner_shard"], np.int64),
                   owner_row=np.asarray(snap["owner_row"], np.int64),
                   localized=bool(si[3]) if len(si) > 3 else False,
                   cut_history=hist,
                   ops_applied=int(si[4]) if len(si) > 4 else 0)

    def save(self, path) -> None:
        save_snapshot(path, self.snapshot())

    @classmethod
    def load(cls, path) -> "ShardedGritIndex":
        return cls.restore(load_snapshot(path))


def fit_sharded(points, eps: float, min_pts: int, *,
                n_shards: Optional[int] = None, mesh=None,
                engine: Optional[str] = None,
                **opts) -> ShardedGritIndex:
    """Fit and shard in one call: distributed fit -> ShardedGritIndex.

    With ``mesh``, the fit runs the distributed SPMD engine on it (the
    adaptive-cap loop included) and the slab count follows the mesh
    size; otherwise a single-process fit (``engine``, default the host
    ``grit`` pipeline) is sharded host-side into ``n_shards`` slabs --
    the same serving structure without multi-device hardware.
    """
    from repro.engine import cluster

    pts = np.asarray(points, np.float64)
    if mesh is not None:
        res = cluster(pts, eps, min_pts, engine="distributed", mesh=mesh,
                      **opts)
        if n_shards is None:
            n_shards = int(mesh.devices.size)
    else:
        res = cluster(pts, eps, min_pts, engine=engine or "grit", **opts)
        if n_shards is None:
            n_shards = 4
    return ShardedGritIndex.from_global_fit(
        pts, eps, min_pts, labels=res.labels, core=res.core,
        n_shards=n_shards)
