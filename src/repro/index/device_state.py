"""Device-resident serving state for a fitted :class:`GritIndex`.

The serving hot loop historically round-tripped host numpy on every
step: predict gathered float64 candidates per call, and the delta
engine's core-recompute / merge re-decision / border stages ran
per-grid Python loops.  This module keeps the fitted state *resident*
-- the CSR-sorted points, core/alive flags, grid ranges and merge-edge
arrays live as jax buffers (:class:`DeviceState`) -- and drives the hot
stages through one *flat ragged* kernel dispatch each
(``repro.kernels.ops.pairwise_d2_flat`` / ``pairwise_d2_flat_res``),
with host code reduced to packing flat int32 gather indices and
running the segmented reduceat reductions (DESIGN.md §7: on CPU one C
pass beats XLA's scatter-based segment ops).  Stages whose flat
element count falls under the adaptive gates (``MIN_FLAT_T`` /
``EDGE_MIN_FLAT_T``) run their host float64 twin outright -- pure
performance routing, the twin is the reference.

**Bit-exactness by guard band** (DESIGN.md §6/§7).  GriT-DBSCAN's value
is *exact* DBSCAN, so the float32 kernels never get the last word.
Points are stored float32 origin-centered; every distance the kernels
produce carries a provable absolute error below ``band * eps^2`` where
``band = 32 * sqrt(d) * (d+1) * max(span/eps, 1) * 2**-24`` (span =
largest |coordinate - origin| ever resident; monotone).  Each stage
only accepts a kernel answer when it is *certain under the band*:

* core counts: ``count_lo`` hits at ``eps*sqrt(1-band)``, ``count_hi``
  at ``eps*sqrt(1+band)`` bracket the exact count -- core is certain
  iff ``base + count_lo >= MinPts``, non-core iff
  ``base + count_hi < MinPts``;
* merge edges: pair-min ``<= eps2*(1-band)`` proves the edge,
  ``> eps2*(1+band)`` refutes it;
* predict / border argmins: accepted only when the runner-up gap
  ``min2 - min > 2*band*eps2`` proves the float64 argmin is the same
  row (the winning distance is then *re-derived in float64* on host,
  so emitted labels and d2 are bit-identical to the host path).

Everything else -- the uncertain band -- falls back to the *same* host
float64 code the reference path runs, on exactly the uncertain subset.
All host stages are per-row / per-pair independent, so subset fallback
equals a full host run: the device path is bit-identical to
``device_state=None`` serving by construction, and the differential
suite (``tests/test_device_serving.py``) pins it.

Donation policy: the big row buffers are updated in place by donated
jitted scatters (tombstones, core flips) -- the old buffer is consumed,
so stale aliasing across mutation steps is structurally impossible;
structural rewrites (splice, compact, cap growth) re-upload.  The
small CSR / merge-edge mirrors re-ship per mutation.
"""

from __future__ import annotations

import functools
import math
import time
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.grids import group_rows
from repro.engine.adaptive import ResidentCaps, _pow2_at_least
from repro.kernels import ops as kernel_ops

from .delta import (_bbox_survivors, _border_pass_host, _core_count_per_grid,
                    _decide_edges_batch, _recompute_cores_host)

_BAND_SAFETY = 32.0   # x8 over the worst-case f32 error bound


# --------------------------------------------------------------------------
# jitted resident-buffer ops
# --------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_dead(alive_res, core_res, rows):
    """Donated tombstone scatter: pad slots carry ``rows == row_cap``
    and are dropped, so one jit key serves every pow2 batch size."""
    return (alive_res.at[rows].set(False, mode="drop"),
            core_res.at[rows].set(False, mode="drop"))


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("value",))
def _scatter_core(core_res, rows, *, value):
    return core_res.at[rows].set(value, mode="drop")


# --------------------------------------------------------------------------
# host packing helpers (the only work left on host in the hot loop)
# --------------------------------------------------------------------------

def _expand(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenated ``arange(starts[k], starts[k]+counts[k])`` ranges."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    offs = np.cumsum(counts) - counts
    return np.repeat(starts - offs, counts) + np.arange(total)


def _pad_pow2(rows: np.ndarray, sentinel: int) -> jnp.ndarray:
    """Pad a scatter-row vector to its pow2 bucket with an out-of-range
    sentinel (dropped by ``mode="drop"``) -- one jit key per bucket."""
    cap = _pow2_at_least(len(rows), lo=8)
    out = np.full(cap, sentinel, np.int64)
    out[:len(rows)] = rows
    return jnp.asarray(out.astype(np.int32))


def _row_cross(a_vals: np.ndarray, a_sizes: np.ndarray,
               a_offs: np.ndarray, b_vals: np.ndarray,
               b_sizes: np.ndarray, b_offs: np.ndarray,
               sel: np.ndarray):
    """Flat per-row cross-product layout for the delta stages.

    For each group ``k`` in ``sel`` (order kept) and each of its ``a``
    elements (order kept), emit one *segment* holding ``k``'s full
    ``b`` list (order kept -- the host candidate order, so first-min
    tie-breaks match).  Returns ``(ra, rb, seg, row_pos, row_k)``: the
    [T] flat operands, the per-segment lengths, each segment's flat
    position into ``a_vals``'s CSR, and its ``sel`` slot.  Zero padding
    waste; one kernel dispatch covers every group.
    """
    rows_per = a_sizes[sel]
    row_pos = _expand(a_offs[sel], rows_per)
    row_k = np.repeat(np.arange(len(sel)), rows_per)
    seg = b_sizes[sel][row_k]
    ra = np.repeat(a_vals[row_pos], seg)
    rb = b_vals[_expand(b_offs[sel][row_k], seg)]
    return ra, rb, seg, row_pos, row_k


# below this flat element count a delta stage runs its host float64
# twin instead of dispatching: upload + dispatch + sync overhead on a
# tiny batch exceeds the f32 math win, and the host twin IS the
# reference the device path is pinned against, so the shortcut cannot
# change any output.  Tuned on the CPU backend (BENCH_6 workload);
# large mutations and every predict stay on the kernel path.
MIN_FLAT_T = 1 << 15
# the edge decider's host twin early-terminates per pair (most
# re-decided edges are confirmed by the first probe), while the flat
# kernel always pays the full cross product -- so its crossover sits
# far higher than the count-every-pair stages above
EDGE_MIN_FLAT_T = 1 << 20


def _d2_flat_res(ds, ra: np.ndarray, rb: np.ndarray, gg: np.ndarray,
                 anch32: np.ndarray):
    """Dispatch one flat resident-pair distance kernel.  Anchors are
    gathered per element on the host so the upload shapes -- and hence
    the jit key -- depend on the single pow2 T bucket, not on the group
    count: the bucket set saturates within a few waves and recompiles
    stop.  Returns the device array; the caller blocks with
    ``np.asarray`` and slices ``[:len(ra)]``."""
    T = len(ra)
    tcap = _pow2_at_least(T, lo=8)
    obs.note_flat_dispatch("res", T, tcap)
    ra_p = np.empty(tcap, np.int32)       # tail-fill only: the pads
    ra_p[:T] = ra                         # alias row 0 / anchor 0 and
    ra_p[T:] = 0                          # their distances are sliced
    rb_p = np.empty(tcap, np.int32)       # off, so a full zero pass
    rb_p[:T] = rb                         # is wasted work
    rb_p[T:] = 0
    av_p = np.empty((tcap, anch32.shape[1]), np.float32)
    av_p[:T] = anch32[gg]
    av_p[T:] = 0.0
    return kernel_ops.pairwise_d2_flat_res(
        ds.points_res, jnp.asarray(ra_p), jnp.asarray(rb_p),
        jnp.asarray(av_p))


class _Timer:
    """Accumulates pack vs kernel seconds into a ctr/stats dict."""

    def __init__(self, ctr: Optional[Dict[str, Any]]):
        self.ctr = ctr if ctr is not None else {}
        self.t0 = time.perf_counter()

    def mark(self, key: str) -> None:
        now = time.perf_counter()
        self.ctr[key] = self.ctr.get(key, 0.0) + (now - self.t0)
        self.t0 = now


# --------------------------------------------------------------------------
# the resident state
# --------------------------------------------------------------------------

class DeviceState:
    """Resident mirror of a fitted index's serving-hot arrays.

    Host numpy stays authoritative (snapshots never read device
    buffers); the mirror exists to feed the kernels gather indices
    instead of coordinates and is pinned to the host arrays by
    :meth:`mirror_matches` in the differential suite.
    """

    def __init__(self, index, interpret: Optional[bool] = None):
        self.interpret = interpret
        self.caps = ResidentCaps()
        self.uploads = 0          # full/structural buffer ships
        self.donations = 0        # in-place donated updates
        pts = index.points
        self.origin = ((pts.min(axis=0) + pts.max(axis=0)) / 2.0
                       if len(pts) else np.zeros(index.d))
        self.span = 0.0           # monotone max |coord - origin|
        self.refresh_rows(index)
        self.refresh_small(index)

    # -- error band --------------------------------------------------------

    def note_batch(self, arr: np.ndarray) -> None:
        """Fold a coordinate batch (inserts *and* queries) into the
        span the error band is derived from -- monotone, so a certainty
        proven now stays valid for every earlier resident point."""
        if len(arr):
            self.span = max(self.span,
                            float(np.abs(np.asarray(arr, np.float64)
                                         - self.origin[None, :]).max()))

    def thresholds(self, index):
        """(band, lo2, hi2): the relative guard band and the certain
        hit / certain miss d2 thresholds around ``eps^2``."""
        d, eps = index.d, index.eps
        band = (_BAND_SAFETY * math.sqrt(d) * (d + 1)
                * max(self.span / eps, 1.0) * 2.0 ** -24)
        eps2 = eps * eps
        return band, eps2 * max(1.0 - band, 0.0), eps2 * (1.0 + band)

    # -- buffer lifecycle --------------------------------------------------

    def refresh_rows(self, index) -> None:
        """Structural re-upload of the row buffers (fit, splice,
        compact, cap growth): fresh buffers, old ones dropped."""
        n = index.n
        e = (len(index.merge_edges)
             if index.merge_edges is not None else 0)
        self.caps, _ = self.caps.grown_to(
            ResidentCaps.for_state(n, index.num_grids, e))
        rc = self.caps.row_cap
        p32 = np.zeros((rc, index.d), np.float32)
        p32[:n] = (index.points - self.origin[None, :]).astype(np.float32)
        self.note_batch(index.points)
        alive = np.zeros(rc, bool)
        alive[:n] = index.alive
        core = np.zeros(rc, bool)
        core[:n] = index.core
        self.points_res = jnp.asarray(p32)
        self.alive_res = jnp.asarray(alive)
        self.core_res = jnp.asarray(core)
        self.uploads += 1
        obs.counter("device_state.uploads.rows").inc()

    def refresh_small(self, index) -> None:
        """Re-ship the CSR / merge-edge mirrors (cheap, per mutation)."""
        G = index.num_grids
        e = (len(index.merge_edges)
             if index.merge_edges is not None else 0)
        self.caps, _ = self.caps.grown_to(
            ResidentCaps.for_state(index.n, G, e))
        gc, ec = self.caps.grid_cap, self.caps.edge_cap
        starts = np.zeros(gc, np.int32)
        counts = np.zeros(gc, np.int32)
        live = np.zeros(gc, np.int32)
        starts[:G] = index.starts
        counts[:G] = index.counts
        live[:G] = index.live_counts
        edges = np.full((ec, 2), -1, np.int32)
        if e:
            edges[:e] = index.merge_edges
        self.starts_res = jnp.asarray(starts)
        self.counts_res = jnp.asarray(counts)
        self.live_counts_res = jnp.asarray(live)
        self.merge_edges_res = jnp.asarray(edges)
        self.n_edges = e
        self.uploads += 1
        obs.counter("device_state.uploads.small").inc()

    def mark_dead(self, rows: np.ndarray) -> None:
        """Donated tombstone scatter (delete stage 1)."""
        if len(rows) == 0:
            return
        idx = _pad_pow2(rows, self.caps.row_cap)
        self.alive_res, self.core_res = _scatter_dead(
            self.alive_res, self.core_res, idx)
        self.donations += 1
        obs.counter("device_state.donations").inc()

    def flip_core(self, rows: np.ndarray, value: bool) -> None:
        """Donated core-flag scatter (core recompute flips)."""
        if len(rows) == 0:
            return
        idx = _pad_pow2(rows, self.caps.row_cap)
        self.core_res = _scatter_core(self.core_res, idx, value=value)
        self.donations += 1
        obs.counter("device_state.donations").inc()

    # -- differential pinning ---------------------------------------------

    def mirror_matches(self, index) -> Dict[str, bool]:
        """Per-buffer equality of the resident mirror against the host
        arrays -- what the donation stress test asserts after every
        mutation (a stale donated alias shows up here immediately)."""
        n, G = index.n, index.num_grids
        e = (len(index.merge_edges)
             if index.merge_edges is not None else 0)
        want32 = (index.points - self.origin[None, :]).astype(np.float32)
        me = np.asarray(self.merge_edges_res[:e]) if e else \
            np.zeros((0, 2), np.int32)
        host_e = (index.merge_edges if e else np.zeros((0, 2), np.int64))
        return {
            "points": np.array_equal(np.asarray(self.points_res[:n]),
                                     want32),
            "alive": np.array_equal(np.asarray(self.alive_res[:n]),
                                    index.alive),
            "alive_pad": bool(not np.asarray(
                self.alive_res[n:]).any()),
            "core": np.array_equal(np.asarray(self.core_res[:n]),
                                   index.core),
            "starts": np.array_equal(np.asarray(self.starts_res[:G]),
                                     index.starts.astype(np.int32)),
            "counts": np.array_equal(np.asarray(self.counts_res[:G]),
                                     index.counts.astype(np.int32)),
            "live_counts": np.array_equal(
                np.asarray(self.live_counts_res[:G]),
                index.live_counts.astype(np.int32)),
            "merge_edges": np.array_equal(me, host_e.astype(np.int32)),
        }


# --------------------------------------------------------------------------
# stage: predict
# --------------------------------------------------------------------------

def _anchors(index, ds, rep_ids: np.ndarray) -> np.ndarray:
    """float32 cell anchors relative to the resident origin (float64
    subtract, then cast -- the kernel sees stencil-scale coordinates)."""
    a = (index.mins[None, :]
         + (rep_ids - index.id_shift[None, :]) * index.side
         - ds.origin[None, :])
    return a.astype(np.float32)


def predict_device_async(index, ds, q: np.ndarray,
                         stats: Optional[dict]):
    """Two-phase device predict: pack + dispatch now, return a resolver
    that blocks on the kernels and finishes the labels.

    The split is what :class:`~repro.serve.driver.ClusterServer` double-
    buffers on: the next step's admission packs on host while this
    step's jitted program executes.  ``resolve()`` returns
    ``(labels, d2)`` bit-identical to ``GritIndex._predict_host``.
    """
    tm = _Timer(stats)
    eps2 = index.eps * index.eps
    m = q.shape[0]
    ds.note_batch(q)
    band, _, _ = ds.thresholds(index)
    out = np.full(m, -1, np.int64)
    out_d2 = np.full(m, np.inf, np.float64)
    q_ids = index.query_ids(q)
    qorder, sq, gstart, gcount, _ = group_rows(q_ids)
    rep_ids = sq[gstart]
    B = len(gstart)
    rows, g_of = index._candidate_cores(rep_ids)
    cand_per = np.bincount(g_of, minlength=B).astype(np.int64)
    cand_offs = np.cumsum(cand_per) - cand_per
    nonempty = np.flatnonzero(cand_per > 0)
    if stats is not None:
        stats.update(groups=int(B), candidates=int(len(rows)),
                     chunks=0, uncertain=0)
    if len(nonempty) == 0:           # no candidates anywhere: all noise
        tm.mark("t_pack")
        return lambda: (out, out_d2)
    group_of = np.empty(m, np.int64)  # query position -> its group
    group_of[qorder] = np.repeat(np.arange(B), gcount)
    anch32 = _anchors(index, ds, rep_ids)
    q32 = (q - ds.origin[None, :]).astype(np.float32)
    # flat ragged layout: each query's candidate segment, replicated in
    # host candidate order, one seg_min2_flat dispatch for the whole
    # batch (zero padding waste; the chunked row_min2_batch packing
    # this replaces paid ~4 uploads and 2 dispatches per 64 groups).
    # queries are not resident: center on host in f32 (IEEE --
    # identical values to the device-side subtract on the b side)
    qa = q32 - anch32[group_of]
    csz = cand_per[group_of]                      # candidates per query
    offs = cand_offs[group_of]
    T = int(csz.sum())
    rr_flat = rows[_expand(offs, csz)]
    qo_flat = np.repeat(np.arange(m), csz)        # sorted segment ids
    tcap = _pow2_at_least(T, lo=8)
    mcap = _pow2_at_least(m + 1, lo=8)            # +1: pad segment
    rr_p = np.zeros(tcap, np.int32)
    rr_p[:T] = rr_flat
    qo_p = np.full(tcap, m, np.int32)             # pads -> slot m
    qo_p[:T] = qo_flat
    qa_p = np.zeros((mcap, index.d), np.float32)
    qa_p[:m] = qa
    # anchors host-gathered per element: jit key = (tcap, mcap) only
    av_p = np.zeros((tcap, index.d), np.float32)
    av_p[:T] = np.repeat(anch32[group_of], csz, axis=0)
    obs.note_flat_dispatch("predict", T, tcap)
    d2dev = kernel_ops.pairwise_d2_flat(
        ds.points_res, jnp.asarray(qa_p), jnp.asarray(rr_p),
        jnp.asarray(qo_p), jnp.asarray(av_p))
    if stats is not None:
        stats["chunks"] = 1
    tm.mark("t_pack")

    def resolve():
        tm.t0 = time.perf_counter()
        # grit-lint: disable=hot-path-sync -- resolve() IS this stage's single intended block point: f32 distances materialize once here
        d2f = np.asarray(d2dev)[:T]               # f32, device math
        # segmented (min, first-arg, runner-up) on host: one C pass
        # per reduce, same shape as the host oracle's reduceat
        hasq = np.flatnonzero(csz > 0)
        seg = (np.cumsum(csz) - csz)[hasq]
        mn_h = np.minimum.reduceat(d2f, seg)
        is_min = d2f == np.repeat(mn_h, csz[hasq])
        pos = np.flatnonzero(is_min)
        _, first = np.unique(qo_flat[pos], return_index=True)
        best = pos[first]                         # first-min tie-break
        d2b = d2f.copy()
        d2b[best] = np.inf                        # drop argmin element
        mn2_h = np.minimum.reduceat(d2b, seg)
        mn = np.full(m, np.inf)
        mn[hasq] = mn_h.astype(np.float64)
        mn2 = np.full(m, np.inf)
        mn2[hasq] = mn2_h.astype(np.float64)
        ag = np.full(m, -1, np.int64)
        ag[hasq] = best
        with np.errstate(invalid="ignore"):     # inf - inf rows
            cert = (np.isinf(mn2)
                    | (mn2 - mn > 2.0 * band * eps2)) & (ag >= 0)
        qp = np.flatnonzero(cert)
        if len(qp):
            rr = rr_flat[ag[qp]]
            d2v = ((index.points[rr] - q[qp]) ** 2).sum(axis=1)
            out_d2[qp] = d2v
            hit = d2v <= eps2
            out[qp[hit]] = index.labels[rr[hit]]
        unc = np.flatnonzero((csz > 0) & ~cert)
        if len(unc):
            # band fallback, targeted: a query's flat candidate segment
            # IS its host candidate list (same cell id -> same
            # ``_candidate_cores`` order), so re-deriving the f64
            # segmented argmin over it -- first-hit tie-break, same
            # expression -- equals ``_predict_host`` bit for bit
            # without re-walking the tree for the uncertain subset.
            cs = csz[unc]
            seg = np.cumsum(cs) - cs
            rrq = rows[_expand(offs[unc], cs)]
            qof = np.repeat(np.arange(len(unc)), cs)
            d2v = ((index.points[rrq] - q[unc][qof]) ** 2).sum(axis=1)
            dmin = np.minimum.reduceat(d2v, seg)
            is_min = d2v == np.repeat(dmin, cs)
            pos = np.flatnonzero(is_min)
            qpos_u, first = np.unique(qof[pos], return_index=True)
            best = pos[first]
            out_d2[unc[qpos_u]] = d2v[best]
            hit = d2v[best] <= eps2
            out[unc[qpos_u[hit]]] = index.labels[rrq[best[hit]]]
            if stats is not None:
                stats["uncertain"] = int(len(unc))
        tm.mark("t_kernel")
        return out, out_d2

    return resolve


def predict_device(index, ds, q: np.ndarray, stats: Optional[dict]):
    return predict_device_async(index, ds, q, stats)()


# --------------------------------------------------------------------------
# stage: core recompute (delta stage 2)
# --------------------------------------------------------------------------

def recompute_cores_device(index, ds, affected: np.ndarray,
                           direction: int,
                           ctr: Dict[str, Any]) -> np.ndarray:
    """Device twin of ``delta._recompute_cores_host``: identical need
    filter, shortcut, and flip set (bit-identical ``newly_core`` /
    ``demoted`` arrays), with the per-grid count loops replaced by one
    flat ``pairwise_d2_flat_res`` dispatch and segmented host counts."""
    tm = _Timer(ctr)
    pts, core, alive = index.points, index.core, index.alive
    starts, counts = index.starts, index.counts
    live_counts, min_pts = index.live_counts, index.min_pts
    eps2 = index.eps * index.eps
    band, lo2, hi2 = ds.thresholds(index)
    ccnt = _core_count_per_grid(index)
    if direction > 0:
        need = affected[live_counts[affected] > ccnt[affected]]
    else:
        need = affected[(live_counts[affected] < min_pts)
                        & (ccnt[affected] > 0)]
    if len(need) == 0:
        tm.mark("t_pack")
        return np.empty(0, np.int64)
    ip, nb, _ = index.tree.query(index.ids[need], include_self=False)
    K = len(need)
    # gate on a cheap upper bound of the flat element count (dead rows
    # not yet filtered) *before* building any flat layout: a tiny
    # recount runs the host float64 twin outright -- upload + dispatch
    # overhead would exceed the f32 win, and the twin IS the reference,
    # so the shortcut cannot change any output.  The twin flips
    # ``index.core`` itself; only the resident flags need syncing.
    nbc = np.concatenate([[0], np.cumsum(counts[nb])])
    if int(counts[need] @ (nbc[ip[1:]] - nbc[ip[:-1]])) < MIN_FLAT_T:
        tm.mark("t_pack")
        flips = _recompute_cores_host(index, affected, direction, ctr)
        if len(flips):
            ds.flip_core(flips, direction > 0)
        tm.mark("t_kernel")
        return flips
    # flat candidate rows, grouped in need order (ascending within a
    # grid) -- the flip set reads out of this order, so it matches the
    # host loop's concatenation bit for bit
    own = _expand(starts[need], counts[need])
    own_g = np.repeat(np.arange(K), counts[need])
    keepm = alive[own]
    own, own_g = own[keepm], own_g[keepm]
    keepm = ~core[own] if direction > 0 else core[own]
    cand, cand_g = own[keepm], own_g[keepm]
    cand_sizes = np.bincount(cand_g, minlength=K)
    cand_offs = np.cumsum(cand_sizes) - cand_sizes
    flip = np.zeros(len(cand), bool)
    kern = np.arange(K)
    if direction > 0:
        short = live_counts[need] >= min_pts    # all-live-core shortcut
        flip[short[cand_g]] = True
        kern = np.flatnonzero(~short)
    # stencil candidate rows (live) per need grid
    nsz = np.diff(ip)
    n_of = np.repeat(np.arange(K), nsz)
    nrows = _expand(starts[nb], counts[nb])
    nrow_g = np.repeat(n_of, counts[nb])
    keepm = alive[nrows]
    nrows, nrow_g = nrows[keepm], nrow_g[keepm]
    nb_sizes = np.bincount(nrow_g, minlength=K)
    nb_offs = np.cumsum(nb_sizes) - nb_sizes
    # no live stencil candidate at all: the own count decides exactly
    zero = kern[nb_sizes[kern] == 0]
    if len(zero) and direction < 0:
        # need filter guarantees live_counts < MinPts here: demote all
        flip[np.isin(cand_g, zero)] = True
    kern = kern[(nb_sizes[kern] > 0) & (cand_sizes[kern] > 0)]
    base_of = live_counts[need]
    anch32 = _anchors(index, ds, index.ids[need])
    if len(kern):
        ra, rb, seg, row_pos, row_k = _row_cross(
            cand, cand_sizes, cand_offs, nrows, nb_sizes, nb_offs,
            kern)
        d2dev = _d2_flat_res(ds, ra, rb, np.repeat(kern[row_k], seg),
                             anch32)
    tm.mark("t_pack")

    unc_parts = []
    if len(kern):
        # grit-lint: disable=hot-path-sync -- the stage's single intended block point: bracketing counts need the f32 distances
        d2f = np.asarray(d2dev)[:len(ra)]
        # bracketing counts per candidate row: any f32 distance at or
        # under lo2 is provably a neighbor, anything over hi2 provably
        # is not (guard band, module docstring) -- one add.reduceat
        # pass each, same segmented shape as the host loop's counts
        soff = np.cumsum(seg) - seg
        clo = np.add.reduceat((d2f <= lo2).astype(np.int64), soff)
        chi = np.add.reduceat((d2f <= hi2).astype(np.int64), soff)
        base = base_of[kern[row_k]]
        is_core = base + clo >= min_pts
        not_core = base + chi < min_pts
        want = is_core if direction > 0 else not_core
        flip[row_pos[want]] = True
        unc = ~is_core & ~not_core
        if unc.any():
            unc_parts.append(row_pos[unc])
    if unc_parts:
        # exact float64 recount for the uncertain rows, one group at a
        # time against its own stencil candidates (the same candidate
        # set the host loop scans)
        up = np.concatenate(unc_parts)
        ctr["band_fallback"] = ctr.get("band_fallback", 0) + len(up)
        for g in np.unique(cand_g[up]):
            rr = cand[up[cand_g[up] == g]]
            nr = nrows[nb_offs[g]:nb_offs[g] + nb_sizes[g]]
            d2 = ((pts[rr][:, None, :] - pts[nr][None, :, :]) ** 2
                  ).sum(-1)
            ctr["dist_evals"] += d2.size
            cnt = base_of[g] + (d2 <= eps2).sum(1)
            dec = cnt >= min_pts if direction > 0 else cnt < min_pts
            flip[up[cand_g[up] == g]] = dec
    flips = cand[flip]
    if len(flips):
        core[flips] = direction > 0
        ds.flip_core(flips, direction > 0)
    tm.mark("t_kernel")
    return flips


# --------------------------------------------------------------------------
# stage: merge-edge decisions (delta stage 3)
# --------------------------------------------------------------------------

def decide_edges_device(index, ds, pairs: np.ndarray,
                        ctr: Dict[str, Any]) -> np.ndarray:
    """Device twin of ``delta._decide_edges_batch``: same exact bbox
    reject, then the pair minima come from one flat
    ``pairwise_d2_flat_res`` dispatch reduced per pair; the
    band-uncertain pairs re-run the host float64 decision."""
    if len(pairs) == 0:
        return np.zeros(0, bool)
    tm = _Timer(ctr)
    band, lo2, hi2 = ds.thresholds(index)
    hit = np.zeros(len(pairs), bool)
    rem = _bbox_survivors(index, pairs)
    if len(rem) == 0:
        tm.mark("t_pack")
        return hit
    core_rows, cstarts, ccounts = index._core_ranges()
    a, b = pairs[rem, 0], pairs[rem, 1]
    sizes_a, sizes_b = ccounts[a], ccounts[b]
    # a pair with no core on either side has pairmin inf: no edge,
    # certain (the host reduce over an empty set agrees)
    psel = np.flatnonzero((sizes_a > 0) & (sizes_b > 0))
    if int(sizes_a[psel] @ sizes_b[psel]) < EDGE_MIN_FLAT_T:
        # small decision batch: the host twin's per-pair early exit
        # beats the full-cross-product dispatch (gate before any flat
        # layout is built; same-output by construction)
        tm.mark("t_pack")
        hit[rem] = _decide_edges_batch(index, pairs[rem], ctr)
        tm.mark("t_kernel")
        return hit
    aflat = core_rows[_expand(cstarts[a], sizes_a)]
    bflat = core_rows[_expand(cstarts[b], sizes_b)]
    a_offs = np.cumsum(sizes_a) - sizes_a
    b_offs = np.cumsum(sizes_b) - sizes_b
    anch32 = _anchors(index, ds, index.ids[a])
    if len(psel):
        ra, rb, seg, _, row_k = _row_cross(
            aflat, sizes_a, a_offs, bflat, sizes_b, b_offs, psel)
        d2dev = _d2_flat_res(ds, ra, rb, np.repeat(psel[row_k], seg),
                             anch32)
    tm.mark("t_pack")
    unc = np.empty(0, np.int64)
    if len(psel):
        # grit-lint: disable=hot-path-sync -- the stage's single intended block point: pair minima resolve from f32 distances
        d2f = np.asarray(d2dev)[:len(ra)]
        soff = np.cumsum(seg) - seg
        rowmin = np.minimum.reduceat(d2f, soff).astype(np.float64)
        # pair min = min over its a rows' segment minima
        rps = np.bincount(row_k, minlength=len(psel))
        poff = np.cumsum(rps) - rps
        pairmin = np.minimum.reduceat(rowmin, poff)
        hit[rem[psel[pairmin <= lo2]]] = True
        unc = psel[(pairmin > lo2) & (pairmin <= hi2)]
    if len(unc):
        ctr["band_fallback"] = ctr.get("band_fallback", 0) + len(unc)
        hit[rem[unc]] = _decide_edges_batch(index, pairs[rem[unc]], ctr)
    tm.mark("t_kernel")
    return hit


# --------------------------------------------------------------------------
# stage: border pass (delta stage 5)
# --------------------------------------------------------------------------

def border_pass_device(index, ds, rows: np.ndarray,
                       grid_of: np.ndarray,
                       ctr: Dict[str, Any]) -> None:
    """Device twin of ``delta._border_pass_host``: nearest-live-core
    via one flat ``pairwise_d2_flat_res`` dispatch and a segmented
    (min, first-arg, runner-up) host reduce; a row is decided only
    when its argmin is certain (runner-up gap above the band), and its
    winning distance is re-derived in float64 -- the emitted label is
    the host label.  Uncertain rows re-run the host pass."""
    if len(rows) == 0:
        return
    tm = _Timer(ctr)
    pts, lab = index.points, index.labels
    eps2 = index.eps * index.eps
    band, _, _ = ds.thresholds(index)
    lab[rows] = -1
    cgrids = np.unique(grid_of[rows])
    ip, nb, _ = index.tree.query(index.ids[cgrids], include_self=False)
    K = len(cgrids)
    rg = np.searchsorted(cgrids, grid_of[rows])     # rows sorted ->
    sizes_a = np.bincount(rg, minlength=K)          # groups contiguous
    a_offs = np.cumsum(sizes_a) - sizes_a
    # own + stencil grids per group, own first (host concat order)
    nsz = np.diff(ip)
    gsz = 1 + nsz
    g_offs = np.cumsum(gsz) - gsz
    gflat = np.empty(int(gsz.sum()), np.int64)
    gflat[g_offs] = cgrids
    mask = np.ones(len(gflat), bool)
    mask[g_offs] = False
    gflat[mask] = nb
    g_of2 = np.repeat(np.arange(K), gsz)
    core_rows, cstarts, ccounts = index._core_ranges()
    # gate before the flat candidate build: per-group core totals come
    # from one cumsum over the (cheap) per-grid core counts
    gcc = np.concatenate([[0], np.cumsum(ccounts[gflat])])
    sizes_b = gcc[g_offs + gsz] - gcc[g_offs]
    if int(sizes_a @ sizes_b) < MIN_FLAT_T:
        # tiny border batch: host twin beats dispatch overhead
        tm.mark("t_pack")
        _border_pass_host(index, rows, grid_of, ctr)
        tm.mark("t_kernel")
        return
    crows = core_rows[_expand(cstarts[gflat], ccounts[gflat])]
    b_offs = np.cumsum(sizes_b) - sizes_b
    kern = np.flatnonzero((sizes_b > 0) & (sizes_a > 0))
    # groups with no core candidate: rows stay noise (host `continue`)
    anch32 = _anchors(index, ds, index.ids[cgrids])
    if len(kern):
        ra, rb, seg, _, row_k = _row_cross(
            rows, sizes_a, a_offs, crows, sizes_b, b_offs, kern)
        d2dev = _d2_flat_res(ds, ra, rb, np.repeat(kern[row_k], seg),
                             anch32)
    tm.mark("t_pack")
    unc = np.empty(0, np.int64)
    if len(kern):
        # grit-lint: disable=hot-path-sync -- the stage's single intended block point: border assignment needs segment minima
        d2f = np.asarray(d2dev)[:len(ra)]
        soff = np.cumsum(seg) - seg
        nrow = len(soff)
        mn_f = np.minimum.reduceat(d2f, soff)
        # first flat index achieving each segment min (candidate order
        # is own-first host order, so ties break like the host pass)
        is_min = d2f == np.repeat(mn_f, seg)
        pos = np.flatnonzero(is_min)
        segid = np.repeat(np.arange(nrow), seg)
        _, first = np.unique(segid[pos], return_index=True)
        best = pos[first]
        d2b = d2f.copy()
        d2b[best] = np.inf                  # runner-up sans argmin
        mn2_f = np.minimum.reduceat(d2b, soff)
        mn = mn_f.astype(np.float64)
        mn2 = mn2_f.astype(np.float64)
        rvals = ra[best]                    # == the segment's a row
        with np.errstate(invalid="ignore"):         # inf - inf rows
            cert = np.isinf(mn2) | (mn2 - mn > 2.0 * band * eps2)
        if cert.any():
            rr = rvals[cert]
            cc = rb[best[cert]]
            d2v = ((pts[rr] - pts[cc]) ** 2).sum(axis=1)
            okh = d2v <= eps2
            lab[rr[okh]] = lab[cc[okh]]
        unc = rvals[~cert]
    if len(unc):
        unc = np.unique(unc)
        ctr["band_fallback"] = ctr.get("band_fallback", 0) + len(unc)
        _border_pass_host(index, unc, grid_of, ctr)
    tm.mark("t_kernel")
