"""Micro-batch incremental insert for a fitted :class:`GritIndex`.

Exactness argument (DESIGN.md §7).  DBSCAN is *monotone under
insertion*: neighborhood counts only grow, so existing core points stay
core, and a merge edge between two grids (MinDist of their core sets
<= eps) never disappears.  The from-scratch result on the union set
therefore differs from the fitted state only where the new points can
reach:

* **core status** can change only for points within eps of a new point.
  A new point lives in a *touched* grid t; anything within eps of it
  lies in a grid at integer offset < d of t (the paper's stencil
  bound).  Recomputing core status for the non-core points of
  ``touched ∪ Nei(touched)`` grids -- from scratch, against their full
  own+stencil candidate sets -- is thus exhaustive.
* **merges**: the core-grid graph gains vertices/edges only at grids
  whose core *set* changed (a MinDist decision depends on nothing
  else).  Re-deciding every (changed grid, core neighbor) pair with
  FastMerging and folding the decisions into a union-find over cluster
  ids splices the new components exactly; decisions between two
  unchanged grids are already encoded in the existing labels.
* **border/noise**: a labeled border stays valid (its witness core
  survives; its cluster id follows the union-find relabel).  A noise
  point can only flip to border via a *newly* core point, so only noise
  rows in the stencil of changed grids -- plus the new points
  themselves -- need the nearest-core test.

Everything runs in float64 with the same distance expression as the
brute oracle, so decisions are bit-identical to a from-scratch host
fit.  Cost: one O((n+m) log(n+m)) identifier re-sort (numpy lexsort --
milliseconds at 1e5) plus distance work proportional to the occupancy
of the touched stencil, not to n.
"""

from __future__ import annotations

import time
from typing import Any, Dict

import numpy as np

from repro.core.grids import group_rows
from repro.core.labels import UnionFind
from repro.core.merging import fast_merging


def insert_batch(index, batch) -> Dict[str, Any]:
    """Splice ``batch`` ([m, d]) into ``index`` in place.

    Returns a stats dict (grids touched/affected, newly-core count,
    merge checks, distance evals, timings).  Raises ``ValueError`` on
    shape/NaN problems, mirroring ``cluster()``'s input validation.
    """
    t0 = time.perf_counter()
    B = np.asarray(batch, np.float64)
    if B.ndim != 2 or B.shape[1] != index.d:
        raise ValueError(f"insert batch must be [m, {index.d}], "
                         f"got {B.shape}")
    m = B.shape[0]
    if m == 0:
        return {"inserted": 0, "n": index.n, "touched_grids": 0,
                "affected_grids": 0, "changed_grids": 0, "newly_core": 0,
                "newly_core_arrival": np.empty(0, np.int64),
                "merge_checks": 0, "dist_evals": 0, "id_shifted": False,
                "t_total": time.perf_counter() - t0}
    if not np.isfinite(B).all():
        raise ValueError("insert batch contains non-finite coordinates")

    d = index.d
    eps, eps2, min_pts = index.eps, index.eps * index.eps, index.min_pts

    # ---- 1. identifiers (fit-time formula) + origin shift ---------------
    new_ids = index.query_ids(B)
    neg = np.minimum(new_ids.min(axis=0), 0)
    shifted = bool((neg < 0).any())
    if shifted:
        # keep the stored-ids >= 0 invariant by translating the integer
        # lattice -- never by moving the float origin, which could
        # re-cell existing points through rounding
        shift = (-neg).astype(np.int64)
        index.ids = index.ids + shift[None, :]
        new_ids = new_ids + shift[None, :]
        index.id_shift = index.id_shift + shift

    # ---- 2. merge into the sorted structure -----------------------------
    n_old = index.n
    old_pt_ids = np.repeat(index.ids, index.counts, axis=0)       # [n, d]
    all_ids = np.concatenate([old_pt_ids, new_ids])
    order, sids, starts, counts, grid_of = group_rows(all_ids)
    n = n_old + m
    index.points = np.concatenate([index.points, B])[order]
    index.arrival = np.concatenate(
        [index.arrival, n_old + np.arange(m, dtype=np.int64)])[order]
    index.core = np.concatenate([index.core, np.zeros(m, bool)])[order]
    index.labels = np.concatenate(
        [index.labels, np.full(m, -1, np.int64)])[order]
    index.ids = sids[starts]
    index.starts, index.counts = starts, counts
    index.invalidate()
    G = index.num_grids
    pts, core = index.points, index.core
    tree = index.tree
    is_new = (order >= n_old)                                     # sorted

    # ---- 3. core recompute over the touched stencil ---------------------
    touched = np.unique(grid_of[is_new])
    ip_t, nb_t, _ = tree.query(index.ids[touched], include_self=False)
    affected = np.unique(np.concatenate([touched, nb_t]))
    ip, nb, _ = tree.query(index.ids[affected], include_self=False)
    newly_core_rows = []
    dist_evals = 0
    for k, g in enumerate(affected):
        own = np.arange(starts[g], starts[g] + counts[g])
        if counts[g] >= min_pts:                  # all-core shortcut
            gain = own[~core[own]]
        else:
            cand = own[~core[own]]
            if len(cand) == 0:
                continue
            p = pts[cand]
            cnt = np.full(len(cand), counts[g], np.int64)
            undecided = cnt < min_pts
            for ng in nb[ip[k]:ip[k + 1]]:        # offset-ascending
                if not undecided.any():
                    break
                crows = np.arange(starts[ng], starts[ng] + counts[ng])
                d2 = ((p[undecided][:, None, :]
                       - pts[crows][None, :, :]) ** 2).sum(-1)
                dist_evals += d2.size
                cnt[undecided] += (d2 <= eps2).sum(1)
                undecided = cnt < min_pts
            gain = cand[cnt >= min_pts]
        if len(gain):
            core[gain] = True
            newly_core_rows.append(gain)
    newly_core = (np.concatenate(newly_core_rows) if newly_core_rows
                  else np.empty(0, np.int64))
    index.invalidate()            # core CSR cache is stale now

    # ---- 4. merge splice over grids whose core set changed --------------
    core_per_grid = np.zeros(G, np.int64)
    np.add.at(core_per_grid, grid_of[core], 1)
    glabel = np.full(G, -1, np.int64)
    # core points that already carry a cluster id: pre-insert cores, and
    # former *border* points promoted to core (their old id is a real
    # connection -- the witness core that labeled them survives)
    labeled_core = core & (index.labels >= 0)
    np.maximum.at(glabel, grid_of[labeled_core], index.labels[labeled_core])
    fresh = (core_per_grid > 0) & (glabel < 0)    # all-new core grids
    glabel[fresh] = index.next_label + np.arange(int(fresh.sum()))
    n_comp = index.next_label + int(fresh.sum())
    uf = UnionFind(n_comp)
    merge_checks = 0
    changed = (np.unique(grid_of[newly_core]) if len(newly_core)
               else np.empty(0, np.int64))
    if len(changed):
        # inside a changed grid, every labeled core is <= eps from every
        # other core of that grid (grid diagonal == eps), so all their
        # cluster ids collapse into the grid's component.  Outside
        # changed grids the previous state already guarantees one id per
        # grid, so only changed grids need the sweep.
        in_changed = np.zeros(G, bool)
        in_changed[changed] = True
        for r in np.flatnonzero(labeled_core & in_changed[grid_of]):
            uf.union(int(index.labels[r]), int(glabel[grid_of[r]]))
        ipc, nbc, _ = tree.query(index.ids[changed], include_self=False)
        for k, g in enumerate(changed):
            sg = pts[index.grid_core_rows(g)]
            for g2 in nbc[ipc[k]:ipc[k + 1]]:
                if core_per_grid[g2] == 0:
                    continue
                if uf.find(glabel[g]) == uf.find(glabel[g2]):
                    continue
                merge_checks += 1
                if fast_merging(sg, pts[index.grid_core_rows(g2)], eps):
                    uf.union(glabel[g], glabel[g2])
    root = np.fromiter((uf.find(i) for i in range(n_comp)),
                       np.int64, count=n_comp)
    index.labels[core] = root[glabel[grid_of[core]]]
    relabel = (~core) & (index.labels >= 0)
    index.labels[relabel] = root[index.labels[relabel]]
    index.next_label = n_comp

    # ---- 5. border pass: new points + noise near newly-core grids -------
    new_noise = np.flatnonzero(is_new & ~core)
    region_noise = np.empty(0, np.int64)
    if len(changed):
        region = np.unique(np.concatenate([changed, nbc]))
        in_region = np.zeros(G, bool)
        in_region[region] = True
        region_noise = np.flatnonzero(
            in_region[grid_of] & ~core & (index.labels < 0))
    cand_rows = np.unique(np.concatenate([new_noise, region_noise]))
    if len(cand_rows):
        cgrids = np.unique(grid_of[cand_rows])
        ipb, nbb, _ = tree.query(index.ids[cgrids], include_self=False)
        for k, g in enumerate(cgrids):
            rows = cand_rows[(cand_rows >= starts[g])
                             & (cand_rows < starts[g] + counts[g])]
            crows = np.concatenate(
                [index.grid_core_rows(g)]
                + [index.grid_core_rows(g2) for g2 in nbb[ipb[k]:ipb[k + 1]]])
            if len(crows) == 0:
                continue
            d2 = ((pts[rows][:, None, :] - pts[crows][None, :, :]) ** 2
                  ).sum(-1)
            dist_evals += d2.size
            j = d2.argmin(axis=1)
            dmin = d2[np.arange(len(rows)), j]
            hit = dmin <= eps2
            index.labels[rows[hit]] = index.labels[crows[j[hit]]]

    return {
        "inserted": m, "n": n, "touched_grids": int(len(touched)),
        "affected_grids": int(len(affected)),
        "changed_grids": int(len(changed)),
        "newly_core": int(len(newly_core)),
        # arrival ids of the newly-core rows: lets a multi-shard caller
        # attribute promotions to owned vs ghost copies
        "newly_core_arrival": index.arrival[newly_core],
        "merge_checks": merge_checks, "dist_evals": dist_evals,
        "id_shifted": shifted,
        "t_total": time.perf_counter() - t0,
    }
