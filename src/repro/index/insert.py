"""Compatibility shim: the insert path moved into the unified mutation
plane ``repro.index.delta``.

What used to live here as an insert-only splice is now one *delta
engine* shared by both mutation directions -- ``insert_batch`` and
``delete_ids`` run the same direction-parameterized stages (touched
stencil closure -> per-grid core recompute -> FastMerging re-decision
at changed-core-set grids -> component relabel over the persistent
merge graph -> border reconciliation).  Import from
``repro.index.delta`` in new code; this module keeps the historical
name importable (same pattern as ``repro.core.distributed``).
"""

import warnings

from repro.index.delta import insert_batch  # noqa: F401

warnings.warn(
    "repro.index.insert is deprecated; import insert_batch from "
    "repro.index.delta (the unified mutation plane) instead.",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = ["insert_batch"]
