"""Read-only replicas: scale predict traffic without touching writes.

One mutable index serializes every reader behind every writer.  The
replication plane splits them: the *primary* absorbs mutations and
appends each top-level batch verbatim to its
:class:`~repro.index.delta.MutationLog`; a :class:`ReplicaIndex`
clones the primary's snapshot once and then *catches up* by replaying
the log from its cursor -- the delta engine is the replay operator, so
no per-row state ships after the initial clone.

**Bit-identity.**  The delta engine is deterministic: identical
starting state + identical mutation batches in identical order ==
identical fitted state, bit for bit.  A caught-up replica therefore
serves ``predict`` (and every read-out) exactly as the primary would
-- same labels, same ids, same float64 decisions -- which is what lets
a serve driver fan read-only traffic across R replicas while the
primary absorbs writes, with no answer drift (pinned by
``tests/test_topology.py``).  Sharded primaries log their topology ops
(split/merge) too: in the localized regime those re-mint label ids, so
a replica must replay them to stay id-identical, not just
partition-identical.

**Staleness.**  ``catch_up()`` replays everything the log still holds;
a replica whose cursor predates the log ``base`` (the primary
truncated replayed history) gets a ``ValueError`` and must re-clone.
``predict`` catches up automatically by default (read-your-writes
against the log); pass ``auto_catch_up=False`` for bounded-staleness
serving where ``catch_up()`` runs on the caller's schedule.
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np

__all__ = ["ReplicaIndex", "make_replicas"]


class ReplicaIndex:
    """Snapshot clone of a primary index + mutation-log catch-up."""

    def __init__(self, primary, *, auto_catch_up: bool = True):
        log = getattr(primary, "mutation_log", None)
        if log is None:
            raise ValueError(
                "primary has no mutation log: call "
                "enable_mutation_log() before creating replicas")
        self._log = log
        # the clone is a restore of the primary's snapshot: same class,
        # same state, no log of its own (its mutations are replays)
        self.index = type(primary).restore(primary.snapshot())
        self.cursor = int(primary.ops_applied)
        self.auto_catch_up = bool(auto_catch_up)

    # ------------------------------------------------------------------

    @property
    def d(self) -> int:
        return self.index.d

    @property
    def lag(self) -> int:
        """Ops the primary has applied that this replica has not."""
        return int(self._log.end - self.cursor)

    def catch_up(self) -> int:
        """Replay every log record past the cursor; returns the count.

        Raises ``ValueError`` when the cursor predates the truncated
        log (too stale to catch up -- re-clone from a fresh snapshot).
        """
        n = 0
        for op, payload in self._log.since(self.cursor):
            if op == "insert":
                self.index.insert(payload)
            elif op == "delete":
                self.index.delete(payload)
            elif op == "split":
                self.index.split_shard(int(payload[0]))
            else:
                self.index.merge_shards(int(payload[0]))
            n += 1
        self.cursor += n
        return n

    # ------------------------------------------------------------------
    # read plane (catch-up-then-delegate)
    # ------------------------------------------------------------------

    def predict(self, queries, **kw) -> np.ndarray:
        if self.auto_catch_up:
            self.catch_up()
        return self.index.predict(queries, **kw)

    def predict_async(self, queries, **kw):
        """Dispatch-then-resolve twin of :meth:`predict` (only on
        backends that have one -- the serve driver probes for it)."""
        if self.auto_catch_up:
            self.catch_up()
        dispatch = getattr(self.index, "predict_async", None)
        if dispatch is not None:
            return dispatch(queries, **kw)
        out = self.index.predict(queries, **kw)
        return lambda: out

    def labels_arrival(self) -> np.ndarray:
        if self.auto_catch_up:
            self.catch_up()
        return self.index.labels_arrival()

    def core_arrival(self) -> np.ndarray:
        if self.auto_catch_up:
            self.catch_up()
        return self.index.core_arrival()

    # ------------------------------------------------------------------
    # write plane: explicitly absent
    # ------------------------------------------------------------------

    def insert(self, points) -> Dict[str, Any]:
        raise TypeError("ReplicaIndex is read-only: route mutations to "
                        "the primary (replicas catch up from its log)")

    def delete(self, arrival_ids) -> Dict[str, Any]:
        raise TypeError("ReplicaIndex is read-only: route mutations to "
                        "the primary (replicas catch up from its log)")


def make_replicas(primary, r: int, *,
                  auto_catch_up: bool = True) -> "list[ReplicaIndex]":
    """Enable the primary's log and clone ``r`` replicas off it."""
    primary.enable_mutation_log()
    return [ReplicaIndex(primary, auto_catch_up=auto_catch_up)
            for _ in range(int(r))]
