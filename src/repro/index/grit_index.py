"""Fitted GriT index: the persistent artifact of one clustering run.

``cluster()`` engines historically burned the grid tree, core flags and
merge structure they built and returned bare labels, so serving a second
query cost a full refit.  ``GritIndex`` captures that fitted state --
the lex-sorted grid identifier arrays (level tree rebuilt lazily),
per-grid point ranges, core flags, canonical labels, eps/MinPts and the
device caps of the fit -- and serves it (DESIGN.md §7):

* :meth:`predict` labels new points *exactly* under the DBSCAN
  assignment rule: a query is noise unless some core point lies within
  eps, else it takes the label of the nearest core point.  Candidates
  come from the grid tree (every core point within eps of a query lies
  in a grid at integer offset < d from the query's cell -- the paper's
  stencil bound -- so the tree query is a complete candidate
  enumeration, including for queries landing in empty cells or outside
  the fitted bounding box).  Two execution modes: ``host`` (float64
  numpy, bit-identical to the brute oracle's distance formula) and
  ``kernel`` (slot-batched ``row_min_batch`` -- jitted, static-shaped,
  grown through :class:`PredictCaps` like the adaptive driver's caps).
* :meth:`insert` / :meth:`delete` mutate the fitted state through one
  shared *delta engine* (``repro.index.delta``): both directions
  recompute core status and merge decisions only in the offset-stencil
  of the touched grids, maintain the **persistent core-grid merge
  graph** (:attr:`merge_edges` -- the first-class structure cluster
  identity is recomputed from), and reconcile labels by connected
  components over it.  Deletes tombstone rows first; a
  threshold-triggered :meth:`compact` re-packs the flat arrays.
* :meth:`snapshot` / :meth:`restore` serialize the whole fitted state
  as a dict of flat numpy arrays (``np.savez``-able), so a fitted index
  ships between processes without refitting.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.grids import GridIndex, build_grids, group_rows
from repro.core.grid_tree import GridTree
from repro.core.device_dbscan import GritCaps
from repro.engine.adaptive import _pow2_at_least

from .delta import MutationLog
from .snapshot_io import (check_version, load_snapshot, save_snapshot)

# v2 adds the mutation-plane state: ``alive`` tombstone flags,
# ``next_arrival`` and the persistent merge-graph edge array.  v1
# snapshots stay restorable (no tombstones; merge graph rebuilt lazily
# on the first mutation that needs it).
_SNAPSHOT_VERSION = 2
_ACCEPTED_VERSIONS = (1, 2)


@dataclasses.dataclass
class PredictCaps:
    """Static shapes of the batched kernel predict path.

    Mirrors the adaptive driver's cap discipline: power-of-two
    quantization so similarly-shaped query batches share one jit cache
    entry, and never silent truncation -- the host packs the slots, so
    an overflow is *detected before* the kernel runs.  Each call packs
    at its own batch's pow2 bucket (one historical mega-batch must not
    inflate every later small predict); the index keeps a monotone
    *record* of the largest shapes seen, whose growth marks fresh jit
    keys for the serving telemetry.
    """

    group_cap: int = 0      # distinct query grids per call
    query_cap: int = 0      # queries per grid slot
    cand_cap: int = 0       # candidate core points per grid slot

    @classmethod
    def for_batch(cls, groups: int, queries: int, cands: int
                  ) -> "PredictCaps":
        return cls(group_cap=_pow2_at_least(groups, lo=8),
                   query_cap=_pow2_at_least(queries, lo=8),
                   cand_cap=_pow2_at_least(cands, lo=32))

    def grown_to(self, other: "PredictCaps") -> Tuple["PredictCaps", bool]:
        new = PredictCaps(
            group_cap=max(self.group_cap, other.group_cap),
            query_cap=max(self.query_cap, other.query_cap),
            cand_cap=max(self.cand_cap, other.cand_cap))
        return new, new != self


@dataclasses.dataclass
class GritIndex:
    """Fitted state of one GriT-DBSCAN run, in grid-sorted order.

    All per-point arrays are in *sorted* (lexicographic grid) order;
    ``arrival`` maps a sorted row back to its arrival index (fit points
    keep their original order 0..n_fit-1, inserted batches append).
    Stored identifiers satisfy ``ids >= 0``; ``id_shift`` records the
    integer translation applied when inserts extend the bounding box
    below the fitted origin, so the identifier of any coordinate is
    always ``floor((x - mins) / side) + id_shift`` -- the fit-time
    formula, never re-derived from a moved origin (which could re-cell
    points through float rounding).
    """

    points: np.ndarray        # [n, d] float64, sorted by grid id
    arrival: np.ndarray       # [n] int64 arrival index of each sorted row
    ids: np.ndarray           # [G, d] int64 lex-sorted non-empty grid ids
    starts: np.ndarray        # [G] int64 first sorted row of each grid
    counts: np.ndarray        # [G] int64 physical rows per grid
    core: np.ndarray          # [n] bool (sorted order; False on dead rows)
    labels: np.ndarray        # [n] int64 (sorted order; -1 noise/dead)
    eps: float
    min_pts: int
    side: float               # eps / sqrt(d), exactly as fit
    mins: np.ndarray          # [d] float64 fit-time identifier origin
    id_shift: np.ndarray      # [d] int64 (see class docstring)
    next_label: int           # smallest unused cluster id
    caps: Optional[GritCaps] = None   # device-fit caps (jit key reuse)
    predict_caps: PredictCaps = dataclasses.field(default_factory=PredictCaps)
    # -- mutation-plane state (repro.index.delta) ----------------------
    # Deleted rows *tombstone* first (alive=False, core=False, label=-1,
    # physical row kept so the CSR layout and grid numbering survive);
    # compact() re-packs once dead_fraction crosses compact_threshold.
    # Arrival ids are never reused: next_arrival is the id the next
    # inserted point gets, so delete(ids) stays unambiguous forever.
    alive: Optional[np.ndarray] = None        # [n] bool
    live_counts: Optional[np.ndarray] = None  # [G] live points per grid
    next_arrival: int = -1
    # The persistent core-grid merge graph: [E, 2] int64 grid-index
    # pairs (i < j, lex-sorted, deduped) with MinDist(cores_i, cores_j)
    # <= eps.  None = not built yet (v1 snapshots / fresh fits); the
    # delta engine builds it lazily on the first mutation and then
    # maintains it incrementally in both directions.  Cluster identity
    # of core points is exactly the connected components of this graph.
    merge_edges: Optional[np.ndarray] = None
    compact_threshold: float = 0.25
    _tree: Optional[GridTree] = dataclasses.field(
        default=None, repr=False, compare=False)
    _core_csr: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    _arr_to_row: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Device-resident serving state (repro.index.device_state): jax
    # mirrors of the serving-hot arrays, attached explicitly via
    # ensure_device_state().  Host numpy stays authoritative -- the
    # mirror is derived state (like _tree), never snapshotted.
    device_state: Optional[Any] = dataclasses.field(
        default=None, repr=False, compare=False)
    # Replication plane (repro.index.replica): ops_applied counts the
    # top-level insert/delete batches this index has absorbed -- the
    # cursor a read replica replays from -- and, once a MutationLog is
    # attached (enable_mutation_log), every such batch is appended
    # verbatim after it applies.  The log is runtime state shared with
    # the replicas, never snapshotted; a restored clone starts its own
    # count from the cursor its snapshot schema carries (0 here: the
    # single-host snapshot stays v2).
    ops_applied: int = 0
    mutation_log: Optional[MutationLog] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.alive is None:
            self.alive = np.ones(self.points.shape[0], bool)
        if self.live_counts is None:
            self.live_counts = np.asarray(self.counts, np.int64).copy()
        if self.next_arrival < 0:
            self.next_arrival = int(self.arrival.max(initial=-1)) + 1

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_fit(cls, points, eps: float, min_pts: int, labels,
                 core=None, grid: Optional[GridIndex] = None,
                 caps: Optional[GritCaps] = None) -> "GritIndex":
        """Build the index from one finished fit (arrival-order arrays).

        ``grid`` reuses an engine's float64 host partition when it
        carried one (``ClusterResult.grid``); ``core=None`` (e.g. the
        distributed engine) triggers a grid-based core identification --
        still O(n * stencil), never the O(n^2) oracle.
        """
        pts = np.asarray(points, np.float64)
        n, d = pts.shape
        labels = np.asarray(labels, np.int64)
        assert labels.shape == (n,), labels.shape
        gi = grid if isinstance(grid, GridIndex) else build_grids(pts, eps)
        if core is None:
            from repro.core.dbscan import _identify_cores
            tree = GridTree.build(gi.ids)
            indptr, nbr, _ = tree.query(gi.ids, include_self=False)
            core = _identify_cores(pts, gi, indptr, nbr, eps, min_pts, {})
        core = np.asarray(core, bool)
        order = np.asarray(gi.order, np.int64)
        return cls(
            points=pts[order], arrival=order,
            ids=np.asarray(gi.ids, np.int64).copy(),
            starts=np.asarray(gi.starts, np.int64).copy(),
            counts=np.asarray(gi.counts, np.int64).copy(),
            core=core[order], labels=labels[order],
            eps=float(eps), min_pts=int(min_pts), side=float(gi.side),
            mins=np.asarray(gi.mins, np.float64).copy(),
            id_shift=np.zeros(d, np.int64),
            next_label=int(labels.max(initial=-1)) + 1, caps=caps)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    @property
    def n(self) -> int:
        """Physical rows (tombstoned rows included until compaction)."""
        return int(self.points.shape[0])

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    @property
    def dead_fraction(self) -> float:
        n = self.n
        return (n - self.n_live) / n if n else 0.0

    @property
    def d(self) -> int:
        return int(self.points.shape[1])

    @property
    def num_grids(self) -> int:
        return int(self.ids.shape[0])

    @property
    def tree(self) -> GridTree:
        if self._tree is None:
            self._tree = GridTree.build(self.ids)
        return self._tree

    @property
    def fit_grid(self) -> GridIndex:
        """The current *live* partition as a host ``GridIndex``.

        Identifiers are returned in the canonical origin (``id_shift``
        subtracted), so the ``GridIndex`` invariant
        ``ids == floor((x - mins) / side)`` holds even after inserts
        extended the bounding box; a uniform integer shift preserves
        the lexicographic order, so the CSR layout is unchanged.  Rows
        are indexed in arrival *rank* order (live points sorted by
        arrival id -- identical to arrival order until a delete
        tombstones rows).
        """
        grid_of = np.repeat(np.arange(self.num_grids, dtype=np.int64),
                            self.counts)
        live = np.flatnonzero(self.alive)
        rank = np.argsort(self.arrival[live], kind="stable")
        keep = self.live_counts > 0
        new_of_old = np.cumsum(keep) - 1          # grid renumbering
        order = np.empty(len(live), np.int64)
        order[rank] = np.arange(len(live))
        point_grid = new_of_old[grid_of[live]][rank]
        ids = self.ids[keep] - self.id_shift[None, :]
        starts = np.cumsum(self.live_counts[keep]) - self.live_counts[keep]
        return GridIndex(order=order, ids=ids,
                         starts=starts, counts=self.live_counts[keep].copy(),
                         point_grid=point_grid, side=self.side,
                         mins=self.mins.copy(),
                         eta=int(ids.max(initial=0)))

    def labels_arrival(self) -> np.ndarray:
        """Labels of the *live* points, ordered by arrival id (fit
        points first, inserts appended; deleted rows omitted)."""
        live = self.alive
        return self.labels[live][np.argsort(self.arrival[live],
                                            kind="stable")]

    def core_arrival(self) -> np.ndarray:
        """Core flags of the live points, ordered by arrival id."""
        live = self.alive
        return self.core[live][np.argsort(self.arrival[live],
                                          kind="stable")]

    def points_arrival(self) -> np.ndarray:
        """Coordinates of the live points, ordered by arrival id (the
        surviving set :meth:`labels_arrival` labels, row for row)."""
        live = self.alive
        return self.points[live][np.argsort(self.arrival[live],
                                            kind="stable")]

    def arrival_live(self) -> np.ndarray:
        """Sorted arrival ids of the surviving points (what
        :meth:`labels_arrival` rows correspond to)."""
        return np.sort(self.arrival[self.alive])

    def rows_of_arrival(self, arrival_ids: np.ndarray) -> np.ndarray:
        """Sorted-order rows holding the given arrival ids (-1 where an
        id was never assigned or its row is tombstoned)."""
        if self._arr_to_row is None:
            a2r = np.full(self.next_arrival, -1, np.int64)
            live = np.flatnonzero(self.alive)
            a2r[self.arrival[live]] = live
            self._arr_to_row = a2r
        ids = np.asarray(arrival_ids, np.int64)
        out = np.full(ids.shape, -1, np.int64)
        ok = (ids >= 0) & (ids < self.next_arrival)
        out[ok] = self._arr_to_row[ids[ok]]
        return out

    def labels_at(self, arrival_ids: np.ndarray) -> np.ndarray:
        """Labels of specific (live) arrival ids; -1 for dead/unknown."""
        rows = self.rows_of_arrival(arrival_ids)
        out = np.full(rows.shape, -1, np.int64)
        ok = rows >= 0
        out[ok] = self.labels[rows[ok]]
        return out

    def core_at(self, arrival_ids: np.ndarray) -> np.ndarray:
        """Core flags of specific (live) arrival ids; False for dead."""
        rows = self.rows_of_arrival(arrival_ids)
        out = np.zeros(rows.shape, bool)
        ok = rows >= 0
        out[ok] = self.core[rows[ok]]
        return out

    def invalidate(self, keep_tree: bool = False) -> None:
        """Drop derived caches after a structural mutation.

        ``keep_tree=True`` preserves the level tree when the grid id
        array is untouched (deletes tombstone in place, so only the
        row-level caches go stale)."""
        if not keep_tree:
            self._tree = None
        self._core_csr = None
        self._arr_to_row = None

    # ------------------------------------------------------------------
    # identifiers + candidate enumeration
    # ------------------------------------------------------------------

    def query_ids(self, points: np.ndarray) -> np.ndarray:
        """Grid identifiers of arbitrary coordinates (may be negative or
        beyond the fitted range -- the tree query handles both)."""
        q = np.asarray(points, np.float64)
        return (np.floor((q - self.mins[None, :]) / self.side)
                .astype(np.int64) + self.id_shift[None, :])

    def _core_ranges(self):
        """Per-grid core-point rows: (core_rows [k], cstarts [G],
        ccounts [G]) -- core rows are ascending, hence grouped by grid."""
        if self._core_csr is None:
            core_rows = np.flatnonzero(self.core)
            cstarts = np.searchsorted(core_rows, self.starts)
            cends = np.searchsorted(core_rows, self.starts + self.counts)
            self._core_csr = (core_rows, cstarts, cends - cstarts)
        return self._core_csr

    def grid_core_rows(self, g: int) -> np.ndarray:
        """Sorted-order rows of grid ``g``'s core points."""
        core_rows, cstarts, ccounts = self._core_ranges()
        return core_rows[cstarts[g]:cstarts[g] + ccounts[g]]

    def _candidate_cores(self, q_ids: np.ndarray):
        """Core-point candidates for each query identifier.

        Returns ``(rows, q_of)``: candidate sorted-order rows and the
        query each belongs to.  Complete by the stencil bound (module
        docstring); queries in empty cells simply contribute the cores
        of their non-empty stencil neighbors (possibly none).
        """
        indptr, grids, _ = self.tree.query(q_ids, include_self=True)
        core_rows, cstarts, ccounts = self._core_ranges()
        per = ccounts[grids]                                   # [E]
        total = int(per.sum())
        base = np.repeat(np.cumsum(per) - per, per)            # [T]
        pos = np.arange(total, dtype=np.int64) - base
        rows = core_rows[np.repeat(cstarts[grids], per) + pos]
        q_of_entry = np.repeat(np.arange(len(q_ids), dtype=np.int64),
                               np.diff(indptr))
        q_of = np.repeat(q_of_entry, per)
        return rows, q_of

    # ------------------------------------------------------------------
    # predict
    # ------------------------------------------------------------------

    def predict(self, queries, *, mode: str = "auto", chunk: int = 2048,
                stats: Optional[dict] = None, return_d2: bool = False):
        """Label new points under the DBSCAN assignment rule (exact).

        Args:
          queries: [m, d] array-like; any coordinates (empty cells,
            outside the fitted bounding box, ... all fine).
          mode: "host" (float64 numpy -- bit-identical to the brute
            oracle), "kernel" (slot-batched jitted ``row_min_batch``,
            float32 with per-grid re-centering), "device" (resident-
            buffer guard-band path -- float32 kernels for the certain
            queries, host float64 for the band, output bit-identical
            to "host"), or "auto" (device when a resident state is
            attached, else kernel on accelerators / host on CPU).
          chunk: host-mode query chunk (memory bound).
          stats: optional dict filled with execution counters
            (mode, candidate totals, kernel cap growth).
          return_d2: also return [m] float64 squared distances to the
            nearest core candidate (inf where none) -- what a sharded
            router needs to combine answers from several slabs.

        Returns [m] int64 labels; -1 noise (``(labels, d2)`` under
        ``return_d2``).  Never mutates the fitted state; kernel mode may
        grow ``predict_caps`` (monotone -- the jit-shape memory), so
        concurrent kernel predicts on one shared index need external
        serialization.
        """
        q = np.asarray(queries, np.float64)
        if q.ndim != 2 or q.shape[1] != self.d:
            raise ValueError(
                f"queries must be [m, {self.d}], got {q.shape}")
        if q.shape[0] == 0:
            out = np.empty(0, np.int64)
            return (out, np.empty(0, np.float64)) if return_d2 else out
        if not np.isfinite(q).all():
            raise ValueError("queries contain non-finite coordinates")
        if mode == "auto":
            if self.device_state is not None:
                mode = "device"
            else:
                import jax
                mode = ("host" if jax.default_backend() == "cpu"
                        else "kernel")
        if stats is not None:
            stats["mode"] = mode
            stats["n_queries"] = int(q.shape[0])
        if not self.core.any():
            # no live cores (e.g. everything deleted): every query is
            # noise by the assignment rule -- skip the (possibly empty)
            # tree entirely
            out = np.full(q.shape[0], -1, np.int64)
            if stats is not None:
                stats["candidates"] = 0
            d2 = np.full(q.shape[0], np.inf, np.float64)
            return (out, d2) if return_d2 else out
        if mode == "host":
            out, d2 = self._predict_host(q, chunk, stats)
        elif mode == "kernel":
            out, d2 = self._predict_kernel(q, stats)
        elif mode == "device":
            out, d2 = self._predict_device(q, stats)
        else:
            raise ValueError(f"unknown predict mode {mode!r}")
        return (out, d2) if return_d2 else out

    def predict_async(self, queries, *, mode: str = "auto",
                      chunk: int = 2048, stats: Optional[dict] = None,
                      return_d2: bool = False):
        """Two-phase :meth:`predict`: dispatch now, block later.

        Returns a zero-argument ``resolve()`` producing exactly what
        :meth:`predict` would.  On the device path the kernel work is
        dispatched before this returns and ``resolve()`` blocks on it
        -- what :class:`~repro.serve.driver.ClusterServer` overlaps the
        next step's host packing with.  Other modes compute eagerly
        (``resolve()`` just hands the answer back), so callers need no
        mode-specific branches.
        """
        q = np.asarray(queries, np.float64)
        if mode == "auto" and self.device_state is not None:
            mode = "device"
        if (mode != "device" or q.shape[0] == 0
                or not self.core.any()):
            out = self.predict(q, mode=mode, chunk=chunk, stats=stats,
                               return_d2=return_d2)
            return lambda: out
        if q.ndim != 2 or q.shape[1] != self.d:
            raise ValueError(
                f"queries must be [m, {self.d}], got {q.shape}")
        if not np.isfinite(q).all():
            raise ValueError("queries contain non-finite coordinates")
        self.ensure_device_state()
        if stats is not None:
            stats["mode"] = "device"
            stats["n_queries"] = int(q.shape[0])
        from . import device_state as _dsm
        resolver = _dsm.predict_device_async(self, self.device_state,
                                             q, stats)

        def resolve():
            out, d2 = resolver()
            return (out, d2) if return_d2 else out

        return resolve

    def _predict_device(self, q: np.ndarray, stats: Optional[dict]):
        from . import device_state as _dsm
        self.ensure_device_state()
        return _dsm.predict_device(self, self.device_state, q, stats)

    def _predict_host(self, q: np.ndarray, chunk: int,
                      stats: Optional[dict]):
        eps2 = self.eps * self.eps
        m = q.shape[0]
        out = np.full(m, -1, np.int64)
        out_d2 = np.full(m, np.inf, np.float64)
        q_ids = self.query_ids(q)
        n_cand = 0
        for s in range(0, m, chunk):
            nq = min(chunk, m - s)
            rows, q_of = self._candidate_cores(q_ids[s:s + chunk])
            n_cand += len(rows)
            if len(rows) == 0:
                continue
            d2 = ((self.points[rows] - q[s + q_of]) ** 2).sum(axis=1)
            # nearest candidate per query; ``q_of`` is nondecreasing by
            # construction, so a segmented reduce beats a global sort
            cnt = np.bincount(q_of, minlength=nq)
            ne = cnt > 0
            seg = (np.cumsum(cnt) - cnt)[ne]
            dmin = np.minimum.reduceat(d2, seg)
            # argmin = first candidate matching its segment's minimum
            is_min = d2 == np.repeat(dmin, cnt[ne])
            pos = np.flatnonzero(is_min)
            qpos, first = np.unique(q_of[pos], return_index=True)
            best = pos[first]
            out_d2[s + qpos] = d2[best]
            hit = d2[best] <= eps2
            out[s + qpos[hit]] = self.labels[rows[best[hit]]]
        if stats is not None:
            stats["candidates"] = n_cand
        return out, out_d2

    def _predict_kernel(self, q: np.ndarray,
                        stats: Optional[dict]):
        """Slot-batched predict: queries grouped by grid cell, one
        ``row_min_batch`` call per (group_cap, query_cap, cand_cap) jit
        key.  Both operands are re-centered on the group's cell origin
        so the float32 contraction runs on stencil-scale coordinates
        (same policy as the device pipeline's kernel plane)."""
        import jax.numpy as jnp
        from repro.kernels import ops as kernel_ops

        eps2 = np.float32(self.eps) ** 2
        m = q.shape[0]
        q_ids = self.query_ids(q)
        # group queries sharing a cell: they share the candidate set
        qorder, sq, gstart, gcount, _ = group_rows(q_ids)
        B = len(gstart)
        rep_ids = sq[gstart]
        rows, g_of = self._candidate_cores(rep_ids)
        cand_per = np.zeros(B, np.int64)
        np.add.at(cand_per, g_of, 1)
        pc = PredictCaps.for_batch(B, int(gcount.max()),
                                   int(cand_per.max(initial=1)))
        self.predict_caps, grew = self.predict_caps.grown_to(pc)
        if stats is not None:
            stats.update(groups=B, candidates=int(len(rows)),
                         caps=dataclasses.asdict(pc), caps_grew=grew)

        a = np.zeros((pc.group_cap, pc.query_cap, self.d), np.float64)
        b = np.zeros((pc.group_cap, pc.cand_cap, self.d), np.float64)
        vb = np.zeros((pc.group_cap, pc.cand_cap), bool)
        brow = np.zeros((pc.group_cap, pc.cand_cap), np.int64)
        # scatter queries into their group's slot row (same flat-offset
        # pattern as the candidate scatter below)
        qgroup = np.repeat(np.arange(B, dtype=np.int64), gcount)
        qslot = np.arange(m, dtype=np.int64) - np.repeat(gstart, gcount)
        a[qgroup, qslot] = q[qorder]
        qslot_of = np.empty(m, np.int64)      # flat slot of each query
        qslot_of[qorder] = qgroup * pc.query_cap + qslot
        cbase = np.cumsum(cand_per) - cand_per
        slot = np.arange(len(rows)) - np.repeat(cbase, cand_per)
        b[g_of, slot] = self.points[rows]
        vb[g_of, slot] = True
        brow[g_of, slot] = rows
        # re-center on each group's cell origin (float64 subtract, then
        # cast -- stencil-scale coordinates for the f32 kernel)
        anchor = (self.mins[None, :]
                  + (rep_ids - self.id_shift[None, :]) * self.side)
        anchor = np.concatenate(
            [anchor, np.zeros((pc.group_cap - B, self.d))])[:, None, :]
        dmin, argi = kernel_ops.row_min_batch(
            jnp.asarray(a - anchor, jnp.float32),
            jnp.asarray(b - anchor, jnp.float32),
            valid_b=jnp.asarray(vb))
        # grit-lint: disable=hot-path-sync -- the predict kernel's intended block point: both reductions resolve in one transfer
        dmin = np.asarray(dmin).reshape(-1)
        argi = np.asarray(argi).reshape(-1)  # grit-lint: disable=hot-path-sync -- same block point as dmin above
        out = np.full(m, -1, np.int64)
        dq = dmin[qslot_of]
        aq = argi[qslot_of]
        hit = (dq <= eps2) & (aq >= 0)
        gq = qslot_of // pc.query_cap
        out[hit] = self.labels[brow[gq[hit], aq[hit]]]
        out_d2 = np.where(aq >= 0, dq.astype(np.float64), np.inf)
        return out, out_d2

    # ------------------------------------------------------------------
    # mutation plane (repro.index.delta)
    # ------------------------------------------------------------------

    def ensure_merge_graph(self) -> np.ndarray:
        """The persistent core-grid merge graph, building it if absent.

        Returns the ``[E, 2]`` edge array (grid-index pairs, i < j).
        Built once from the fitted state (FastMerging over every
        core-grid neighbor pair -- the cost shape of one fit's merging
        phase), then maintained incrementally by insert/delete."""
        if self.merge_edges is None:
            from .delta import build_merge_graph
            self.merge_edges = build_merge_graph(self)
        return self.merge_edges

    def ensure_device_state(self, interpret: Optional[bool] = None):
        """Attach (or return) the device-resident serving state.

        Uploads the CSR-sorted points, core/alive flags, grid ranges
        and merge edges as jax buffers; predict and the delta engine's
        hot stages then run through the batched kernels (guard-band
        exact -- outputs stay bit-identical to the host path).  The
        mirror follows every mutation automatically; ``interpret``
        forces Pallas interpret mode for the kernels (CPU-only
        runners)."""
        if self.device_state is None:
            from . import device_state as _dsm
            self.device_state = _dsm.DeviceState(self,
                                                 interpret=interpret)
        return self.device_state

    def drop_device_state(self) -> None:
        """Detach the resident mirror (serving falls back to host)."""
        self.device_state = None

    def enable_mutation_log(self) -> MutationLog:
        """Attach (or return) the replication log.

        From this call on, every top-level :meth:`insert` /
        :meth:`delete` batch is appended verbatim; the log base is the
        current :attr:`ops_applied`, so a replica cloned from a
        snapshot taken *now* starts exactly at the log base."""
        if self.mutation_log is None:
            self.mutation_log = MutationLog(base=self.ops_applied)
        return self.mutation_log

    def _log_mutation(self, op: str, payload: np.ndarray) -> None:
        self.ops_applied += 1
        if self.mutation_log is not None:
            self.mutation_log.append(op, payload)

    def insert(self, points) -> Dict[str, Any]:
        """Micro-batch incremental insert (stats schema: see
        :func:`repro.index.delta.insert_batch`)."""
        from .delta import insert_batch
        pts = np.asarray(points, np.float64)
        st = insert_batch(self, pts)
        self._log_mutation("insert", pts)
        return st

    def delete(self, arrival_ids) -> Dict[str, Any]:
        """Exact micro-batch delete by arrival id (stats schema: see
        :func:`repro.index.delta.delete_ids`).  Unknown or already
        deleted ids are rejected, not raised -- serving traffic carries
        them routinely (double deletes, TTL races); they stay in the
        mutation-log record (a replay rejects them identically)."""
        from .delta import delete_ids
        ids = np.asarray(arrival_ids, np.int64)
        st = delete_ids(self, ids)
        self._log_mutation("delete", ids)
        return st

    def compact(self) -> Dict[str, Any]:
        """Re-pack the flat arrays, dropping tombstoned rows (called
        automatically by :meth:`delete` past ``compact_threshold``)."""
        from .delta import compact
        return compact(self)

    # ------------------------------------------------------------------
    # snapshot / restore
    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, np.ndarray]:
        """Flat-array serialization of the whole fitted state.

        Every value is a numpy array (``np.savez(path, **snap)`` works
        directly); scalars are packed into small arrays.  Derived
        structures (level tree, core CSR, predict caps) are rebuilt on
        :meth:`restore`, not shipped.
        """
        caps = np.zeros(0, np.int64)
        if self.caps is not None:
            f = dataclasses.asdict(self.caps)
            # the 11th slot (dispatch strategy) is appended after the
            # original fixed-10 layout; restore accepts both lengths
            caps = np.asarray(
                [f["grid_cap"], f["frontier_cap"], f["k_cap"], f["c_cap"],
                 f["m_cap"], f["pair_cap"], f["grid_block"],
                 f["pair_block"], f["merge_iters"],
                 int(f["use_kernels"]), int(f["packed"])], np.int64)
        return {
            "version": np.asarray([_SNAPSHOT_VERSION], np.int64),
            "points": self.points, "arrival": self.arrival,
            "ids": self.ids, "starts": self.starts, "counts": self.counts,
            "core": self.core, "labels": self.labels,
            "mins": self.mins, "id_shift": self.id_shift,
            "scalars_f": np.asarray([self.eps, self.side], np.float64),
            "scalars_i": np.asarray([self.min_pts, self.next_label,
                                     self.next_arrival], np.int64),
            "caps": caps,
            # v2: mutation-plane state.  ``has_merge_graph``
            # distinguishes a built-but-empty graph (no merges) from an
            # absent one (rebuild lazily on restore).
            "alive": self.alive,
            "live_counts": self.live_counts,
            "merge_edges": (self.merge_edges if self.merge_edges is not None
                            else np.zeros((0, 2), np.int64)),
            "has_merge_graph": np.asarray(
                [self.merge_edges is not None], bool),
        }

    @classmethod
    def restore(cls, snap: Dict[str, np.ndarray]) -> "GritIndex":
        """Rebuild a fitted index from :meth:`snapshot` output (accepts
        an ``np.load`` mapping of a saved ``.npz`` as well).  Previous-
        version snapshots restore too: a v1 snapshot has no tombstones
        and no merge graph (rebuilt lazily by the first mutation)."""
        version = check_version(snap, "version", _ACCEPTED_VERSIONS,
                                "snapshot")
        caps_arr = np.asarray(snap["caps"])
        caps = None
        if caps_arr.size:
            v = [int(x) for x in caps_arr]
            caps = GritCaps(grid_cap=v[0], frontier_cap=v[1], k_cap=v[2],
                            c_cap=v[3], m_cap=v[4], pair_cap=v[5],
                            grid_block=v[6], pair_block=v[7],
                            merge_iters=v[8], use_kernels=bool(v[9]),
                            # pre-packed-dispatch snapshots carry 10
                            # slots; packed defaults on for them (a
                            # dispatch strategy, not fitted state)
                            packed=bool(v[10]) if len(v) > 10 else True)
        sf = np.asarray(snap["scalars_f"], np.float64)
        si = np.asarray(snap["scalars_i"], np.int64)
        merge_edges = None
        alive = live_counts = None
        next_arrival = -1
        if version >= 2:
            alive = np.asarray(snap["alive"], bool)
            live_counts = np.asarray(snap["live_counts"], np.int64)
            next_arrival = int(si[2])
            if bool(np.asarray(snap["has_merge_graph"])[0]):
                merge_edges = np.asarray(snap["merge_edges"],
                                         np.int64).reshape(-1, 2)
        return cls(
            points=np.asarray(snap["points"], np.float64),
            arrival=np.asarray(snap["arrival"], np.int64),
            ids=np.asarray(snap["ids"], np.int64),
            starts=np.asarray(snap["starts"], np.int64),
            counts=np.asarray(snap["counts"], np.int64),
            core=np.asarray(snap["core"], bool),
            labels=np.asarray(snap["labels"], np.int64),
            eps=float(sf[0]), min_pts=int(si[0]), side=float(sf[1]),
            mins=np.asarray(snap["mins"], np.float64),
            id_shift=np.asarray(snap["id_shift"], np.int64),
            next_label=int(si[1]), caps=caps,
            alive=alive, live_counts=live_counts,
            next_arrival=next_arrival, merge_edges=merge_edges)

    def save(self, path) -> None:
        save_snapshot(path, self.snapshot())

    @classmethod
    def load(cls, path) -> "GritIndex":
        return cls.restore(load_snapshot(path))
