"""Shared snapshot plumbing for the fitted indexes.

``GritIndex`` and ``ShardedGritIndex`` both serialize as a dict of flat
numpy arrays; the ``.npz`` read/write boilerplate (and the version
guard) used to be copy-pasted between them.  This module is the single
home for it: a snapshot *is* a ``Dict[str, np.ndarray]``, and these
helpers move one between memory and a ``np.savez`` file.
"""

from __future__ import annotations

import zipfile
import zlib
from typing import Dict, Sequence

import numpy as np


def save_snapshot(path, snap: Dict[str, np.ndarray]) -> None:
    """Write a flat-array snapshot dict as one ``.npz`` file/buffer."""
    np.savez(path, **snap)


def load_snapshot(path) -> Dict[str, np.ndarray]:
    """Read a ``.npz`` file/buffer back into a plain snapshot dict.

    A truncated or otherwise corrupt file raises a ``ValueError`` that
    names the file -- a half-written snapshot (crashed writer, partial
    download) must fail loudly at load, not as a ``BadZipFile`` /
    ``zlib.error`` deep inside the array reader.
    """
    try:
        with np.load(path) as data:
            return {k: data[k] for k in data.files}
    except (zipfile.BadZipFile, zlib.error, EOFError, OSError) as e:
        raise ValueError(
            f"snapshot file {path!r} is not a readable .npz "
            f"(truncated or corrupt?): {e}") from e


def check_version(snap: Dict[str, np.ndarray], key: str,
                  accepted: Sequence[int], what: str) -> int:
    """Validate a snapshot's schema version and return it.

    ``accepted`` lists every version ``restore()`` knows how to read
    (older versions stay restorable: missing arrays are rebuilt lazily
    by the caller).  Unknown versions raise, never mis-parse; a mapping
    without the version field (wrong file, truncated writer) raises the
    same clear ``ValueError`` instead of a raw ``KeyError``.
    """
    if key not in snap:
        raise ValueError(
            f"{what} has no {key!r} field -- not a {what} "
            f"(found keys {sorted(snap)[:8]}) or truncated")
    arr = np.asarray(snap[key])
    if arr.size == 0:
        raise ValueError(f"{what} {key!r} field is empty -- truncated?")
    version = int(arr.reshape(-1)[0])
    if version not in tuple(accepted):
        raise ValueError(
            f"{what} version {version} not in supported {tuple(accepted)}")
    return version
