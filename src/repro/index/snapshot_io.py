"""Shared snapshot plumbing for the fitted indexes.

``GritIndex`` and ``ShardedGritIndex`` both serialize as a dict of flat
numpy arrays; the ``.npz`` read/write boilerplate (and the version
guard) used to be copy-pasted between them.  This module is the single
home for it: a snapshot *is* a ``Dict[str, np.ndarray]``, and these
helpers move one between memory and a ``np.savez`` file.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def save_snapshot(path, snap: Dict[str, np.ndarray]) -> None:
    """Write a flat-array snapshot dict as one ``.npz`` file/buffer."""
    np.savez(path, **snap)


def load_snapshot(path) -> Dict[str, np.ndarray]:
    """Read a ``.npz`` file/buffer back into a plain snapshot dict."""
    with np.load(path) as data:
        return {k: data[k] for k in data.files}


def check_version(snap: Dict[str, np.ndarray], key: str,
                  accepted: Sequence[int], what: str) -> int:
    """Validate a snapshot's schema version and return it.

    ``accepted`` lists every version ``restore()`` knows how to read
    (older versions stay restorable: missing arrays are rebuilt lazily
    by the caller).  Unknown versions raise, never mis-parse.
    """
    version = int(np.asarray(snap[key])[0])
    if version not in tuple(accepted):
        raise ValueError(
            f"{what} version {version} not in supported {tuple(accepted)}")
    return version
