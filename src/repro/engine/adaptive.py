"""Adaptive-cap driver for the static-shape device pipeline.

The in-graph GriT pipeline (``device_dbscan``) trades the paper's dynamic
data structures for static caps; every cap carries an overflow flag.
Before this driver, callers hand-tuned ``GritCaps`` per dataset and a
missed cap silently truncated the result.  Now:

1. :func:`estimate_caps` derives an initial ``GritCaps`` from *host-side
   grid statistics* — an O(n log n) pass that is vanishing next to the
   clustering itself: the non-empty-grid count bounds ``grid_cap``, the
   max grid occupancy bounds ``m_cap`` (core points per grid can never
   exceed occupancy), and the stencil bound (3^d - 1, clamped to the
   exact offset-stencil size) seeds ``k_cap``.
2. :func:`adaptive_device_dbscan` runs the jitted pipeline, reads the
   per-cap :class:`OverflowReport`, geometrically grows exactly the caps
   that overflowed, and retries.  Caps are quantized to powers of two /
   block multiples so re-runs on similarly-sized data reuse the jit
   cache instead of recompiling per dataset.

Growth is geometric (default 2x), so reaching a true bound B from an
under-estimate costs O(log B) recompiles worst case; each cap is also
clamped at its provable maximum (e.g. candidates <= n, neighbors <= the
exact stencil size), so the loop terminates even on adversarial data.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.device_dbscan import (GritCaps, DeviceDBSCANResult,
                                      OverflowReport, device_dbscan)
from repro.core.grids import identifiers
from repro.core.grid_tree import offset_stencil, radius


class CapOverflowError(RuntimeError):
    """Raised when the adaptive driver exhausts its retries."""

    def __init__(self, attempts: List[dict]):
        self.attempts = attempts
        last = attempts[-1]
        super().__init__(
            f"static caps still overflowing after {len(attempts)} "
            f"attempt(s): {last['overflow']}; last caps {last['caps']}")


def _pow2_at_least(x: int, lo: int = 1) -> int:
    return max(lo, 1 << max(int(x) - 1, 0).bit_length())


def _mult8(x: int) -> int:
    return max(8, (int(x) + 7) // 8 * 8)


@dataclasses.dataclass
class ResidentCaps:
    """Static shapes of a :class:`~repro.index.GritIndex`'s
    device-resident serving state (``index.device_state``).

    Same cap discipline as :class:`GritCaps` / ``PredictCaps``:
    power-of-two quantization so mutation-driven growth re-jits at
    O(log n) distinct shapes, monotone growth (``grown_to``), and
    never silent truncation -- the host packs the resident buffers, so
    an overflow triggers a rebuild *before* any kernel runs.
    """

    row_cap: int = 0       # physical point rows (tombstones included)
    grid_cap: int = 0      # non-empty grids
    edge_cap: int = 0      # persistent merge-graph edges

    @classmethod
    def for_state(cls, rows: int, grids: int, edges: int
                  ) -> "ResidentCaps":
        return cls(row_cap=_pow2_at_least(rows, lo=256),
                   grid_cap=_pow2_at_least(grids, lo=64),
                   edge_cap=_pow2_at_least(edges, lo=64))

    def grown_to(self, other: "ResidentCaps"
                 ) -> Tuple["ResidentCaps", bool]:
        new = ResidentCaps(row_cap=max(self.row_cap, other.row_cap),
                           grid_cap=max(self.grid_cap, other.grid_cap),
                           edge_cap=max(self.edge_cap, other.edge_cap))
        return new, new != self


def stencil_neighbor_bound(d: int) -> int:
    """Exact max number of neighboring non-empty grids: the size of the
    offset-< d stencil, minus the grid itself."""
    deltas, _ = offset_stencil(d)
    return int(len(deltas)) - 1


def grid_stats(points: np.ndarray, eps: float,
               point_valid: Optional[np.ndarray] = None
               ) -> Tuple[int, int]:
    """(non-empty grid count, max occupancy) over the *valid* points."""
    pts = np.asarray(points, np.float64)
    if point_valid is not None:
        pts = pts[np.asarray(point_valid, bool)]
    if len(pts) == 0:
        return 1, 1
    ids, _, _ = identifiers(pts, eps)
    _, counts = np.unique(ids, axis=0, return_counts=True)
    return int(len(counts)), int(counts.max())


def _lex_rows(a: np.ndarray) -> np.ndarray:
    """Rows of an int array as a lexicographically sortable structured
    view (for vectorized row membership via searchsorted)."""
    a = np.ascontiguousarray(np.asarray(a, np.int64))
    return a.view([("", a.dtype)] * a.shape[1]).ravel()


def candidate_census(points: np.ndarray, eps: float, min_pts: int,
                     point_valid: Optional[np.ndarray] = None) -> int:
    """Exact host-side upper bound on any *small* grid's candidate
    total: for every non-empty grid with occupancy < MinPts, the sum of
    occupancies over its offset stencil (a superset of the grid tree's
    exact MinDist <= eps neighbor set, so the device pipeline's
    per-grid totals can never exceed it).  All-core grids skip the
    candidate scan entirely, so they don't constrain ``c_cap``.

    Vectorized: one ``searchsorted`` over the lex-sorted grid ids per
    stencil offset -- O(|stencil| * G log G), vanishing next to the
    fit."""
    pts = np.asarray(points, np.float64)
    if point_valid is not None:
        pts = pts[np.asarray(point_valid, bool)]
    if len(pts) == 0:
        return 1
    d = pts.shape[1]
    ids, _, _ = identifiers(pts, eps)
    uids, counts = np.unique(np.asarray(ids, np.int64), axis=0,
                             return_counts=True)
    small = counts < min_pts
    if not small.any():
        return 1
    keys = _lex_rows(uids)                       # sorted (np.unique)
    totals = np.zeros(int(small.sum()), np.int64)
    deltas, _ = offset_stencil(d)
    for delta in np.asarray(deltas, np.int64):
        probe = _lex_rows(uids[small] + delta)
        pos = np.searchsorted(keys, probe)
        pos = np.minimum(pos, len(keys) - 1)
        hit = keys[pos] == probe
        totals += np.where(hit, counts[pos], 0)
    return int(totals.max())


def _caps_from_stats(n: int, d: int, num_grids: int, max_occ: int,
                     cand_max: int, margin: float, extra_grids: int,
                     use_kernels: bool) -> GritCaps:
    """``GritCaps`` from (grid count, max occupancy, max small-grid
    candidate total) -- the quantization/clamp discipline shared by the
    global and the per-shard estimators."""
    grid_cap = _pow2_at_least(
        int(math.ceil(num_grids * margin)) + extra_grids, lo=8)
    grid_block = min(64, grid_cap)

    # 3^d - 1 stencil heuristic, clamped to the exact offset-stencil
    # size (the provable per-grid neighbor maximum); at low d the exact
    # bound is small enough to just provision outright
    bound = stencil_neighbor_bound(d)
    k_est = bound if bound <= 32 else max(3 ** d - 1, 8)
    k_cap = _mult8(min(k_est, bound, max(grid_cap - 1, 1)))

    m_cap = _mult8(max_occ)
    # candidate list of a small grid: the census is the exact stencil
    # occupancy sum, an upper bound on what the device's (possibly
    # tighter) MinDist neighbor set can produce
    c_cap = _pow2_at_least(min(n, cand_max), lo=32)

    # deduped (g < g') merge pairs are bounded by G * k / 2; density
    # rarely reaches it, but a half-bound start avoids a recompile on
    # blob-like data where most neighbor pairs are core-core
    pair_cap = _pow2_at_least(num_grids * k_cap // 2 + 8, lo=64)
    pair_block = min(256, pair_cap)

    # the per-level surviving prefix count depends on the id
    # distribution, not just geometry; the r^(d-1) fanout regularly
    # undershoots by one pow2 step on blob-like data, and a too-small
    # frontier costs a full overflow fit + retry on EVERY caps=None
    # call -- double it up front (a [frontier_cap] working set, so the
    # headroom is nearly free)
    r = 2 * radius(d) + 1
    frontier_cap = _pow2_at_least(
        2 * min(int(r ** max(d - 1, 1)), 256), lo=32)

    # paper Theorem 3: FastMerging terminates within |s_i| + |s_j|
    # iterations; lax.while_loop makes a generous bound free at runtime
    merge_iters = 2 * m_cap + 4

    return GritCaps(grid_cap=grid_cap, frontier_cap=frontier_cap,
                    k_cap=k_cap, c_cap=c_cap, m_cap=m_cap,
                    pair_cap=pair_cap, grid_block=grid_block,
                    pair_block=pair_block, merge_iters=merge_iters,
                    use_kernels=use_kernels)


def estimate_caps(points: np.ndarray, eps: float, min_pts: int,
                  point_valid: Optional[np.ndarray] = None,
                  margin: float = 1.25,
                  extra_grids: int = 2,
                  use_kernels: bool = False) -> GritCaps:
    """Initial ``GritCaps`` from host grid statistics (see module doc).

    ``extra_grids`` reserves slots for the sentinel grids that padding
    points (``point_valid == False`` -> PAD_COORD) occupy.
    ``use_kernels`` selects the kernelized distance plane; it rides on
    the caps (same static jit key) and is preserved by ``grow_caps``.
    """
    pts = np.asarray(points)
    n, d = pts.shape
    num_grids, max_occ = grid_stats(pts, eps, point_valid)
    cand_max = candidate_census(pts, eps, min_pts, point_valid)
    return _caps_from_stats(n, d, num_grids, max_occ, cand_max,
                            margin, extra_grids, use_kernels)


def _shard_point_sets(points: np.ndarray, eps: float, n_shards: int):
    """The exact per-shard point set of a distributed fit: the shard's
    own slab plus the 2*eps boundary bands its neighbors ship as ghosts
    (the same selection predicate as ``repro.dist.halo.halo_buffer``)."""
    from repro.dist.sharding import slab_cuts  # deferred: dist is optional
    pts = np.asarray(points, np.float64)
    order, cut_idx, _ = slab_cuts(pts, eps, n_shards)
    starts = np.concatenate([[0], cut_idx]).astype(np.int64)
    ends = np.concatenate([cut_idx, [len(pts)]]).astype(np.int64)
    spts = pts[order]

    def ship(s: int, side: str) -> np.ndarray:
        seg = spts[starts[s]:ends[s]]
        if not len(seg):
            return seg
        x0 = seg[:, 0]
        if side == "hi":
            return seg[x0 >= x0.max() - 2 * eps]
        return seg[x0 <= x0.min() + 2 * eps]

    for s in range(n_shards):
        parts = [spts[starts[s]:ends[s]]]
        if s > 0:
            parts.append(ship(s - 1, "hi"))
        if s < n_shards - 1:
            parts.append(ship(s + 1, "lo"))
        sub = np.concatenate(parts)
        if len(sub):
            yield sub


def estimate_shard_caps(points: np.ndarray, eps: float, min_pts: int,
                        n_shards: int, margin: float = 1.25,
                        extra_grids: int = 2,
                        use_kernels: bool = False) -> GritCaps:
    """Per-shard ``GritCaps`` for the distributed fit.

    Global grid statistics are a valid but wasteful bound for the
    shard-local pipelines: slab cuts land on grid lines, so the worst
    *shard's* grid count is roughly ``1 / n_shards`` of the global one,
    yet shard-max caps derived globally inflate every shard to the
    whole dataset's table.  This runs :func:`grid_stats` /
    :func:`candidate_census` per shard over the exact per-shard point
    set (own slab + the neighbors' 2*eps ghost bands) and takes the max
    over shards -- still one shared static shape for the SPMD step,
    but sized to the worst shard instead of the union."""
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    if n_shards <= 1:
        return estimate_caps(pts, eps, min_pts, margin=margin,
                             extra_grids=extra_grids,
                             use_kernels=use_kernels)
    num_grids, max_occ, cand_max, n_max = 1, 1, 1, 1
    for sub in _shard_point_sets(pts, eps, n_shards):
        g, o = grid_stats(sub, eps)
        c = candidate_census(sub, eps, min_pts)
        num_grids, max_occ = max(num_grids, g), max(max_occ, o)
        cand_max, n_max = max(cand_max, c), max(n_max, len(sub))
    return _caps_from_stats(n_max, d, num_grids, max_occ, cand_max,
                            margin, extra_grids, use_kernels)


def grow_caps(caps: GritCaps, overflowed: Tuple[str, ...], *,
              n: int, d: int, growth: float = 2.0) -> GritCaps:
    """Grow exactly the caps named in ``overflowed`` (an
    ``OverflowReport.overflowing()`` tuple), geometrically, clamped at
    each cap's provable maximum."""
    assert overflowed, "grow_caps called without any overflow"
    kw = dataclasses.asdict(caps)
    g = lambda x: int(math.ceil(x * growth))

    if "grid" in overflowed:
        kw["grid_cap"] = _pow2_at_least(g(caps.grid_cap))
    if "frontier" in overflowed:
        kw["frontier_cap"] = _pow2_at_least(
            min(g(caps.frontier_cap), kw["grid_cap"]))
    if "neighbors" in overflowed:
        kw["k_cap"] = _mult8(min(g(caps.k_cap), stencil_neighbor_bound(d)))
    if "candidates" in overflowed:
        kw["c_cap"] = min(_pow2_at_least(g(caps.c_cap)),
                          _pow2_at_least(n))
    if "core_set" in overflowed:
        kw["m_cap"] = _mult8(min(g(caps.m_cap), n))
    if "pairs" in overflowed:
        kw["pair_cap"] = _pow2_at_least(
            min(g(caps.pair_cap), kw["grid_cap"] * kw["k_cap"]))

    kw["grid_block"] = min(64, kw["grid_cap"])
    kw["pair_block"] = min(256, kw["pair_cap"])
    kw["merge_iters"] = 2 * kw["m_cap"] + 4
    new = GritCaps(**kw)
    cap_of = {"grid": "grid_cap", "frontier": "frontier_cap",
              "neighbors": "k_cap", "candidates": "c_cap",
              "core_set": "m_cap", "pairs": "pair_cap"}
    grew = any(getattr(new, cap_of[f]) > getattr(caps, cap_of[f])
               for f in overflowed if f in cap_of)
    if not grew:
        # every overflowing cap is already at its clamp -- nothing left
        # to grow; surface that instead of looping forever (drivers with
        # a retry history catch this and re-raise with the full trail)
        raise CapOverflowError(
            [{"caps": dataclasses.asdict(caps), "overflow": overflowed}])
    return new


def adaptive_loop(run, grow, describe, caps, max_retries: int):
    """The shared grow/retry protocol behind both adaptive drivers.

    ``run(caps) -> (result, OverflowReport)`` executes one attempt;
    ``grow(caps, overflowed) -> caps`` grows exactly the named caps (may
    raise :class:`CapOverflowError` at a clamp); ``describe(caps)``
    renders caps for the attempt trail.  When ``grid`` overflows, the
    flags downstream of the grid table (frontier, neighbors, candidates,
    core_set, pairs) are dropped for that round: a truncated table
    funnels the excess points into the last grid, making them unreliable
    until the grids fit.  ``halo`` is measured from the raw points and
    stays trustworthy, so it keeps growing alongside ``grid``.

    Returns (result, attempts); raises :class:`CapOverflowError` with
    the full real attempt trail on exhaustion or clamp.
    """
    attempts: List[dict] = []
    for _ in range(max_retries + 1):
        result, report = run(caps)
        overflowed = report.overflowing()
        attempts.append({"caps": describe(caps), "overflow": overflowed})
        obs.counter("adaptive.attempts").inc()
        if not overflowed:
            return result, attempts
        obs.counter("adaptive.retries").inc()
        for f in overflowed:
            obs.counter(f"adaptive.overflow.{f}").inc()
        if "grid" in overflowed:
            overflowed = tuple(f for f in overflowed
                               if f in ("grid", "halo"))
        try:
            caps = grow(caps, overflowed)
        except CapOverflowError:
            raise CapOverflowError(attempts) from None
    raise CapOverflowError(attempts)


def adaptive_device_dbscan(points, eps: float, min_pts: int,
                           caps: Optional[GritCaps] = None, *,
                           point_valid=None, max_retries: int = 8,
                           growth: float = 2.0,
                           use_kernels: Optional[bool] = None
                           ) -> Tuple[DeviceDBSCANResult, List[dict]]:
    """Run ``device_dbscan``, growing caps on overflow until exact.

    ``use_kernels`` overrides the distance plane carried by ``caps``
    (None leaves the caps' own setting -- False for estimated caps --
    untouched); the flag survives every growth round unchanged.

    Returns (result, attempts); ``attempts`` records the caps and the
    overflowing-cap names of every try (the last entry has no overflow).
    Raises :class:`CapOverflowError` if ``max_retries`` growth rounds do
    not suffice (geometric growth makes that pathological).
    """
    pts = jnp.asarray(points, jnp.float32)
    n, d = pts.shape
    if caps is None:
        caps = estimate_caps(np.asarray(points), eps, min_pts,
                             point_valid=None if point_valid is None
                             else np.asarray(point_valid),
                             use_kernels=bool(use_kernels))
    elif use_kernels is not None and caps.use_kernels != use_kernels:
        caps = dataclasses.replace(caps, use_kernels=use_kernels)

    def run(c):
        res = device_dbscan(pts, eps, min_pts, c, point_valid=point_valid)
        return res, jax.device_get(res.report)

    result, attempts = adaptive_loop(
        run,
        lambda c, flags: grow_caps(c, flags, n=n, d=d, growth=growth),
        dataclasses.asdict, caps, max_retries)
    # occupancy-packed dispatch telemetry (device_dbscan module doc):
    # grids actually swept per tier vs the grid_cap slots the dense
    # strategy would sweep -- the work-proportionality regression gauge
    tiers = np.asarray(jax.device_get(result.dispatch_tiers), np.int64)
    reg = obs.registry()
    for i in range(3):
        reg.gauge(f"device.dispatch.tier{i + 1}_grids").set(float(tiers[i]))
    reg.gauge("device.dispatch.dense_slots").set(float(tiers[3]))
    reg.gauge("device.dispatch.grids_swept").set(float(tiers.sum()))
    reg.gauge("device.dispatch.grid_cap").set(
        float(attempts[-1]["caps"]["grid_cap"]))
    return result, attempts
