"""Engine registry: one ``cluster()`` entry point, many backends.

Every clustering backend in the repo registers itself here under a short
name (``brute``, ``grit``, ``grit-ldf``, ``device``, ``device-kernels``,
``distributed``) and is invoked through :func:`cluster` with identical
semantics: exact DBSCAN, labels in original point order.
``engine="auto"`` picks a backend from the runtime (multi-device ->
distributed, TPU -> the kernelized device pipeline, other accelerators
-> the device pipeline, otherwise the host GriT pipeline).

Input validation happens *here*, once, for every engine: empty point
sets, ``n < min_pts`` (every point would be noise -- always a caller
bug) and non-finite coordinates raise ``ValueError`` before any engine
runs, so no backend needs its own guards and all of them fail
identically.

Registering a new engine:

    @register_engine("my-engine", description="...")
    def _my_engine(points, eps, min_pts, **opts) -> ClusterResult: ...

Engines receive host numpy points and must return a
:class:`~repro.engine.result.ClusterResult`; anything cap-bounded must
either resolve overflow itself (adaptive retry) or surface it in
``result.overflow``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from repro import obs

from .result import ClusterResult


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    name: str
    fn: Callable[..., ClusterResult]
    description: str


_REGISTRY: Dict[str, EngineSpec] = {}


def register_engine(name: str, description: str = ""):
    """Decorator: register ``fn(points, eps, min_pts, **opts)`` under ``name``."""

    def deco(fn: Callable[..., ClusterResult]):
        if name in _REGISTRY:
            raise ValueError(f"engine {name!r} already registered")
        _REGISTRY[name] = EngineSpec(
            name=name, fn=fn,
            description=description or (fn.__doc__ or "").strip())
        return fn

    return deco


def _ensure_loaded() -> None:
    # the built-in engines live in .engines; importing it populates the
    # registry (deferred to break the registry <-> engines import cycle)
    from . import engines  # noqa: F401


def available_engines() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def get_engine(name: str) -> EngineSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown engine {name!r}; available: {available_engines()}")
    return _REGISTRY[name]


def engine_descriptions() -> Dict[str, str]:
    _ensure_loaded()
    return {n: s.description for n, s in sorted(_REGISTRY.items())}


def resolve_auto() -> str:
    """Pick a backend for ``engine="auto"`` from the runtime.

    * >1 jax devices        -> "distributed" (spatial sharding + halo;
                               on a TPU mesh the shard-local pipeline
                               defaults to the Pallas kernel distance
                               plane -- see the engine's ``use_kernels``)
    * TPU backend           -> "device-kernels" (single jitted XLA
                               program, adaptive caps, MXU Pallas
                               distance plane -- on TPU the kernels are
                               the point)
    * other accelerator     -> "device" (the one-shot broadcast plane
                               fuses well under XLA; the kernels'
                               non-TPU tiled loop is serialized and has
                               not been benchmarked on GPU)
    * otherwise             -> "grit" (host pipeline, dynamic shapes:
                               fastest on CPU for the sizes a single
                               host should handle)
    """
    import jax
    if jax.device_count() > 1:
        return "distributed"
    if jax.default_backend() == "tpu":
        return "device-kernels"
    if jax.default_backend() != "cpu":
        return "device"
    return "grit"


def _attach_index(result: ClusterResult, pts: np.ndarray, eps: float,
                  min_pts: int) -> ClusterResult:
    """Build the fitted :class:`~repro.index.GritIndex` from an engine
    result (the ``return_index=True`` path).

    Host engines already carry the float64 ``GridIndex`` and core flags,
    so this is pure reshuffling; device/distributed results trigger a
    host partition rebuild (and, for engines that report no core flags,
    a grid-based core identification) inside ``from_fit``.  The caps of
    the final adaptive attempt ride along so a device-fitted index can
    reuse the same jit key when serving.
    """
    from repro.index import GritIndex
    from repro.core.device_dbscan import GritCaps

    caps = None
    if result.attempts:
        # the distributed attempt dicts carry extra caps (halo_cap) on
        # top of the GritCaps fields; keep the GritCaps subset
        names = {f.name for f in dataclasses.fields(GritCaps)}
        kw = {k: v for k, v in result.attempts[-1]["caps"].items()
              if k in names}
        try:
            caps = GritCaps(**kw) if kw else None
        except TypeError:
            caps = None
    index = GritIndex.from_fit(pts, eps, min_pts, labels=result.labels,
                               core=result.core, grid=result.grid,
                               caps=caps)
    result.index = index
    if result.grid is None:
        result.grid = index.fit_grid
    if result.core is None:
        result.core = index.core_arrival()
        result.core_idx = np.flatnonzero(result.core)
    return result


def cluster(points, eps: float, min_pts: int, *,
            engine: str = "auto", return_index: bool = False,
            **opts) -> ClusterResult:
    """Exact DBSCAN via the named engine (the production entry point).

    Args:
      points: [n, d] array-like.
      eps, min_pts: DBSCAN parameters (paper's eps / MinPts).
      engine: registry name, or "auto" (see :func:`resolve_auto`).
      return_index: also build a fitted :class:`~repro.index.GritIndex`
        (grid partition + core flags + labels, ready for ``predict`` /
        ``insert`` / ``snapshot``) and attach it as ``result.index`` --
        the fit-once / serve-many path, available for every engine.
      **opts: engine-specific options (e.g. ``caps=``, ``mesh=``,
        ``variant=`` -- see each engine's docstring).

    Returns a :class:`ClusterResult`; ``labels[i] >= 0`` is a cluster
    id, ``-1`` noise, in the original order of ``points``.
    """
    pts = np.asarray(points)
    if pts.ndim != 2 or pts.shape[0] == 0:
        raise ValueError(f"points must be [n, d] with n > 0, got {pts.shape}")
    if not (eps > 0):
        raise ValueError(f"eps must be positive, got {eps}")
    if min_pts < 1:
        raise ValueError(f"min_pts must be >= 1, got {min_pts}")
    if pts.shape[0] < min_pts:
        raise ValueError(
            f"n={pts.shape[0]} < min_pts={min_pts}: no point can ever be "
            f"core, every point would come out as noise")
    if not np.isfinite(pts).all():
        bad = int((~np.isfinite(pts).all(axis=1)).sum())
        raise ValueError(
            f"points contain non-finite coordinates ({bad} row(s) with "
            f"NaN/Inf); clean the input before clustering")
    name = resolve_auto() if engine == "auto" else engine
    spec = get_engine(name)
    obs.counter(f"engine.cluster.{name}").inc()
    with obs.span("engine.cluster", engine=name, n=int(pts.shape[0]),
                  d=int(pts.shape[1])):
        result = spec.fn(pts, float(eps), int(min_pts), **opts)
    assert result.labels.shape == (pts.shape[0],), \
        f"engine {name}: labels shape {result.labels.shape}"
    if return_index:
        with obs.span("engine.attach_index", engine=name):
            result = _attach_index(result, np.asarray(pts, np.float64),
                                   float(eps), int(min_pts))
    return result
