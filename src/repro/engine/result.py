"""The one result type every clustering engine returns.

``ClusterResult`` is the host-facing contract of :func:`repro.engine.cluster`:
numpy labels in original point order, plus enough provenance (engine
name, overflow trail, per-stage stats) to debug a run without re-running
it.  Device/distributed engines surface their static-cap ``OverflowReport``
here as plain tuples of cap names — an *empty* tuple is the success
criterion; a non-empty one means the result was truncated and must not
be trusted (the adaptive driver retries before ever letting that
escape).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ClusterResult:
    """Clustering of one point set.

    Attributes:
      labels:   [n] int64, original point order; >= 0 cluster id, -1 noise.
      engine:   registry name of the engine that produced the labels.
      n_clusters: number of distinct non-noise labels.
      core:     [n] bool core-point flags, or None if the engine does not
                report them (e.g. the distributed path).
      overflow: names of static caps still overflowing in the *final*
                attempt; empty for host engines and for any result the
                adaptive driver accepted.
      attempts: one dict per adaptive-cap attempt:
                {"caps": {...}, "overflow": (cap names...)}.  Host engines
                leave this empty.
      stats:    engine-specific counters/timings (paper's kappa, distance
                evals, per-stage seconds, ...).
    """

    labels: np.ndarray
    engine: str
    n_clusters: int
    core: Optional[np.ndarray] = None
    overflow: Tuple[str, ...] = ()
    attempts: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def build(cls, labels, engine: str, **kw) -> "ClusterResult":
        labels = np.asarray(labels, np.int64)
        n_clusters = int(len(np.unique(labels[labels >= 0])))
        core = kw.pop("core", None)
        if core is not None:
            core = np.asarray(core, bool)
        return cls(labels=labels, engine=engine, n_clusters=n_clusters,
                   core=core, **kw)

    @property
    def noise_count(self) -> int:
        return int((self.labels < 0).sum())
