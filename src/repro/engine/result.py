"""The one result type every clustering engine returns.

``ClusterResult`` is the host-facing contract of :func:`repro.engine.cluster`:
numpy labels in original point order, plus enough provenance (engine
name, overflow trail, per-stage stats) to debug a run without re-running
it.  Device/distributed engines surface their static-cap ``OverflowReport``
here as plain tuples of cap names — an *empty* tuple is the success
criterion; a non-empty one means the result was truncated and must not
be trusted (the adaptive driver retries before ever letting that
escape).

Beyond labels, a result carries what downstream tooling (the fitted
``GritIndex``, serving, diagnostics) would otherwise re-derive:

* ``core`` / ``core_idx`` — core-point flags and their indices;
* ``grid`` — the host :class:`~repro.core.grids.GridIndex` the engine
  built (exact float64 identifiers).  Host engines attach it for free;
  device engines run on float32 identifiers whose cell assignment can
  disagree with the float64 host partition at cell edges, so they leave
  it ``None`` and the ``return_index=True`` path of ``cluster()``
  rebuilds it host-side (one O(n log n) pass) when an index is wanted.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ClusterResult:
    """Clustering of one point set.

    Attributes:
      labels:   [n] int64, original point order; >= 0 cluster id, -1 noise.
      engine:   registry name of the engine that produced the labels.
      n_clusters: number of distinct non-noise labels.
      core:     [n] bool core-point flags, or None if the engine does not
                report them (e.g. the distributed path).
      core_idx: [k] int64 indices of the core points (ascending), or None
                when ``core`` is None.
      grid:     host :class:`~repro.core.grids.GridIndex` (lex-sorted
                non-empty grid identifiers + CSR point ranges + the
                eps/sqrt(d) partition origin), or None for engines that
                never build a float64 host partition (brute, device,
                distributed).
      overflow: names of static caps still overflowing in the *final*
                attempt; empty for host engines and for any result the
                adaptive driver accepted.
      attempts: one dict per adaptive-cap attempt:
                {"caps": {...}, "overflow": (cap names...)}.  Host engines
                leave this empty.
      stats:    engine-specific counters/timings (paper's kappa, distance
                evals, per-stage seconds, ...).
      index:    fitted :class:`~repro.index.GritIndex` when the caller
                asked ``cluster(..., return_index=True)``; None otherwise.
    """

    labels: np.ndarray
    engine: str
    n_clusters: int
    core: Optional[np.ndarray] = None
    core_idx: Optional[np.ndarray] = None
    grid: Optional[Any] = None
    overflow: Tuple[str, ...] = ()
    attempts: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    index: Optional[Any] = None

    @classmethod
    def build(cls, labels, engine: str, **kw) -> "ClusterResult":
        labels = np.asarray(labels, np.int64)
        n_clusters = int(len(np.unique(labels[labels >= 0])))
        core = kw.pop("core", None)
        if core is not None:
            core = np.asarray(core, bool)
        if kw.get("core_idx") is None and core is not None:
            kw["core_idx"] = np.flatnonzero(core)
        return cls(labels=labels, engine=engine, n_clusters=n_clusters,
                   core=core, **kw)

    @property
    def noise_count(self) -> int:
        return int((self.labels < 0).sum())
