"""engine: the unified clustering API (registry + adaptive-cap driver).

    from repro.engine import cluster
    result = cluster(points, eps=3000.0, min_pts=10)   # engine="auto"

See DESIGN.md §3 for the architecture.
"""

from .result import ClusterResult
from .registry import (available_engines, cluster, engine_descriptions,
                       get_engine, register_engine, resolve_auto)
from .adaptive import (CapOverflowError, adaptive_device_dbscan,
                       adaptive_loop, candidate_census, estimate_caps,
                       estimate_shard_caps, grow_caps, grid_stats,
                       stencil_neighbor_bound)

__all__ = [
    "ClusterResult", "cluster", "available_engines", "engine_descriptions",
    "get_engine", "register_engine", "resolve_auto",
    "CapOverflowError", "adaptive_device_dbscan", "adaptive_loop",
    "candidate_census", "estimate_caps", "estimate_shard_caps",
    "grow_caps", "grid_stats", "stencil_neighbor_bound",
]
