"""The built-in engines behind :func:`repro.engine.cluster`.

========== =============================================================
name       backing pipeline
========== =============================================================
brute      O(n^2) host oracle (``brute_dbscan``) -- the ground truth the
           conformance suite holds every other engine to.
grit       paper-faithful host GriT-DBSCAN (Alg 6: grid tree +
           FastMerging + BFS over seed grids).
grit-ldf   host GriT-DBSCAN-LDF (union-find, low-density-first, §5.2).
device     fully in-graph jitted pipeline with *adaptive* static caps:
           estimated from grid statistics, grown geometrically on
           overflow (never silently truncated).  Naive-broadcast
           distance plane (the in-graph oracle).
device-kernels
           the same pipeline with ``use_kernels=True``: core/border
           distances go through the batched Pallas kernels (MXU-tiled
           on TPU; elsewhere a tiled loop that skips the candidate
           padding tail and early-exits core counts at MinPts -- see
           ``repro.kernels.ops``).
distributed spatial slab sharding + halo exchange + global label
           reconciliation over a jax mesh (shard_map), with the same
           adaptive cap loop wrapped around the whole SPMD program.
           The shard-local pipeline honors ``use_kernels`` (threaded
           through ``ClusterCaps.grit``; defaults to the Pallas kernel
           plane on TPU meshes) and reports per-point core flags plus
           slab/grid provenance -- the inputs of the sharded serving
           index (``repro.index.ShardedGritIndex``).
========== =============================================================

All engines take host numpy points and return
:class:`~repro.engine.result.ClusterResult` with labels in original
point order.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.dbscan import brute_dbscan, grit_dbscan
from repro.core.validate import core_flags

from .adaptive import (adaptive_device_dbscan, adaptive_loop,
                       estimate_caps, estimate_shard_caps, grow_caps,
                       _pow2_at_least)
from .registry import register_engine
from .result import ClusterResult


@register_engine("brute", "O(n^2) host oracle (reference labels)")
def _brute_engine(points, eps, min_pts, *, chunk: int = 2048,
                  with_core: bool = True) -> ClusterResult:
    t0 = time.perf_counter()
    labels = brute_dbscan(points, eps, min_pts, chunk=chunk)
    core = core_flags(points, eps, min_pts, chunk=chunk) if with_core \
        else None
    return ClusterResult.build(
        labels, "brute", core=core,
        stats={"n": len(points), "t_total": time.perf_counter() - t0})


def _host_grit(points, eps, min_pts, variant: str, name: str,
               **opts) -> ClusterResult:
    r = grit_dbscan(points, eps, min_pts, variant=variant, **opts)
    return ClusterResult.build(r.labels, name, core=r.core, grid=r.grid,
                               stats=r.stats)


@register_engine("grit", "host GriT-DBSCAN (paper Algorithm 6)")
def _grit_engine(points, eps, min_pts, *, neighbor_engine: str = "tree",
                 merge_engine: str = "fast", rng=None) -> ClusterResult:
    return _host_grit(points, eps, min_pts, "grit", "grit",
                      neighbor_engine=neighbor_engine,
                      merge_engine=merge_engine, rng=rng)


@register_engine("grit-ldf",
                 "host GriT-DBSCAN-LDF (union-find, low-density first)")
def _grit_ldf_engine(points, eps, min_pts, *, neighbor_engine: str = "tree",
                     merge_engine: str = "fast", rng=None) -> ClusterResult:
    return _host_grit(points, eps, min_pts, "ldf", "grit-ldf",
                      neighbor_engine=neighbor_engine,
                      merge_engine=merge_engine, rng=rng)


def _pad_bucket(n: int, quantum: int = 128) -> int:
    """Pad n up to a coarse bucket so similarly-sized datasets hit the
    same jitted program instead of recompiling per exact n."""
    return max(quantum, (n + quantum - 1) // quantum * quantum)


# build_grids_device computes interval indices as floor((x - min)/side)
# in f32 and clamps them into [0, PAD_ID] before the int32 cast.  Both
# steps lose correctness silently once span/side gets large: beyond
# ~2^22 the f32 quotient's ulp approaches a whole grid cell, so a
# point's identifier can land cells away from its true cell and miss
# its eps-neighbors' stencils, and near 2^30 a top-edge valid point can
# round up onto the PAD_ID sentinel itself.  The in-graph pipeline
# cannot raise under jit, so the device-backed engines reject such
# inputs host-side here.  Host engines are unaffected (float64/int64
# identifiers).
def _check_device_grid_range(pts: np.ndarray, eps: float,
                             limit: int = 2 ** 22) -> None:
    d = pts.shape[1]
    side = float(eps) / np.sqrt(d)
    span = float((pts.max(axis=0) - pts.min(axis=0)).max())
    if span / side >= limit:
        raise ValueError(
            f"eps={eps} is too small for the coordinate span {span:.3g}: "
            f"span/side = {span / side:.3g} >= 2^22 exceeds the f32 "
            f"device-grid identifier range (grid assignment would "
            f"quantize by whole cells); rescale the data, increase eps, "
            f"or use a host engine (grit/grit-ldf)")


def _device_impl(points, eps, min_pts, name: str, *, caps=None,
                 use_kernels=None, max_retries: int = 8,
                 growth: float = 2.0,
                 pad_quantum: int = 128) -> ClusterResult:
    """Single-program XLA pipeline with the adaptive-cap driver.

    Points are padded to a coarse size bucket (``pad_quantum``) with
    masked-out sentinel points, so the jit cache is shared across
    datasets of similar size.
    """
    import jax.numpy as jnp

    t0 = time.perf_counter()
    pts = np.asarray(points, np.float32)
    n, d = pts.shape
    _check_device_grid_range(pts, eps)
    n_pad = _pad_bucket(n, pad_quantum)
    padded = np.zeros((n_pad, d), np.float32)
    padded[:n] = pts
    valid = np.arange(n_pad) < n

    res, attempts = adaptive_device_dbscan(
        jnp.asarray(padded), eps, min_pts, caps,
        point_valid=jnp.asarray(valid), max_retries=max_retries,
        growth=growth, use_kernels=use_kernels)
    labels = np.asarray(res.labels)[:n].astype(np.int64)
    core = np.asarray(res.core)[:n]
    return ClusterResult.build(
        labels, name, core=core, attempts=attempts,
        overflow=attempts[-1]["overflow"],
        stats={"n": n, "n_padded": n_pad, "retries": len(attempts) - 1,
               "t_total": time.perf_counter() - t0})


@register_engine("device",
                 "in-graph jitted pipeline, adaptive static caps, "
                 "naive-broadcast distance plane")
def _device_engine(points, eps, min_pts, **opts) -> ClusterResult:
    opts.setdefault("use_kernels", False)
    return _device_impl(points, eps, min_pts, "device", **opts)


@register_engine("device-kernels",
                 "device pipeline with the batched Pallas distance "
                 "kernels (MXU on TPU, tiled early-exit loop elsewhere)")
def _device_kernels_engine(points, eps, min_pts, **opts) -> ClusterResult:
    opts.setdefault("use_kernels", True)
    return _device_impl(points, eps, min_pts, "device-kernels", **opts)


@register_engine("distributed",
                 "slab-sharded shard_map pipeline (halo exchange + "
                 "global label reconciliation), adaptive caps")
def _distributed_engine(points, eps, min_pts, *, mesh=None, caps=None,
                        use_kernels: Optional[bool] = None,
                        max_retries: int = 8,
                        growth: float = 2.0) -> ClusterResult:
    """Multi-device SPMD engine.

    ``mesh`` defaults to a 1-D mesh over every visible jax device.  Caps
    are estimated from *per-shard* grid statistics
    (:func:`repro.engine.estimate_shard_caps`): slab cuts land on grid
    lines, so the worst shard's own + ghost-band point set bounds every
    shard-local table without inflating each shard to the global one;
    the halo cap comes from the boundary-band census
    (``repro.dist.halo.census_halo_cap``) instead of the densest-window
    upper bound that historically left halo buffers ~76% padding.

    ``use_kernels`` selects the shard-local distance plane (it rides on
    ``ClusterCaps.grit`` -- the same static jit key as the caps): None
    defaults to the Pallas kernel plane on TPU meshes (where the MXU
    kernels are the point -- the choice ``engine="auto"`` inherits) and
    the naive broadcast plane elsewhere; an explicit flag always wins,
    including over the plane carried by a caller-provided ``caps``.
    """
    import jax
    from repro.dist import ClusterCaps, census_halo_cap, distributed_fit

    t0 = time.perf_counter()
    pts = np.asarray(points, np.float64)
    n, d = pts.shape
    _check_device_grid_range(pts, eps)
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), ("shard",))
    if caps is None:
        uk = (jax.default_backend() == "tpu") if use_kernels is None \
            else bool(use_kernels)
        n_shards = int(mesh.devices.size)
        grit = estimate_shard_caps(pts, eps, min_pts, n_shards,
                                   use_kernels=uk)
        halo = min(census_halo_cap(pts, eps, n_shards), _pow2_at_least(n))
        caps = ClusterCaps(grit=grit, halo_cap=halo)
    elif use_kernels is not None and \
            caps.grit.use_kernels != bool(use_kernels):
        caps = dataclasses.replace(
            caps, grit=dataclasses.replace(caps.grit,
                                           use_kernels=bool(use_kernels)))

    def run(c):
        fit = distributed_fit(pts, eps, min_pts, mesh, caps=c)
        return fit, fit.report

    def grow(c, overflowed):
        # halo is measured from the raw points, so its flag stays
        # trustworthy even while the grid table is truncated
        grit = c.grit
        grit_flags = tuple(f for f in overflowed if f != "halo")
        if grit_flags:
            grit = grow_caps(grit, grit_flags, n=n, d=d, growth=growth)
        halo = c.halo_cap
        if "halo" in overflowed:
            halo = _pow2_at_least(min(int(halo * growth), n))
        return ClusterCaps(grit=grit, halo_cap=halo)

    fit, attempts = adaptive_loop(
        run, grow,
        lambda c: {**dataclasses.asdict(c.grit), "halo_cap": c.halo_cap},
        caps, max_retries)
    return ClusterResult.build(
        fit.labels, "distributed", core=fit.core, attempts=attempts,
        overflow=attempts[-1]["overflow"],
        stats={"n": n, "n_shards": mesh.devices.size,
               "retries": len(attempts) - 1,
               "use_kernels": caps.grit.use_kernels,
               "t_total": time.perf_counter() - t0})
