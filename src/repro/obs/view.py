"""Text summarizer for exported traces: ``python -m repro.obs.view
<trace.json> [--root NAME]``.

Prints the attribution table ROADMAP item 2 asks for: per-span-name
totals (count / total / mean / self time), each name's share of the
chosen root span's wall-clock, the coverage of the root by its direct
children (how much of the wall is *attributed* rather than guessed),
the top jit-compile counters, and the padding-waste /
bucket-occupancy metrics.  Reads both export formats (Chrome trace
JSON and JSONL).
"""

from __future__ import annotations

import argparse
from typing import Any, Dict, List, Optional, Tuple

from .export import load_trace

__all__ = ["span_aggregates", "attribution", "render", "main"]


def _nest(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Annotate complete events with ``self`` time and ``parent`` name
    by interval containment (per pid/tid lane), the standard Chrome
    trace reconstruction: sort by (ts, -dur), pop the stack while the
    event does not fit inside the top."""
    out = []
    lanes: Dict[Tuple[Any, Any], List[Dict[str, Any]]] = {}
    for e in sorted(events, key=lambda e: (e.get("pid", 0),
                                           e.get("tid", 0),
                                           e["ts"], -e.get("dur", 0.0))):
        lane = lanes.setdefault((e.get("pid", 0), e.get("tid", 0)), [])
        ev = dict(e)
        ev["self"] = ev.get("dur", 0.0)
        ev["parent"] = None
        end = ev["ts"] + ev.get("dur", 0.0)
        eps = 1e-9
        while lane and end > lane[-1]["ts"] + lane[-1]["dur"] + eps:
            lane.pop()
        if lane:
            lane[-1]["self"] -= ev.get("dur", 0.0)
            ev["parent"] = lane[-1]["name"]
        lane.append(ev)
        out.append(ev)
    return out


def span_aggregates(events: List[Dict[str, Any]]
                    ) -> Dict[str, Dict[str, float]]:
    """name -> {count, total_us, mean_us, self_us}."""
    agg: Dict[str, Dict[str, float]] = {}
    for e in _nest(events):
        a = agg.setdefault(e["name"], dict(count=0, total_us=0.0,
                                           self_us=0.0))
        a["count"] += 1
        a["total_us"] += e.get("dur", 0.0)
        a["self_us"] += max(e["self"], 0.0)
    for a in agg.values():
        a["mean_us"] = a["total_us"] / a["count"] if a["count"] else 0.0
    return agg


def attribution(events: List[Dict[str, Any]],
                root: Optional[str] = None) -> Dict[str, Any]:
    """Attribute the root span's wall-clock to its direct children.

    ``root`` defaults to the name of the single longest event.
    Returns ``{root, wall_us, children: {name: us}, accounted_us,
    coverage}`` -- ``coverage`` is the fraction of the root's wall
    spent inside named child spans (the >= 0.9 acceptance bar of the
    traced distributed fit).
    """
    nested = _nest(events)
    if not nested:
        return {"root": root, "wall_us": 0.0, "children": {},
                "accounted_us": 0.0, "coverage": 0.0}
    if root is None:
        root = max(nested, key=lambda e: e.get("dur", 0.0))["name"]
    roots = [e for e in nested if e["name"] == root]
    wall = sum(e.get("dur", 0.0) for e in roots)
    children: Dict[str, float] = {}
    for e in nested:
        if e["parent"] == root:
            children[e["name"]] = children.get(e["name"], 0.0) \
                + e.get("dur", 0.0)
    accounted = sum(children.values())
    return {"root": root, "wall_us": wall, "children": children,
            "accounted_us": accounted,
            "coverage": accounted / wall if wall else 0.0}


def _fmt_ms(us: float) -> str:
    return f"{us / 1e3:10.2f}"


def render(events: List[Dict[str, Any]], metrics: Dict[str, Any],
           meta: Dict[str, Any], root: Optional[str] = None) -> str:
    lines: List[str] = []
    if meta:
        lines.append("meta: " + ", ".join(
            f"{k}={meta[k]}" for k in ("git_rev", "jax", "backend",
                                       "device_count", "timestamp")
            if k in meta))
    agg = span_aggregates(events)
    if agg:
        lines.append("")
        lines.append(f"{'span':<28}{'count':>7}{'total ms':>11}"
                     f"{'mean ms':>11}{'self ms':>11}")
        for name, a in sorted(agg.items(),
                              key=lambda kv: -kv[1]["total_us"]):
            lines.append(
                f"{name:<28}{a['count']:>7.0f}"
                f"{_fmt_ms(a['total_us'])} {_fmt_ms(a['mean_us'])}"
                f"{_fmt_ms(a['self_us'])}")
        att = attribution(events, root=root)
        if att["wall_us"]:
            lines.append("")
            lines.append(
                f"attribution of {att['root']!r} "
                f"({att['wall_us'] / 1e3:.2f} ms wall):")
            for name, us in sorted(att["children"].items(),
                                   key=lambda kv: -kv[1]):
                lines.append(f"  {name:<26}{_fmt_ms(us)} ms  "
                             f"{100 * us / att['wall_us']:5.1f}%")
            lines.append(f"  accounted: {att['coverage']:.1%} of wall")
    else:
        lines.append("(no span events)")

    compiles = {k: v for k, v in metrics.items()
                if k.startswith("jax.events.") and "compile" in k}
    if compiles:
        lines.append("")
        lines.append("top recompile counters:")
        for k, v in sorted(compiles.items(),
                           key=lambda kv: -kv[1])[:8]:
            lines.append(f"  {k:<44}{v:>8}")
    waste = {k: v for k, v in metrics.items()
             if "padding_waste" in k or "bucket_elems" in k
             or k.endswith(".elems")}
    if waste:
        lines.append("")
        lines.append("padding / occupancy:")
        for k in sorted(waste):
            v = waste[k]
            val = v["value"] if isinstance(v, dict) and "value" in v \
                else v
            lines.append(f"  {k:<44}{val:>12}")
    others = {k: v for k, v in metrics.items()
              if k not in compiles and k not in waste
              and isinstance(v, int)}
    if others:
        lines.append("")
        lines.append("counters:")
        for k in sorted(others):
            lines.append(f"  {k:<44}{others[k]:>8}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize a repro.obs trace export")
    ap.add_argument("trace", help="Chrome trace JSON or JSONL export")
    ap.add_argument("--root", default=None,
                    help="span name to attribute (default: longest)")
    args = ap.parse_args(argv)
    events, metrics, meta = load_trace(args.trace)
    print(render(events, metrics, meta, root=args.root))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
