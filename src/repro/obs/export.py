"""Trace exporters: Chrome trace-event JSON (Perfetto-loadable) and a
JSONL event stream, plus the loader the viewer shares.

The Chrome format is the profiling lingua franca: ``{"traceEvents":
[...complete events...]}`` with microsecond ``ts``/``dur`` opens
directly in ``ui.perfetto.dev`` / ``chrome://tracing``.  The repo's
metrics snapshot and provenance (``bench_meta``) ride along under
``otherData`` -- ignored by the UIs, read by ``repro.obs.view``.

JSONL is the stream form: one JSON object per line, span events
as-recorded, with a trailing ``{"kind": "metrics"}`` line carrying the
registry snapshot -- greppable and append-friendly for long serving
runs.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["chrome_trace", "write_chrome_trace", "write_jsonl",
           "load_trace"]


def chrome_trace(events: List[Dict[str, Any]],
                 metrics: Optional[Dict[str, Any]] = None,
                 meta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Render recorded span events as a Chrome trace-event document."""
    te = []
    for e in events:
        ev = {
            "name": e["name"],
            "cat": e.get("cat", "repro"),
            "ph": e.get("ph", "X"),
            "ts": e["ts"],
            "dur": e.get("dur", 0.0),
            "pid": e.get("pid", 0),
            "tid": e.get("tid", 0),
        }
        if e.get("args"):
            ev["args"] = e["args"]
        te.append(ev)
    doc: Dict[str, Any] = {
        "traceEvents": te,
        "displayTimeUnit": "ms",
    }
    other: Dict[str, Any] = {}
    if meta:
        other["meta"] = meta
    if metrics:
        other["metrics"] = metrics
    if other:
        doc["otherData"] = other
    return doc


def write_chrome_trace(path: str, events: List[Dict[str, Any]],
                       metrics: Optional[Dict[str, Any]] = None,
                       meta: Optional[Dict[str, Any]] = None) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events, metrics=metrics, meta=meta), f,
                  indent=1)
        f.write("\n")


def write_jsonl(path: str, events: List[Dict[str, Any]],
                metrics: Optional[Dict[str, Any]] = None,
                meta: Optional[Dict[str, Any]] = None) -> None:
    with open(path, "w") as f:
        if meta:
            f.write(json.dumps({"kind": "meta", **meta}) + "\n")
        for e in events:
            f.write(json.dumps({"kind": "span", **e}) + "\n")
        if metrics:
            f.write(json.dumps({"kind": "metrics",
                                "metrics": metrics}) + "\n")


def load_trace(path: str) -> Tuple[List[Dict[str, Any]],
                                   Dict[str, Any], Dict[str, Any]]:
    """Load either export format -> (span events, metrics, meta).

    Chrome documents are detected by their ``traceEvents`` key; JSONL
    by one JSON object per line with a ``kind`` tag.
    """
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None
    if isinstance(doc, dict) and "traceEvents" in doc:
        other = doc.get("otherData", {})
        events = [e for e in doc["traceEvents"]
                  if e.get("ph", "X") == "X"]
        return events, other.get("metrics", {}), other.get("meta", {})
    events, metrics, meta = [], {}, {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        kind = rec.pop("kind", "span")
        if kind == "span":
            events.append(rec)
        elif kind == "metrics":
            metrics = rec.get("metrics", rec)
        elif kind == "meta":
            meta = rec
    return events, metrics, meta
