"""``repro.obs``: the tracing + metrics plane.

One instrumentation layer for every subsystem that used to log in its
own dict schema -- spans (``repro.obs.trace``), a process-wide metric
registry (``repro.obs.metrics``), Chrome-trace / JSONL exporters
(``repro.obs.export``) and a text summarizer
(``python -m repro.obs.view``).  DESIGN.md §9 has the span taxonomy
and the overhead policy; the short version:

* tracing **off** (default): ``obs.span(...)`` returns a shared no-op
  -- zero events, zero host syncs, the serving hot path is untouched;
* tracing **on** (``REPRO_OBS=1`` or :func:`enable`): spans sync at
  close only, counters/histograms always record (they are host-side
  integer adds and never sync).

Environment switches (read once at import):

* ``REPRO_OBS=1`` -- enable tracing and the ``jax.monitoring`` bridge.
* ``REPRO_OBS_TRACE=<path>`` -- at process exit, export the Chrome
  trace (with the metrics snapshot and ``bench_meta`` provenance)
  there; implies ``REPRO_OBS=1``.
"""

from __future__ import annotations

import atexit
import os
from typing import Optional

from . import export
from .meta import bench_meta, git_rev
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      counter, gauge, histogram, install_jax_hooks,
                      jax_hooks_installed, recompile_counts, registry)
from .trace import (NOOP_SPAN, Span, Tracer, disable, enable, enabled,
                    get_tracer, span)

__all__ = [
    "span", "enabled", "enable", "disable", "get_tracer", "Tracer",
    "Span", "NOOP_SPAN",
    "MetricsRegistry", "registry", "counter", "gauge", "histogram",
    "Counter", "Gauge", "Histogram",
    "install_jax_hooks", "jax_hooks_installed", "recompile_counts",
    "bench_meta", "git_rev", "export",
    "note_flat_dispatch", "export_chrome",
]


def note_flat_dispatch(stage: str, t_valid: int, bucket: int) -> None:
    """Record one flat ragged kernel dispatch (``pairwise_d2_flat`` /
    ``_flat_res``): dispatch count, valid elements, and the pow2 bucket
    elements actually shipped -- ``elems / bucket_elems`` is the bucket
    occupancy (1 - padding waste).  Host-side counter adds only: safe
    on the serving hot path."""
    r = registry()
    r.counter(f"kernels.flat.{stage}.dispatches").inc()
    r.counter(f"kernels.flat.{stage}.elems").inc(t_valid)
    r.counter(f"kernels.flat.{stage}.bucket_elems").inc(bucket)


def export_chrome(path: str, reg: Optional[MetricsRegistry] = None,
                  meta: bool = True) -> bool:
    """Export the live tracer's events as a Chrome trace at ``path``
    (with the registry snapshot + provenance).  Returns False when
    tracing was never enabled (nothing to export)."""
    t = get_tracer()
    if t is None:
        return False
    export.write_chrome_trace(
        path, t.snapshot_events(),
        metrics=(reg or registry()).snapshot(),
        meta=bench_meta() if meta else None)
    return True


def _env_truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() not in (
        "", "0", "false", "no", "off")


_TRACE_OUT = os.environ.get("REPRO_OBS_TRACE", "").strip()
if _env_truthy("REPRO_OBS") or _TRACE_OUT:
    enable()
    install_jax_hooks()
    if _TRACE_OUT:
        atexit.register(export_chrome, _TRACE_OUT)
