"""Nestable span tracer with device-sync-aware timing.

Overhead contract (DESIGN.md §9):

* **Tracing off** (the default): :func:`span` returns one shared
  module-level no-op object -- no event record, no attribute dict
  walk, and crucially *no host sync*, so the serving hot path is
  untouched and the ``hot-path-sync`` lint rule stays green by
  construction.
* **Tracing on**: a span syncs *only at its close*, and only when the
  caller registered device values to block on (``Span.sync(...)`` or
  the ``sync=`` kwarg) -- one intended block point per stage, which is
  exactly the discipline the serving plane already follows.  Those
  close-time syncs are the only host syncs the tracer ever performs
  and each carries a justified ``grit-lint`` pragma.

Spans nest lexically (context managers); the tracer keeps a per-thread
stack so the exporter can emit parent-ordered Chrome trace events and
the viewer can compute self-times.  Timestamps are
``time.perf_counter`` microseconds relative to the tracer's start --
monotonic, which is what Perfetto wants.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "Span", "NOOP_SPAN", "span", "enabled", "enable",
           "disable", "get_tracer"]


class _NoopSpan:
    """The disabled-tracer span: one shared instance, every method a
    no-op returning fast.  Reentrant (``__enter__`` just returns self),
    so one module-level object serves arbitrarily nested ``with``
    blocks with zero allocations."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self

    def sync(self, *values: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One live span.  Use as a context manager; at ``__exit__`` it
    optionally blocks on the registered device values (so the recorded
    duration covers the device work the stage dispatched, not just the
    Python that enqueued it) and records one complete event."""

    __slots__ = ("_tracer", "name", "attrs", "_sync", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]],
                 sync: Optional[Any] = None):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._sync = [sync] if sync is not None else []
        self._t0 = 0.0

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes mid-span (rendered as Chrome trace args)."""
        if self.attrs is None:
            self.attrs = {}
        self.attrs.update(attrs)
        return self

    def sync(self, *values: Any) -> "Span":
        """Register device values to block on at span close."""
        self._sync.extend(values)
        return self

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._sync and exc_type is None:
            import jax
            # the tracer's single intended block point: enabled-mode
            # spans time device work by blocking at stage close --
            # that sync is the feature, and it never runs when
            # tracing is off (span() returns NOOP_SPAN then)
            jax.block_until_ready(self._sync)  # grit-lint: disable=hot-path-sync -- enabled-mode span close is the stage's intended block point; tracing-off serving never reaches this line
        t1 = time.perf_counter()
        self._tracer._pop(self, self._t0, t1, error=exc_type is not None)


class Tracer:
    """Records complete-span events (thread-safe append)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self.t0 = time.perf_counter()
        self.events: List[Dict[str, Any]] = []

    # -- span plumbing -----------------------------------------------------

    def _stack(self) -> List["Span"]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span, t0: float, t1: float,
             error: bool = False) -> None:
        stack = self._stack()
        depth = len(stack) - 1
        if stack and stack[-1] is span:
            stack.pop()
        ev: Dict[str, Any] = {
            "name": span.name,
            "ph": "X",
            "ts": (t0 - self.t0) * 1e6,          # us, perf_counter base
            "dur": (t1 - t0) * 1e6,
            "pid": 0,
            "tid": threading.get_ident() % 100_000,
            "depth": depth,
        }
        if span.attrs:
            ev["args"] = span.attrs
        if error:
            ev.setdefault("args", {})["error"] = True
        with self._lock:
            self.events.append(ev)

    # -- public ------------------------------------------------------------

    def span(self, name: str, sync: Optional[Any] = None,
             **attrs: Any) -> Span:
        return Span(self, name, attrs or None, sync=sync)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
        self.t0 = time.perf_counter()

    def snapshot_events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self.events]


# --------------------------------------------------------------------------
# module-level switch
# --------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enable(clear: bool = False) -> Tracer:
    """Turn tracing on (idempotent); returns the live tracer."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer()
    elif clear:
        _TRACER.clear()
    return _TRACER


def disable() -> Optional[Tracer]:
    """Turn tracing off; returns the (frozen) tracer for export."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def span(name: str, sync: Optional[Any] = None, **attrs: Any):
    """A span under the process tracer -- or the shared no-op when
    tracing is off (the hot-path fast exit: one global read)."""
    t = _TRACER
    if t is None:
        return NOOP_SPAN
    return Span(t, name, attrs or None, sync=sync)
