"""Run provenance: one dict stamped into every benchmark artifact.

``BENCH_*.json`` files are the repo's perf trajectory, but a number
without its machine is unauditable -- a 1.07x device win on a 1-core
CPU runner and the same ratio on a TPU runner are different facts.
:func:`bench_meta` captures the invariants that make a benchmark row
comparable: jax/jaxlib versions, backend + device kind/count, host
platform, an ISO-8601 UTC timestamp, and the git revision.
"""

from __future__ import annotations

import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Any, Dict

__all__ = ["bench_meta", "git_rev"]


def git_rev() -> str:
    """Short git revision of the working tree ("unknown" outside a
    checkout), with a ``-dirty`` suffix for uncommitted changes."""
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"],
            capture_output=True, text=True, timeout=5,
            check=True).stdout.strip()
        return rev + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def bench_meta() -> Dict[str, Any]:
    """Provenance block for benchmark emitters (JSON-able)."""
    meta: Dict[str, Any] = {
        "timestamp": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "git_rev": git_rev(),
    }
    try:
        import jax
        import jaxlib
        devs = jax.devices()
        meta.update({
            "jax": jax.__version__,
            "jaxlib": jaxlib.__version__,
            "backend": jax.default_backend(),
            "device_kind": devs[0].device_kind if devs else "none",
            "device_count": jax.device_count(),
        })
    except Exception as e:          # benches may pre-configure XLA flags
        meta["jax"] = f"unavailable ({type(e).__name__})"
    return meta
