"""Process-wide counter / gauge / histogram registry.

Every subsystem used to log in its own ad-hoc dict schema (serve step
log, delta-engine stats, adaptive-cap attempt dicts, benchmark rows);
this registry is the one place those numbers accumulate so the
exporters (``repro.obs.export``) and the viewer (``repro.obs.view``)
can read them uniformly.  Instruments are cheap host-side objects --
an ``inc`` is a lock-protected integer add, never a device sync -- so
they are always on (unlike spans, which cost a sync at close and are
gated by ``repro.obs.enabled()``).

The registry is *instantiable*: the process-wide default
(:func:`registry`) collects cross-cutting counters (jit recompiles,
kernel dispatches, halo census, transfer counts), while components
that need isolated books -- one :class:`~repro.serve.driver.ClusterServer`
per registry, say -- hold their own instance.

``install_jax_hooks()`` bridges ``jax.monitoring`` into the default
registry: every monitoring event becomes a counter
(``jax.events.<name>``) and every duration event a histogram
(``jax.dur.<name>``) -- compile events included, which is how the
distributed-fit trace attributes recompiles (ROADMAP item 2).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "counter", "gauge", "histogram", "install_jax_hooks",
    "jax_hooks_installed",
]


class Counter:
    """Monotone counter.  ``inc`` is atomic under the instrument lock,
    so concurrent increments (the serve driver's double-buffered step
    packs batch k+1 while step k's kernels run) are never lost."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins scalar (plus a running max, for watermarks)."""

    __slots__ = ("name", "_value", "_max", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._max = float("-inf")
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
            self._max = max(self._max, float(v))

    @property
    def value(self) -> float:
        return self._value

    @property
    def max(self) -> float:
        return self._max


class Histogram:
    """Raw-sample histogram.

    Keeps every observation (bounded by ``cap``; beyond it the sample
    list freezes and only count/sum accumulate) so percentile queries
    are exact over the kept window -- the serve driver's latency
    summary must report the same p50/p95 it reported when it computed
    them from the request list directly.
    """

    __slots__ = ("name", "cap", "count", "total", "_values", "_lock")

    def __init__(self, name: str, cap: int = 1 << 16):
        self.name = name
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self._values: List[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if len(self._values) < self.cap:
                self._values.append(v)

    def values(self) -> List[float]:
        with self._lock:
            return list(self._values)

    def percentile(self, q: float) -> float:
        vals = sorted(self.values())
        if not vals:
            return 0.0
        if len(vals) == 1:
            return vals[0]
        # linear interpolation between closest ranks (numpy's default),
        # so registry percentiles match np.percentile on the same data
        pos = (q / 100.0) * (len(vals) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(vals) - 1)
        frac = pos - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create instrument registry (name -> instrument).

    A name is one kind of instrument forever: asking for a counter
    under an existing gauge name raises -- silent type drift is how
    dashboards rot.
    """

    def __init__(self) -> None:
        self._instruments: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(inst).__name__}, "
                    f"asked for {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 1 << 16) -> Histogram:
        return self._get(name, Histogram, cap=cap)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able view: counters -> int, gauges -> {value, max},
        histograms -> {count, sum, mean, p50, p95, p99, max}."""
        with self._lock:
            items = list(self._instruments.items())
        out: Dict[str, Any] = {}
        for name, inst in sorted(items):
            if isinstance(inst, Counter):
                out[name] = inst.value
            elif isinstance(inst, Gauge):
                out[name] = {"value": inst.value,
                             "max": inst.max if inst.max > float("-inf")
                             else inst.value}
            else:
                vals = inst.values()
                out[name] = {
                    "count": inst.count, "sum": inst.total,
                    "mean": inst.mean,
                    "p50": inst.percentile(50),
                    "p95": inst.percentile(95),
                    "p99": inst.percentile(99),
                    "max": max(vals) if vals else 0.0,
                }
        return out

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_DEFAULT = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _DEFAULT


def counter(name: str) -> Counter:
    return _DEFAULT.counter(name)


def gauge(name: str) -> Gauge:
    return _DEFAULT.gauge(name)


def histogram(name: str) -> Histogram:
    return _DEFAULT.histogram(name)


# --------------------------------------------------------------------------
# jax.monitoring bridge (jit recompile visibility)
# --------------------------------------------------------------------------

_JAX_HOOKS = {"installed": False}


def _event_key(event: str) -> str:
    return event.strip("/").replace("/", ".")


def install_jax_hooks() -> bool:
    """Route ``jax.monitoring`` events into the default registry.

    Each event increments ``jax.events.<name>`` and each duration
    event feeds ``jax.dur.<name>`` (seconds).  The jit-compile events
    (``jax.events.*compile*``) are the per-step recompile counters the
    distributed-fit attribution reads.  Installs once per process
    (jax.monitoring keeps listeners forever); returns whether the
    hooks are (now) installed.
    """
    if _JAX_HOOKS["installed"]:
        return True
    try:
        from jax import monitoring
    except Exception:          # jax not importable: metrics still work
        return False

    def _on_event(event: str, **kw: Any) -> None:
        _DEFAULT.counter(f"jax.events.{_event_key(event)}").inc()

    def _on_duration(event: str, duration: float, **kw: Any) -> None:
        _DEFAULT.counter(f"jax.events.{_event_key(event)}").inc()
        _DEFAULT.histogram(f"jax.dur.{_event_key(event)}").observe(
            duration)

    monitoring.register_event_listener(_on_event)
    monitoring.register_event_duration_secs_listener(_on_duration)
    _JAX_HOOKS["installed"] = True
    return True


def jax_hooks_installed() -> bool:
    return _JAX_HOOKS["installed"]


def recompile_counts(snapshot: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, int]:
    """The compile-event counters out of a snapshot (default: live)."""
    snap = snapshot if snapshot is not None else _DEFAULT.snapshot()
    return {k: v for k, v in snap.items()
            if k.startswith("jax.events.") and "compile" in k
            and isinstance(v, int)}
