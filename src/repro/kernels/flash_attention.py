"""Flash attention Pallas kernel (TPU target).

The LM zoo's compute hot spot.  Online-softmax blocked attention with
support for the attention variants the assigned architectures need:

* causal masking (decoder LMs),
* sliding-window masking (mixtral SWA, gemma2 local layers),
* tanh logit soft-capping (gemma2),

Grid is ``(batch*heads, q_blocks, k_blocks)`` with the k axis innermost;
running max / denominator / output accumulator live in VMEM scratch and
are carried across k steps (classic Pallas accumulation pattern).  Fully
masked (block-level) causal/window tiles are skipped with ``pl.when`` so
the sliding-window FLOPs actually drop, mirroring how the paper's grid
pruning skips whole regions of distance work.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

BLOCK_Q = 128
BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], q_offset: int, sk: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: in the (causal, windowed) band?
    q_lo = qi * block_q + q_offset           # first aligned key pos of block
    q_hi = q_lo + block_q - 1
    k_lo = kj * block_k
    k_hi = k_lo + block_k - 1
    live = True
    if causal:
        live = jnp.asarray(k_lo <= q_hi)
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = kpos < sk
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           sk_actual: Optional[int] = None,
                           q_offset: Optional[int] = None,
                           block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                           interpret: bool = False) -> jnp.ndarray:
    """q: [BH, Sq, D], k/v: [BH, Sk, D]; Sq % block_q == Sk % block_k == 0.

    ``sk_actual`` masks key padding when the true length is below Sk;
    ``q_offset`` is the key position aligned to query row 0 (defaults to
    right-alignment against the actual key length).
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    sk = sk_actual if sk_actual is not None else Sk
    if q_offset is None:
        q_offset = sk - Sq
    if scale is None:
        scale = D ** -0.5
    grid = (BH, Sq // block_q, Sk // block_k)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, sk=sk,
        block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
