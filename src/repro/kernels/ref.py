"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: kernel tests sweep shapes/dtypes and
``assert_allclose`` against these functions; the jit'd wrappers in
``ops.py`` fall back to them on platforms without Pallas support.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# pairwise distances (DBSCAN hot spots)
# --------------------------------------------------------------------------

def sq_dists(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """[M, d] x [N, d] -> [M, N] squared Euclidean distances."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    aa = jnp.sum(a * a, axis=1)[:, None]
    bb = jnp.sum(b * b, axis=1)[None, :]
    d2 = aa + bb - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def eps_count(a: jnp.ndarray, b: jnp.ndarray, eps: jnp.ndarray,
              valid_b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-row count of points of ``b`` within ``eps`` of each row of ``a``."""
    d2 = sq_dists(a, b)
    hit = d2 <= jnp.asarray(eps, jnp.float32) ** 2
    if valid_b is not None:
        hit = hit & valid_b[None, :]
    return hit.sum(axis=1).astype(jnp.int32)


def row_min(a: jnp.ndarray, b: jnp.ndarray,
            valid_b: Optional[jnp.ndarray] = None):
    """Per-row (min squared distance, argmin index) into ``b``.

    Contract for a fully-masked row (no valid b-point at all): the min
    distance is ``inf`` and the argmin is ``-1`` -- never an in-range
    index into masked/padded rows.  ``border_block`` relies on this
    whenever a grid has no core candidates.
    """
    d2 = sq_dists(a, b)
    if valid_b is not None:
        d2 = jnp.where(valid_b[None, :], d2, jnp.inf)
    mins = jnp.min(d2, axis=1)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    idx = jnp.where(jnp.isinf(mins), jnp.int32(-1), idx)
    return mins, idx


# --------------------------------------------------------------------------
# batched (leading grid-batch dimension) forms
# --------------------------------------------------------------------------

def sq_dists_batch(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """[B, M, d] x [B, N, d] -> [B, M, N] squared Euclidean distances.

    Same `aa + bb - 2ab` matmul form as the Pallas kernels (the MXU
    path), so kernel parity against this oracle is tight."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    aa = jnp.sum(a * a, axis=-1)[:, :, None]
    bb = jnp.sum(b * b, axis=-1)[:, None, :]
    ab = jnp.einsum("bmd,bnd->bmn", a, b,
                    preferred_element_type=jnp.float32)
    return jnp.maximum(aa + bb - 2.0 * ab, 0.0)


def eps_count_batch(a: jnp.ndarray, b: jnp.ndarray, eps: jnp.ndarray,
                    valid_b: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-batch per-row eps-counts: a [B, M, d], b [B, N, d], valid_b
    [B, N] -> [B, M] int32."""
    d2 = sq_dists_batch(a, b)
    hit = d2 <= jnp.asarray(eps, jnp.float32) ** 2
    if valid_b is not None:
        hit = hit & valid_b[:, None, :]
    return hit.sum(axis=-1).astype(jnp.int32)


def row_min_batch(a: jnp.ndarray, b: jnp.ndarray,
                  valid_b: Optional[jnp.ndarray] = None):
    """Batched :func:`row_min`: a [B, M, d], b [B, N, d], valid_b [B, N]
    -> ([B, M] f32 min d2, [B, M] int32 argmin; (inf, -1) for rows with
    no valid b-point)."""
    d2 = sq_dists_batch(a, b)
    if valid_b is not None:
        d2 = jnp.where(valid_b[:, None, :], d2, jnp.inf)
    mins = jnp.min(d2, axis=-1)
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    idx = jnp.where(jnp.isinf(mins), jnp.int32(-1), idx)
    return mins, idx


def eps_count_band_batch(a: jnp.ndarray, b: jnp.ndarray,
                         eps_lo: jnp.ndarray, eps_hi: jnp.ndarray,
                         valid_b: Optional[jnp.ndarray] = None):
    """Two-threshold batched eps-counts: hits at ``d2 <= eps_lo**2`` and
    at ``d2 <= eps_hi**2`` in one pass (a [B, M, d], b [B, N, d] ->
    two [B, M] int32 arrays).

    The guard-band discipline of the device serving path rests on
    ``count_lo <= exact_count <= count_hi`` whenever the float32 error
    of every decided distance is below the lo/hi band, which is how a
    core decision is proven without float64.
    """
    d2 = sq_dists_batch(a, b)
    lo2 = jnp.asarray(eps_lo, jnp.float32) ** 2
    hi2 = jnp.asarray(eps_hi, jnp.float32) ** 2
    hit_lo = d2 <= lo2
    hit_hi = d2 <= hi2
    if valid_b is not None:
        hit_lo = hit_lo & valid_b[:, None, :]
        hit_hi = hit_hi & valid_b[:, None, :]
    return (hit_lo.sum(axis=-1).astype(jnp.int32),
            hit_hi.sum(axis=-1).astype(jnp.int32))


def row_min2_batch(a: jnp.ndarray, b: jnp.ndarray,
                   valid_b: Optional[jnp.ndarray] = None):
    """Batched (min, runner-up min, argmin) squared distances.

    a [B, M, d], b [B, N, d], valid_b [B, N] -> ([B, M] f32 min,
    [B, M] f32 second-smallest, [B, M] int32 argmin).  The runner-up is
    over the remaining *slots* (duplicate distances count separately),
    so ``min2 - min`` bounds how far the argmin is from being tied --
    the device path's argmin-certainty test.  No valid candidate ->
    (inf, inf, -1); exactly one -> (d2, inf, idx).
    """
    d2 = sq_dists_batch(a, b)
    if valid_b is not None:
        d2 = jnp.where(valid_b[:, None, :], d2, jnp.inf)
    mins = jnp.min(d2, axis=-1)
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    cols = jnp.arange(d2.shape[-1], dtype=jnp.int32)
    d2_wo = jnp.where(cols[None, None, :] == idx[:, :, None], jnp.inf, d2)
    mins2 = jnp.min(d2_wo, axis=-1)
    idx = jnp.where(jnp.isinf(mins), jnp.int32(-1), idx)
    return mins, mins2, idx


def min_dist(a: jnp.ndarray, va: jnp.ndarray,
             b: jnp.ndarray, vb: jnp.ndarray) -> jnp.ndarray:
    """Minimum squared distance between two masked sets (scalar)."""
    d2 = sq_dists(a, b)
    d2 = jnp.where(va[:, None] & vb[None, :], d2, jnp.inf)
    return jnp.min(d2)


# --------------------------------------------------------------------------
# attention (LM hot spot)
# --------------------------------------------------------------------------

def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: Optional[int] = None,
        softcap: Optional[float] = None,
        scale: Optional[float] = None) -> jnp.ndarray:
    """Reference multi-head attention.

    q: [B, H, Sq, D], k/v: [B, H, Sk, D] (kv heads already broadcast).
    ``window``: sliding-window width (keys with q_pos - k_pos >= window
    masked out); ``softcap``: gemma2-style tanh logit soft capping.
    Query position i is aligned to key position i + (Sk - Sq) so decode
    (Sq=1) attends to the full prefix.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    if scale is None:
        scale = D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
