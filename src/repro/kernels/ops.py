"""jit'd public wrappers around the Pallas kernels.

Padding / masking policy
------------------------
The kernels require MXU-aligned shapes (rows % 128 == 0, feature dim ==
128 lanes).  The wrappers here pad:

* A-rows: zero-padded; callers receive `[M]` results sliced back.
* B-rows: padded with ``FAR`` coordinates so padded points can never
  satisfy a distance predicate (same convention as the device DBSCAN
  pipeline); an explicit ``valid_b`` mask folds into the same mechanism.
* feature dim: zero-padded to 128 (distances unchanged).

The batched wrappers (``eps_count_batch`` / ``row_min_batch``) apply the
identical policy per batch slot: a per-row ``valid_b`` [B, N] mask is
folded into FAR coordinates, row padding is batched, and a row whose
*every* b-point is masked/padded reports ``(inf, -1)`` -- the squared
distance to a FAR point exceeds ``FAR_D2`` (1e29), far above any real
distance, which is how "no valid candidate" is detected after the kernel
(the kernel itself never sees a mask).

Platform dispatch: on TPU the batched kernels compile natively
(MXU-tiled).  Elsewhere they run as a *tiled jnp loop* over b-tiles --
the same blocking as the kernels, expressed as ``lax.while_loop`` so the
trip count is data-dependent: the loop stops at the last tile holding a
valid candidate (static padding up to the candidate cap is never
scanned) and, for ``eps_count_batch(stop_at=k)``, as soon as every
valid a-row has accumulated ``k`` hits -- the paper's offset-ascending
early termination, which a one-shot broadcast cannot express.
``interpret=True`` forces the Pallas kernels under the interpreter
(slow; kernel parity tests only).  The unbatched wrappers keep their
historical behaviour of interpreting on non-TPU backends.  Set
``repro.kernels.ops.FORCE_REF = True`` to route everything through
``ref.py``.

``stop_at`` contract: with ``stop_at=k`` the returned counts satisfy
``min(count, k) == min(exact_count, k)`` (values below k are exact;
values >= k mean "at least k" and may undercount the exact total).
Thresholding at ``>= k`` -- the only thing core identification does --
is therefore exact.  The TPU kernels simply return full counts, which
satisfies the contract trivially.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs

from . import ref
from .pairwise import (eps_count_pallas, row_min_pallas,
                       eps_count_batch_pallas, row_min_batch_pallas,
                       eps_count_band_batch_pallas, row_min2_batch_pallas,
                       LANE)
from .flash_attention import flash_attention_pallas

FAR = 1e15
# any squared distance >= FAR_D2 can only involve a FAR-padded/masked
# point (real coordinates are orders of magnitude below FAR), so it
# marks "no valid candidate" after a row_min kernel
FAR_D2 = 1e29
FORCE_REF = False
# REPRO_FORCE_INTERPRET=1 routes the batched wrappers through the
# *Pallas kernels under the interpreter* on non-TPU backends (instead
# of the tiled jnp fast path) -- how a CPU-only CI runner exercises the
# exact kernel code the device serving path compiles on TPU.  Read at
# import; per-call ``interpret=`` arguments still take precedence.
FORCE_INTERPRET = os.environ.get("REPRO_FORCE_INTERPRET", "") not in ("", "0")


def interpret_default(interpret: Optional[bool]) -> Optional[bool]:
    """Resolve a caller's ``interpret=None`` against the
    ``REPRO_FORCE_INTERPRET`` knob (module docstring)."""
    if interpret is None and FORCE_INTERPRET:
        return True
    return interpret


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, mult: int, fill: float,
              axis: int = 0) -> jnp.ndarray:
    """Pad ``axis`` up to a multiple of ``mult`` with ``fill``."""
    m = x.shape[axis]
    tgt = ((m + mult - 1) // mult) * mult
    if tgt == m:
        return x
    shape = list(x.shape)
    shape[axis] = tgt - m
    return jnp.concatenate([x, jnp.full(shape, fill, x.dtype)], axis=axis)


def _pad_feat(x: jnp.ndarray, lane: int = LANE) -> jnp.ndarray:
    """Zero-pad the (last) feature axis to the lane width."""
    d = x.shape[-1]
    if d == lane:
        return x
    if d > lane:
        raise ValueError(f"feature dim {d} > lane width {lane}")
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, lane - d)])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def eps_count(a: jnp.ndarray, b: jnp.ndarray, eps,
              valid_b: Optional[jnp.ndarray] = None,
              *, block_m: int = 128, block_n: int = 128) -> jnp.ndarray:
    """Count of b-points within ``eps`` of each a-point. Returns [M] int32."""
    if FORCE_REF:
        return ref.eps_count(a, b, eps, valid_b)
    M = a.shape[0]
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, None], b32, FAR)
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR))
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    out = eps_count_pallas(ap, bp, eps2, block_m=block_m, block_n=block_n,
                           interpret=_interpret())
    return out[:M, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def row_min(a: jnp.ndarray, b: jnp.ndarray,
            valid_b: Optional[jnp.ndarray] = None,
            *, block_m: int = 128, block_n: int = 128):
    """Per-row (min squared distance, argmin) into b. Returns ([M], [M]).

    A row with no valid b-point at all (every candidate masked by
    ``valid_b``) reports ``(inf, -1)``, never an in-range index into a
    masked row -- the distance to a FAR-folded point exceeds ``FAR_D2``,
    which is the post-kernel detection threshold."""
    if FORCE_REF:
        return ref.row_min(a, b, valid_b)
    M = a.shape[0]
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, None], b32, FAR)
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR))
    mins, args = row_min_pallas(ap, bp, block_m=block_m, block_n=block_n,
                                interpret=_interpret())
    mins, args = mins[:M, 0], args[:M, 0]
    none = mins >= FAR_D2
    return (jnp.where(none, jnp.inf, mins),
            jnp.where(none, jnp.int32(-1), args))


# --------------------------------------------------------------------------
# batched (leading grid-batch dimension) wrappers
# --------------------------------------------------------------------------

def _use_batch_pallas(interpret) -> bool:
    """Dispatch policy for the batched wrappers (module docstring):
    native Pallas on TPU, the tiled jnp loop elsewhere, unless the
    caller forces the interpreter (parity tests) or native
    compilation."""
    if FORCE_REF:
        return False
    if interpret is None:
        return jax.default_backend() == "tpu"
    return True


def _tile_prep(b32, valid_b, block_n):
    """Pad the candidate axis to a tile multiple and return (b tiles,
    valid tiles, index of the last tile holding any valid candidate)."""
    B, N = b32.shape[0], b32.shape[1]
    if valid_b is None:
        valid_b = jnp.ones((B, N), bool)
    bp = _pad_rows(b32, block_n, FAR, axis=1)
    vp = jnp.concatenate(
        [valid_b, jnp.zeros((B, bp.shape[1] - N), bool)], axis=1) \
        if bp.shape[1] != N else valid_b
    # 1 + the highest valid slot, in tiles: the loop never scans the
    # all-padding tail that static caps force onto the candidate axis
    last = jnp.max(jnp.where(vp, jnp.arange(vp.shape[1])[None, :] + 1, 0))
    n_tiles = (last + block_n - 1) // block_n
    return bp, vp, n_tiles


def _eps_count_tiled(a32, b32, eps2, valid_a, valid_b, stop_at, block_n):
    """Non-TPU fast path: b-tile loop with data-dependent trip count
    (see module docstring).  Each tile is the fused broadcast form --
    the optimal XLA-CPU shape -- so the win over the one-shot broadcast
    is pure work skipped, not a different contraction."""
    B, M, _ = a32.shape
    bp, vp, n_tiles = _tile_prep(b32, valid_b, block_n)
    if valid_a is None:
        valid_a = jnp.ones((B, M), bool)

    def cond(state):
        t, cnt = state
        live = t < n_tiles
        if stop_at is not None:
            live = live & jnp.any((cnt < stop_at) & valid_a)
        return live

    def body(state):
        t, cnt = state
        bt = jax.lax.dynamic_slice_in_dim(bp, t * block_n, block_n, axis=1)
        vt = jax.lax.dynamic_slice_in_dim(vp, t * block_n, block_n, axis=1)
        d2 = jnp.sum((a32[:, :, None, :] - bt[:, None, :, :]) ** 2, axis=-1)
        hit = (d2 <= eps2) & vt[:, None, :]
        return t + 1, cnt + hit.sum(axis=2, dtype=jnp.int32)

    _, cnt = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.zeros((B, M), jnp.int32)))
    return cnt


def _row_min_tiled(a32, b32, valid_b, block_n):
    """Non-TPU fast path for the nearest query: same b-tile loop; no
    stop condition (the minimum needs every valid candidate) but the
    padding tail is still skipped."""
    B, M, _ = a32.shape
    bp, vp, n_tiles = _tile_prep(b32, valid_b, block_n)

    def body(state):
        t, best_d, best_i = state
        bt = jax.lax.dynamic_slice_in_dim(bp, t * block_n, block_n, axis=1)
        vt = jax.lax.dynamic_slice_in_dim(vp, t * block_n, block_n, axis=1)
        d2 = jnp.sum((a32[:, :, None, :] - bt[:, None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(vt[:, None, :], d2, jnp.inf)
        tmin = jnp.min(d2, axis=2)
        targ = jnp.argmin(d2, axis=2).astype(jnp.int32) + t * block_n
        better = tmin < best_d
        return (t + 1, jnp.where(better, tmin, best_d),
                jnp.where(better, targ, best_i))

    _, mins, args = jax.lax.while_loop(
        lambda s: s[0] < n_tiles, body,
        (jnp.int32(0), jnp.full((B, M), jnp.inf, jnp.float32),
         jnp.full((B, M), -1, jnp.int32)))
    return mins, args


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "interpret", "stop_at"))
def eps_count_batch(a: jnp.ndarray, b: jnp.ndarray, eps,
                    valid_b: Optional[jnp.ndarray] = None,
                    valid_a: Optional[jnp.ndarray] = None,
                    *, block_m: int = 128, block_n: int = 128,
                    interpret: Optional[bool] = None,
                    stop_at: Optional[int] = None) -> jnp.ndarray:
    """Batched eps-counts: a [B, M, d], b [B, N, d], valid_b [B, N].

    Returns [B, M] int32 counts of valid b-rows of batch slot g within
    ``eps`` of each a-row of slot g.  ``stop_at`` enables the saturating
    early-exit contract (module docstring); ``valid_a`` only feeds that
    exit decision -- invalid a-rows still receive (garbage) counts the
    caller masks."""
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if not _use_batch_pallas(interpret):
        if FORCE_REF:
            return ref.eps_count_batch(a32, b32, eps, valid_b)
        return _eps_count_tiled(a32, b32, eps2, valid_a, valid_b,
                                stop_at, block_n)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, :, None], b32, FAR)
    M = a.shape[1]
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0, axis=1))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR, axis=1))
    out = eps_count_batch_pallas(ap, bp, eps2, block_m=block_m,
                                 block_n=block_n,
                                 interpret=bool(interpret))
    return out[:, :M, 0]


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def row_min_batch(a: jnp.ndarray, b: jnp.ndarray,
                  valid_b: Optional[jnp.ndarray] = None,
                  *, block_m: int = 128, block_n: int = 128,
                  interpret: Optional[bool] = None):
    """Batched :func:`row_min`: a [B, M, d], b [B, N, d], valid_b [B, N].

    Returns ([B, M] f32 min squared distance, [B, M] int32 argmin into
    slot g's b-rows); a row with no valid candidate reports
    ``(inf, -1)``."""
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if not _use_batch_pallas(interpret):
        if FORCE_REF:
            return ref.row_min_batch(a32, b32, valid_b)
        return _row_min_tiled(a32, b32, valid_b, block_n)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, :, None], b32, FAR)
    M = a.shape[1]
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0, axis=1))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR, axis=1))
    mins, args = row_min_batch_pallas(ap, bp, block_m=block_m,
                                      block_n=block_n,
                                      interpret=bool(interpret))
    mins, args = mins[:, :M, 0], args[:, :M, 0]
    none = mins >= FAR_D2
    return (jnp.where(none, jnp.inf, mins),
            jnp.where(none, jnp.int32(-1), args))


def _eps_count_band_tiled(a32, b32, lo2, hi2, stop_row, valid_b, block_n):
    """Non-TPU fast path of :func:`eps_count_band_batch`: one b-tile
    loop accumulating both thresholds' counts.  ``stop_row`` ([B, M]
    int32 or None) is the per-row saturation bar on the *lo* count --
    the delta engine's MinPts-minus-own-count early exit; rows whose
    final lo-count is below their bar have provably scanned every valid
    tile, so their hi-count is complete (see the wrapper contract)."""
    B, M, _ = a32.shape
    bp, vp, n_tiles = _tile_prep(b32, valid_b, block_n)

    def cond(state):
        t, lo, hi = state
        live = t < n_tiles
        if stop_row is not None:
            live = live & jnp.any(lo < stop_row)
        return live

    def body(state):
        t, lo, hi = state
        bt = jax.lax.dynamic_slice_in_dim(bp, t * block_n, block_n, axis=1)
        vt = jax.lax.dynamic_slice_in_dim(vp, t * block_n, block_n, axis=1)
        d2 = jnp.sum((a32[:, :, None, :] - bt[:, None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(vt[:, None, :], d2, jnp.inf)
        return (t + 1,
                lo + (d2 <= lo2).sum(axis=2, dtype=jnp.int32),
                hi + (d2 <= hi2).sum(axis=2, dtype=jnp.int32))

    z = jnp.zeros((B, M), jnp.int32)
    _, lo, hi = jax.lax.while_loop(cond, body, (jnp.int32(0), z, z))
    return lo, hi


def _row_min2_tiled(a32, b32, valid_b, block_n):
    """Non-TPU fast path of :func:`row_min2_batch`: the ``_row_min_tiled``
    loop extended with the runner-up merge (smaller of both tiles'
    runners-up and the loser of the two firsts)."""
    B, M, _ = a32.shape
    bp, vp, n_tiles = _tile_prep(b32, valid_b, block_n)

    def body(state):
        t, best, best2, arg = state
        bt = jax.lax.dynamic_slice_in_dim(bp, t * block_n, block_n, axis=1)
        vt = jax.lax.dynamic_slice_in_dim(vp, t * block_n, block_n, axis=1)
        d2 = jnp.sum((a32[:, :, None, :] - bt[:, None, :, :]) ** 2, axis=-1)
        d2 = jnp.where(vt[:, None, :], d2, jnp.inf)
        tloc = jnp.argmin(d2, axis=2).astype(jnp.int32)
        tmin = jnp.min(d2, axis=2)
        cols = jnp.arange(d2.shape[2], dtype=jnp.int32)
        d2_wo = jnp.where(cols[None, None, :] == tloc[:, :, None],
                          jnp.inf, d2)
        tmin2 = jnp.min(d2_wo, axis=2)
        better = tmin < best
        loser = jnp.maximum(best, tmin)
        return (t + 1, jnp.where(better, tmin, best),
                jnp.minimum(jnp.minimum(best2, tmin2), loser),
                jnp.where(better, tloc + t * block_n, arg))

    inf = jnp.full((B, M), jnp.inf, jnp.float32)
    _, mins, mins2, args = jax.lax.while_loop(
        lambda s: s[0] < n_tiles, body,
        (jnp.int32(0), inf, inf, jnp.full((B, M), -1, jnp.int32)))
    return mins, mins2, args


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "interpret", "has_stop"))
def _eps_count_band_batch_jit(a, b, eps_lo, eps_hi, valid_b, stop_row,
                              *, block_m, block_n, interpret, has_stop):
    lo2 = jnp.asarray(eps_lo, jnp.float32) ** 2
    hi2 = jnp.asarray(eps_hi, jnp.float32) ** 2
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if not _use_batch_pallas(interpret):
        if FORCE_REF:
            return ref.eps_count_band_batch(a32, b32, eps_lo, eps_hi,
                                            valid_b)
        return _eps_count_band_tiled(a32, b32, lo2, hi2,
                                     stop_row if has_stop else None,
                                     valid_b, block_n)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, :, None], b32, FAR)
    M = a.shape[1]
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0, axis=1))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR, axis=1))
    lo, hi = eps_count_band_batch_pallas(
        ap, bp, jnp.stack([lo2, hi2]), block_m=block_m, block_n=block_n,
        interpret=bool(interpret))
    return lo[:, :M, 0], hi[:, :M, 0]


def eps_count_band_batch(a, b, eps_lo, eps_hi,
                         valid_b: Optional[jnp.ndarray] = None,
                         stop_row: Optional[jnp.ndarray] = None,
                         *, block_m: int = 128, block_n: int = 128,
                         interpret: Optional[bool] = None):
    """Two-threshold batched eps-counts (a [B, M, d], b [B, N, d]).

    Returns ``(count_lo, count_hi)`` [B, M] int32 -- hits at
    ``d2 <= eps_lo**2`` and ``d2 <= eps_hi**2`` in one sweep over the
    same distance tiles.  The guard-band serving path brackets the
    exact float64 count between the two whenever the f32 error of the
    decided distances is inside the band.

    ``stop_row`` ([B, M] int32) is a per-row saturating bar on the *lo*
    count (the MinPts-minus-base early exit; pass 0 to exempt padded
    rows).  Contract: a row whose returned ``count_lo`` is below its
    bar has scanned every valid candidate -- its counts are complete --
    because the loop only exits early once *every* row reached its bar.
    The TPU kernel scans everything, satisfying the contract trivially.
    """
    if stop_row is None:
        stop = jnp.zeros((a.shape[0], a.shape[1]), jnp.int32)
        has_stop = False
    else:
        stop, has_stop = stop_row, True
    return _eps_count_band_batch_jit(
        a, b, eps_lo, eps_hi, valid_b, stop, block_m=block_m,
        block_n=block_n, interpret=interpret_default(interpret),
        has_stop=has_stop)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def _row_min2_batch_jit(a, b, valid_b, *, block_m, block_n, interpret):
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if not _use_batch_pallas(interpret):
        if FORCE_REF:
            return ref.row_min2_batch(a32, b32, valid_b)
        return _row_min2_tiled(a32, b32, valid_b, block_n)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, :, None], b32, FAR)
    M = a.shape[1]
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0, axis=1))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR, axis=1))
    mins, mins2, args = row_min2_batch_pallas(
        ap, bp, block_m=block_m, block_n=block_n,
        interpret=bool(interpret))
    mins, mins2, args = mins[:, :M, 0], mins2[:, :M, 0], args[:, :M, 0]
    none = mins >= FAR_D2
    return (jnp.where(none, jnp.inf, mins),
            jnp.where(mins2 >= FAR_D2, jnp.inf, mins2),
            jnp.where(none, jnp.int32(-1), args))


@jax.jit
def _pairwise_d2_flat_jit(points_res, qa, rr, qo, av):
    diff = (points_res[rr] - av) - qa[qo]
    return jnp.sum(diff * diff, axis=1)


def pairwise_d2_flat(points_res, qa, rr, qo, av):
    """Flat ragged candidate distances: [T] float32 squared distances.

    The padded-chunk form (``row_min2_batch``) pays pow2 padding plus
    one dispatch per chunk; this op takes the ragged candidate list
    *flat* -- one dispatch, zero padding waste, all the O(T*d) distance
    math on device.  ``points_res`` is the [row_cap, d] float32
    resident buffer; ``rr``/``qo`` [T] int32 give each flat element's
    resident row and query slot; ``qa`` [m, d] float32 holds
    anchor-centered queries and ``av`` [T, d] each element's cell
    anchor (host-gathered -- shipping it per element keeps the jit key
    a function of the T bucket alone, so recompiles converge fast), so
    the subtraction runs on stencil-scale coordinates (same error
    budget as the chunked kernels).  The caller reduces the returned
    distances per segment (segmented min is O(T) and memory-bound;
    XLA's scatter-based segment ops lose to a single host
    ``minimum.reduceat`` pass on CPU, so the reduce stays with the
    caller).  Pure jnp (gather + map): XLA-native on every backend, so
    there is no pallas/interpret variant.
    """
    obs.counter("kernels.dispatch.pairwise_d2_flat").inc()
    return _pairwise_d2_flat_jit(points_res, qa, rr, qo, av)


@jax.jit
def _pairwise_d2_flat_res_jit(points_res, ra, rb, av):
    a = points_res[ra] - av
    b = points_res[rb] - av
    diff = a - b
    return jnp.sum(diff * diff, axis=1)


def pairwise_d2_flat_res(points_res, ra, rb, av):
    """``pairwise_d2_flat`` with *both* operands resident.

    ``ra``/``rb`` [T] int32 pick the two resident rows of each flat
    element; ``av`` [T, d] float32 is each element's cell anchor
    (host-gathered, same jit-key rationale as ``pairwise_d2_flat``).
    Both sides are re-centered by the same resident-row-minus-anchor
    subtract, so the float32 distances carry the established
    stencil-scale error budget.  Used by the delta engine's flat
    core-recount / merge-decide / border stages, where every operand
    already lives in the resident buffer.
    """
    obs.counter("kernels.dispatch.pairwise_d2_flat_res").inc()
    return _pairwise_d2_flat_res_jit(points_res, ra, rb, av)


def row_min2_batch(a, b, valid_b: Optional[jnp.ndarray] = None,
                   *, block_m: int = 128, block_n: int = 128,
                   interpret: Optional[bool] = None):
    """Batched (min, runner-up, argmin) squared distances.

    a [B, M, d], b [B, N, d], valid_b [B, N] -> ([B, M] f32 min d2,
    [B, M] f32 second-smallest slot d2, [B, M] int32 argmin).  The
    runner-up is over remaining slots (a duplicate distance counts),
    so ``min2 - min`` lower-bounds the argmin's margin: wider than the
    f32 error band proves the float64 argmin picks the same row.  No
    valid candidate -> (inf, inf, -1); exactly one -> (d2, inf, idx).
    """
    return _row_min2_batch_jit(a, b, valid_b, block_m=block_m,
                               block_n=block_n,
                               interpret=interpret_default(interpret))


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Blocked attention. q: [B, H, Sq, D]; k/v: [B, H, Sk, D] (H already
    broadcast over kv groups). Pads Sq/Sk to block multiples internally."""
    if FORCE_REF:
        return ref.mha(q, k, v, causal=causal, window=window,
                       softcap=softcap, scale=scale)
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)

    def pad_seq(x, blk, fill):
        s = x.shape[1]
        tgt = ((s + blk - 1) // blk) * blk
        if tgt == s:
            return x
        return jnp.concatenate(
            [x, jnp.full((x.shape[0], tgt - s, D), fill, x.dtype)], axis=1)

    qf = pad_seq(qf, block_q, 0.0)
    kf = pad_seq(kf, block_k, 0.0)
    vf = pad_seq(vf, block_k, 0.0)
    # padded queries sit at positions >= Sq and are sliced off; padded keys
    # are masked via sk_actual.
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, softcap=softcap,
        scale=scale, sk_actual=Sk, q_offset=Sk - Sq,
        block_q=block_q, block_k=block_k, interpret=_interpret())
    return out[:, :Sq].reshape(B, H, Sq, D)
