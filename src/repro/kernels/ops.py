"""jit'd public wrappers around the Pallas kernels.

Padding / masking policy
------------------------
The kernels require MXU-aligned shapes (rows % 128 == 0, feature dim ==
128 lanes).  The wrappers here pad:

* A-rows: zero-padded; callers receive `[M]` results sliced back.
* B-rows: padded with ``FAR`` coordinates so padded points can never
  satisfy a distance predicate (same convention as the device DBSCAN
  pipeline); an explicit ``valid_b`` mask folds into the same mechanism.
* feature dim: zero-padded to 128 (distances unchanged).

Platform dispatch: on CPU the kernels run under ``interpret=True``
(Python-evaluated, used by tests); on TPU they compile natively.  Set
``repro.kernels.ops.FORCE_REF = True`` to route everything through the
pure-jnp oracles in ``ref.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .pairwise import eps_count_pallas, row_min_pallas, LANE
from .flash_attention import flash_attention_pallas

FAR = 1e15
FORCE_REF = False


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jnp.ndarray, mult: int, fill: float) -> jnp.ndarray:
    m = x.shape[0]
    tgt = ((m + mult - 1) // mult) * mult
    if tgt == m:
        return x
    return jnp.concatenate(
        [x, jnp.full((tgt - m,) + x.shape[1:], fill, x.dtype)])


def _pad_feat(x: jnp.ndarray, lane: int = LANE) -> jnp.ndarray:
    d = x.shape[1]
    if d == lane:
        return x
    if d > lane:
        raise ValueError(f"feature dim {d} > lane width {lane}")
    return jnp.pad(x, ((0, 0), (0, lane - d)))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def eps_count(a: jnp.ndarray, b: jnp.ndarray, eps,
              valid_b: Optional[jnp.ndarray] = None,
              *, block_m: int = 128, block_n: int = 128) -> jnp.ndarray:
    """Count of b-points within ``eps`` of each a-point. Returns [M] int32."""
    if FORCE_REF:
        return ref.eps_count(a, b, eps, valid_b)
    M = a.shape[0]
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, None], b32, FAR)
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR))
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    out = eps_count_pallas(ap, bp, eps2, block_m=block_m, block_n=block_n,
                           interpret=_interpret())
    return out[:M, 0]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def row_min(a: jnp.ndarray, b: jnp.ndarray,
            valid_b: Optional[jnp.ndarray] = None,
            *, block_m: int = 128, block_n: int = 128):
    """Per-row (min squared distance, argmin) into b. Returns ([M], [M])."""
    if FORCE_REF:
        return ref.row_min(a, b, valid_b)
    M = a.shape[0]
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    if valid_b is not None:
        b32 = jnp.where(valid_b[:, None], b32, FAR)
    ap = _pad_feat(_pad_rows(a32, block_m, 0.0))
    bp = _pad_feat(_pad_rows(b32, block_n, FAR))
    mins, args = row_min_pallas(ap, bp, block_m=block_m, block_n=block_n,
                                interpret=_interpret())
    return mins[:M, 0], args[:M, 0]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Blocked attention. q: [B, H, Sq, D]; k/v: [B, H, Sk, D] (H already
    broadcast over kv groups). Pads Sq/Sk to block multiples internally."""
    if FORCE_REF:
        return ref.mha(q, k, v, causal=causal, window=window,
                       softcap=softcap, scale=scale)
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)

    def pad_seq(x, blk, fill):
        s = x.shape[1]
        tgt = ((s + blk - 1) // blk) * blk
        if tgt == s:
            return x
        return jnp.concatenate(
            [x, jnp.full((x.shape[0], tgt - s, D), fill, x.dtype)], axis=1)

    sq_pad = ((Sq + block_q - 1) // block_q) * block_q
    qf = pad_seq(qf, block_q, 0.0)
    kf = pad_seq(kf, block_k, 0.0)
    vf = pad_seq(vf, block_k, 0.0)
    # padded queries sit at positions >= Sq and are sliced off; padded keys
    # are masked via sk_actual.
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, softcap=softcap,
        scale=scale, sk_actual=Sk, q_offset=Sk - Sq,
        block_q=block_q, block_k=block_k, interpret=_interpret())
    return out[:, :Sq].reshape(B, H, Sq, D)
