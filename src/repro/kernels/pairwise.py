"""Blocked pairwise-distance Pallas kernels (TPU target, MXU-tiled).

The DBSCAN hot spots (core identification, FastMerging nearest queries,
border assignment) all reduce to tiles of squared Euclidean distances
between two point sets.  On TPU the `-2 a.b` term is an MXU matmul, so the
tile shapes are chosen MXU-aligned: 128 x 128 output tiles, feature dim
padded to the 128 lane width by the ops.py wrappers.

Kernels (one `pl.pallas_call` each, explicit VMEM BlockSpecs):

* ``eps_count_kernel``  -- per-row count of other-set points within eps.
* ``row_min_kernel``    -- per-row (min squared distance, argmin index).
* ``eps_count_batch_*`` / ``row_min_batch_*`` -- the same contractions
  with a leading grid-batch dimension, one (a-set, b-set) pair per grid
  of the DBSCAN pipeline; the batch axis is the outermost grid dimension
  so each (g, i) output block still accumulates across the j axis.

All iterate a (..., i, j) grid over (rows, cols) tiles and accumulate
across the j axis in the output block (revisited per i), the standard
Pallas accumulation pattern.  Padding policy (see ops.py): padded B-rows
carry coordinates so far away they can never satisfy a predicate (and
per-row validity masks are folded into the same FAR coordinates before
the call); padded A-rows produce garbage that callers slice off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128
LANE = 128


def _sq_dist_tile(a, b):
    """[BM, D] x [BN, D] -> [BM, BN] squared distances (f32, MXU dot)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    aa = jnp.sum(a * a, axis=1, keepdims=True)        # [BM, 1]
    bb = jnp.sum(b * b, axis=1, keepdims=True).T      # [1, BN]
    return jnp.maximum(aa + bb - 2.0 * ab, 0.0)


# --------------------------------------------------------------------------
# eps-count
# --------------------------------------------------------------------------

def _eps_count_kernel(a_ref, b_ref, eps2_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d2 = _sq_dist_tile(a_ref[...], b_ref[...])
    hit = (d2 <= eps2_ref[0, 0]).astype(jnp.int32)
    out_ref[...] += jnp.sum(hit, axis=1, keepdims=True)


def eps_count_pallas(a: jnp.ndarray, b: jnp.ndarray, eps2: jnp.ndarray,
                     *, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                     interpret: bool = False) -> jnp.ndarray:
    """a: [M, D], b: [N, D] (M % block_m == N % block_n == 0, D == LANE).

    Returns [M, 1] int32 counts of b-rows within sqrt(eps2) of each a-row.
    """
    M, D = a.shape
    N = b.shape[0]
    grid = (M // block_m, N // block_n)
    return pl.pallas_call(
        _eps_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, 1), jnp.int32),
        interpret=interpret,
    )(a, b, eps2.reshape(1, 1).astype(jnp.float32))


# --------------------------------------------------------------------------
# row-min (+ argmin)
# --------------------------------------------------------------------------

def _row_min_kernel(a_ref, b_ref, min_ref, arg_ref, *, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.full_like(arg_ref, -1)

    d2 = _sq_dist_tile(a_ref[...], b_ref[...])
    tile_min = jnp.min(d2, axis=1, keepdims=True)             # [BM, 1]
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
    better = tile_min < min_ref[...]
    min_ref[...] = jnp.where(better, tile_min, min_ref[...])
    arg_ref[...] = jnp.where(better, tile_arg + j * block_n, arg_ref[...])


def row_min_pallas(a: jnp.ndarray, b: jnp.ndarray,
                   *, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                   interpret: bool = False):
    """a: [M, D], b: [N, D] (aligned as in ``eps_count_pallas``).

    Returns ([M, 1] f32 min squared distance, [M, 1] int32 argmin row).
    """
    M, D = a.shape
    N = b.shape[0]
    grid = (M // block_m, N // block_n)
    return pl.pallas_call(
        functools.partial(_row_min_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, D), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, 1), jnp.float32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)


# --------------------------------------------------------------------------
# batched forms: leading grid-batch dimension (one DBSCAN grid per slot)
# --------------------------------------------------------------------------

def _eps_count_batch_kernel(a_ref, b_ref, eps2_ref, out_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d2 = _sq_dist_tile(a_ref[0, :, :], b_ref[0, :, :])
    hit = (d2 <= eps2_ref[0, 0]).astype(jnp.int32)
    out_ref[0, :, :] += jnp.sum(hit, axis=1, keepdims=True)


def eps_count_batch_pallas(a: jnp.ndarray, b: jnp.ndarray, eps2: jnp.ndarray,
                           *, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                           interpret: bool = False) -> jnp.ndarray:
    """a: [G, M, D], b: [G, N, D] (M % block_m == N % block_n == 0,
    D == LANE).  Returns [G, M, 1] int32 counts of b-rows of batch g
    within sqrt(eps2) of each a-row of batch g."""
    G, M, D = a.shape
    N = b.shape[1]
    grid = (G, M // block_m, N // block_n)
    return pl.pallas_call(
        _eps_count_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, D), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_n, D), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, 1), lambda g, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((G, M, 1), jnp.int32),
        interpret=interpret,
    )(a, b, eps2.reshape(1, 1).astype(jnp.float32))


def _eps_count_band_batch_kernel(a_ref, b_ref, eps2_ref, lo_ref, hi_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        hi_ref[...] = jnp.zeros_like(hi_ref)

    d2 = _sq_dist_tile(a_ref[0, :, :], b_ref[0, :, :])
    hit_lo = (d2 <= eps2_ref[0, 0]).astype(jnp.int32)
    hit_hi = (d2 <= eps2_ref[0, 1]).astype(jnp.int32)
    lo_ref[0, :, :] += jnp.sum(hit_lo, axis=1, keepdims=True)
    hi_ref[0, :, :] += jnp.sum(hit_hi, axis=1, keepdims=True)


def eps_count_band_batch_pallas(a: jnp.ndarray, b: jnp.ndarray,
                                eps2_band: jnp.ndarray,
                                *, block_m: int = BLOCK_M,
                                block_n: int = BLOCK_N,
                                interpret: bool = False):
    """Two-threshold twin of ``eps_count_batch_pallas``.

    a: [G, M, D], b: [G, N, D] (aligned), eps2_band: [2] (lo2, hi2)
    squared thresholds.  Returns two [G, M, 1] int32 count arrays --
    hits at ``d2 <= lo2`` and at ``d2 <= hi2``, accumulated in one
    sweep over the same distance tiles (the guard-band decision needs
    both counts and the tiles dominate the cost)."""
    G, M, D = a.shape
    N = b.shape[1]
    grid = (G, M // block_m, N // block_n)
    return pl.pallas_call(
        _eps_count_band_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, D), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_n, D), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, 2), lambda g, i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, M, 1), jnp.int32),
            jax.ShapeDtypeStruct((G, M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b, eps2_band.reshape(1, 2).astype(jnp.float32))


def _row_min2_batch_kernel(a_ref, b_ref, min_ref, min2_ref, arg_ref,
                           *, block_n: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        min2_ref[...] = jnp.full_like(min2_ref, jnp.inf)
        arg_ref[...] = jnp.full_like(arg_ref, -1)

    d2 = _sq_dist_tile(a_ref[0, :, :], b_ref[0, :, :])
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
    tile_min = jnp.min(d2, axis=1, keepdims=True)             # [BM, 1]
    cols = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2_wo = jnp.where(cols == tile_arg, jnp.inf, d2)
    tile_min2 = jnp.min(d2_wo, axis=1, keepdims=True)
    prev_min = min_ref[0, :, :]
    better = tile_min < prev_min
    # merge the two sorted (first, second) pairs: the global runner-up
    # is the smaller of both runners-up and the loser of the two firsts
    loser = jnp.maximum(prev_min, tile_min)
    min2_ref[0, :, :] = jnp.minimum(jnp.minimum(min2_ref[0, :, :],
                                                tile_min2), loser)
    min_ref[0, :, :] = jnp.where(better, tile_min, prev_min)
    arg_ref[0, :, :] = jnp.where(better, tile_arg + j * block_n,
                                 arg_ref[0, :, :])


def row_min2_batch_pallas(a: jnp.ndarray, b: jnp.ndarray,
                          *, block_m: int = BLOCK_M,
                          block_n: int = BLOCK_N,
                          interpret: bool = False):
    """``row_min_batch_pallas`` plus the runner-up distance.

    a: [G, M, D], b: [G, N, D] (aligned).  Returns ([G, M, 1] f32 min,
    [G, M, 1] f32 second-smallest slot distance, [G, M, 1] int32
    argmin).  The runner-up feeds the device path's argmin-certainty
    test: a gap wider than the float32 error band proves the float64
    argmin is the same row."""
    G, M, D = a.shape
    N = b.shape[1]
    grid = (G, M // block_m, N // block_n)
    return pl.pallas_call(
        functools.partial(_row_min2_batch_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, D), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_n, D), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, M, 1), jnp.float32),
            jax.ShapeDtypeStruct((G, M, 1), jnp.float32),
            jax.ShapeDtypeStruct((G, M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)


def _row_min_batch_kernel(a_ref, b_ref, min_ref, arg_ref, *, block_n: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.full_like(arg_ref, -1)

    d2 = _sq_dist_tile(a_ref[0, :, :], b_ref[0, :, :])
    tile_min = jnp.min(d2, axis=1, keepdims=True)             # [BM, 1]
    tile_arg = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]
    better = tile_min < min_ref[0, :, :]
    min_ref[0, :, :] = jnp.where(better, tile_min, min_ref[0, :, :])
    arg_ref[0, :, :] = jnp.where(better, tile_arg + j * block_n,
                                 arg_ref[0, :, :])


def row_min_batch_pallas(a: jnp.ndarray, b: jnp.ndarray,
                         *, block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                         interpret: bool = False):
    """a: [G, M, D], b: [G, N, D] (aligned as in
    ``eps_count_batch_pallas``).  Returns ([G, M, 1] f32 min squared
    distance, [G, M, 1] int32 argmin row within batch g)."""
    G, M, D = a.shape
    N = b.shape[1]
    grid = (G, M // block_m, N // block_n)
    return pl.pallas_call(
        functools.partial(_row_min_batch_kernel, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, D), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_n, D), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_m, 1), lambda g, i, j: (g, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, M, 1), jnp.float32),
            jax.ShapeDtypeStruct((G, M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
