"""zamba2-2.7b -- Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64.
The shared transformer block (one parameter set) is applied every
``shared_attn_every`` Mamba2 layers.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=10240, vocab_size=32000,
        ssm_state=64, expand=2, conv_width=4, shared_attn_every=6,
        chunk_size=256,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ce_chunk=32,
        ssm_state=16, ssm_heads=2, expand=2, conv_width=4,
        shared_attn_every=2, chunk_size=8,
    )
