"""Assigned input-shape sets (same four for every LM arch).

``train_*`` shapes lower ``train_step``; ``decode_*`` / ``long_*`` lower
``serve_step`` (one new token against a KV cache of seq_len);
``prefill_*`` lowers the prefill graph.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524_288, 1),
}


def get_shape(name: str) -> ShapeCfg:
    return SHAPES[name]
