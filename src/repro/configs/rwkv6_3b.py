"""rwkv6-3b -- Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="rwkv6-3b", family="rwkv",
        num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=64, d_ff=8960, vocab_size=65536,
        attn_kind="none", chunk_size=16, ce_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="rwkv6-smoke", family="rwkv",
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512,
        attn_kind="none", chunk_size=8, ce_chunk=32,
    )
