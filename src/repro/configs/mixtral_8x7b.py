"""mixtral-8x7b -- 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=14336 vocab=32000.
"""

from repro.models.config import LMConfig, MoECfg


def config() -> LMConfig:
    return LMConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=32000,
        attn_kind="swa", window=4096, rope_theta=1e6,
        moe=MoECfg(num_experts=8, top_k=2, d_ff=14336),
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="mixtral-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        attn_kind="swa", window=16, attn_chunk=16, ce_chunk=32,
        moe=MoECfg(num_experts=4, top_k=2, d_ff=128),
    )
