"""internvl2-1b -- InternViT frontend (stubbed) + Qwen2-0.5B LM backbone
[arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
``input_specs`` feeds precomputed patch embeddings [B, 256, d].
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="internvl2-1b", family="vlm",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151655,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
        num_patches=256, ce_chunk=256,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, ce_chunk=32,
        qkv_bias=True, tie_embeddings=True, num_patches=8,
    )
