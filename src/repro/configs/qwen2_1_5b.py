"""qwen2-1.5b -- GQA, QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        head_dim=128, d_ff=8960, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
        ce_chunk=256,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512,
        qkv_bias=True, tie_embeddings=True, ce_chunk=32,
    )
