"""arctic-480b -- 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
"""

from repro.models.config import LMConfig, MoECfg


def config() -> LMConfig:
    return LMConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=4864, vocab_size=32000,
        attn_kind="full",
        moe=MoECfg(num_experts=128, top_k=2, d_ff=4864,
                   dense_residual=True),
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="arctic-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=512, ce_chunk=32,
        attn_kind="full",
        moe=MoECfg(num_experts=8, top_k=2, d_ff=96, dense_residual=True),
    )
