"""Architecture registry: ``--arch <id>`` -> full / smoke LMConfig.

Each arch module defines ``config()`` (the exact published configuration)
and ``smoke_config()`` (same family, reduced: few layers, thin width,
tiny vocab) used by the CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import List

ARCHS = [
    "rwkv6_3b", "mixtral_8x7b", "arctic_480b", "qwen2_1_5b", "stablelm_3b",
    "qwen1_5_0_5b", "gemma2_27b", "whisper_small", "zamba2_2_7b",
    "internvl2_1b",
]

def canonical(arch: str) -> str:
    """Normalize public ids ('qwen2-1.5b', 'mixtral-8x7b') to module names."""
    norm = arch.replace("-", "_").replace(".", "_")
    for a in ARCHS:
        if norm == a or norm == a.replace(".", "_"):
            return a
    # tolerate ids like 'qwen1.5-0.5b' -> 'qwen1_5_0_5b'
    return norm


def list_archs() -> List[str]:
    return list(ARCHS)


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.smoke_config() if smoke else mod.config()


def long_500k_supported(arch: str) -> bool:
    """Sub-quadratic decode: SSM / hybrid / linear-attn / bounded-window."""
    return canonical(arch) in ("rwkv6_3b", "zamba2_2_7b", "mixtral_8x7b")
