"""gemma2-27b -- local+global alternating attention, logit softcap
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="gemma2-27b", family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256000,
        attn_kind="local_global", window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        act="gelu", scale_embed=True, rope_theta=1e4,
        ce_chunk=128,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="gemma2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=512,
        attn_kind="local_global", window=16, attn_chunk=16,
        attn_softcap=50.0, logit_softcap=30.0,
        act="gelu", scale_embed=True, rope_theta=1e4, ce_chunk=32,
    )
