"""Arch configs (one module per assigned architecture) + shape sets."""

from .registry import get_config, list_archs, canonical, long_500k_supported
from .shapes import SHAPES, get_shape, ShapeCfg
