"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b; unverified].

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
LayerNorm + partial rotary (25%), stablelm-2 style.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=6912, vocab_size=50304,
        norm="layer", rope_fraction=0.25, rope_theta=1e4,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ce_chunk=32,
        norm="layer", rope_fraction=0.25, rope_theta=1e4,
    )
