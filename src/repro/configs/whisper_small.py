"""whisper-small -- encoder-decoder, conv frontend stubbed
[arXiv:2212.04356; unverified].

12L enc + 12L dec, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
``input_specs`` feeds precomputed audio-frame embeddings [B, 1500, d].
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="whisper-small", family="encdec",
        num_layers=12, enc_layers=12, enc_seq=1500,
        d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=51865,
        norm="layer", act="gelu", mlp_kind="plain", rope_theta=1e4,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, enc_layers=2, enc_seq=24,
        d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ce_chunk=32,
        norm="layer", act="gelu", mlp_kind="plain", rope_theta=1e4,
    )
