"""qwen1.5-0.5b -- QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""

from repro.models.config import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-0.5b", family="dense",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=2816, vocab_size=151936,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e4,
        ce_chunk=256,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, ce_chunk=32,
        qkv_bias=True, tie_embeddings=True, rope_theta=1e4,
    )
