"""Shared scenario library: one catalogue of datasets for conformance
tests AND benchmarks (so they stop duplicating data generation).

Each :class:`Scenario` bundles a generator with the (eps, min_pts) that
make it interesting, mirroring how Wang/Gu/Shun and de Berg et al.
validate grid/parallel DBSCAN variants: exact equivalence against a
sequential oracle across a *grid* of adversarial shapes, not just happy
blobs.  The catalogue covers:

* gaussian blobs at every supported dimensionality d in {1..5},
* dense/sparse uniform boxes (one giant cluster / all-noise),
* 2-D moons and concentric rings (non-convex clusters),
* collinear and exactly-duplicated points (degenerate geometry),
* a single-grid blob (the all-core shortcut path),
* chains with gaps placed just inside/outside eps (merge threshold),
* lattices jittered against the grid side eps/sqrt(d) (identifier
  boundary behaviour),
* a cross-slab snake spanning every shard boundary (distributed path).

Deliberate margins: threshold scenarios place gaps at a relative margin
(default 1e-3) away from eps so float32 device engines and the float64
host oracle land on the same side of every comparison.  DBSCAN itself is
discontinuous at exact equality; testing *at* the knife edge tests the
rounding mode, not the algorithm.

Domain is [0, DOMAIN]^d (the paper's normalized integer domain).

Serving workloads (:class:`ServingScenario`, ``serving_scenarios()``)
layer fit-once / serve-many traffic on top of the catalogue: a base fit
set plus held-out query batches (near-cluster, empty-grid,
outside-the-fitted-box, and exact-eps-boundary queries) and streaming
micro-batch inserts that drift outside the fitted bounding box.
``dist_serving_scenarios()`` are the sharded-serving variants: traffic
engineered at the slab cut bands (queries that must consult two shards,
inserts whose blobs straddle a cut and whose merges need cross-shard
re-reconciliation).

Churn workloads (:class:`ChurnScenario`, ``churn_scenarios()``) add the
delete direction: deterministic interleaved insert/delete op streams
engineered at DBSCAN's non-monotone spots -- bridge cuts that split a
cluster in two, thinning that demotes cores to border/noise, deletes
below the shifted identifier origin, a whole grid emptied at once, and
TTL sliding windows that eventually erase entire fitted regions.  The
mutation-plane tests replay each op against both index flavors and pin
the read-out to a from-scratch ``cluster()`` on the surviving set.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .seed_spreader import seed_spreader, DOMAIN


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named dataset + the DBSCAN parameters it should be run with."""

    name: str
    d: int
    n: int
    eps: float
    min_pts: int
    gen: Callable[[np.random.Generator, int, int], np.ndarray]
    tags: Tuple[str, ...] = ()

    def points(self, seed: int = 0, n: Optional[int] = None) -> np.ndarray:
        """Generate the dataset ([n, d] float64, inside [0, DOMAIN]^d)."""
        rng = np.random.default_rng(seed)
        pts = self.gen(rng, n or self.n, self.d)
        assert pts.shape == (n or self.n, self.d), \
            f"{self.name}: generator returned {pts.shape}"
        return np.clip(np.asarray(pts, np.float64), 0.0, DOMAIN)

    def has(self, tag: str) -> bool:
        return tag in self.tags


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------

def _blobs(rng: np.random.Generator, n: int, d: int, k: int = 4,
           spread: float = 900.0) -> np.ndarray:
    """k gaussian blobs + 5% uniform noise."""
    n_noise = max(n // 20, 1)
    centers = rng.uniform(0.15 * DOMAIN, 0.85 * DOMAIN, size=(k, d))
    which = rng.integers(0, k, size=n - n_noise)
    pts = centers[which] + rng.normal(scale=spread, size=(n - n_noise, d))
    noise = rng.uniform(0, DOMAIN, size=(n_noise, d))
    return np.concatenate([pts, noise])


def _uniform(rng: np.random.Generator, n: int, d: int,
             box: float) -> np.ndarray:
    lo = (DOMAIN - box) / 2
    return lo + rng.uniform(0, box, size=(n, d))


def _moons(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    """Two interleaved half-circles (classic non-convex pair)."""
    assert d == 2
    m = n // 2
    t1 = rng.uniform(0, np.pi, size=m)
    t2 = rng.uniform(0, np.pi, size=n - m)
    r = 0.25 * DOMAIN
    a = np.stack([r * np.cos(t1), r * np.sin(t1)], axis=1)
    b = np.stack([r - r * np.cos(t2), -r * np.sin(t2) + 0.35 * r], axis=1)
    pts = np.concatenate([a, b]) + rng.normal(scale=0.01 * r, size=(n, 2))
    return pts + 0.5 * DOMAIN - np.array([r / 2, 0.0])


def _rings(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    """Two concentric annuli around the domain center."""
    assert d == 2
    m = n // 2
    theta = rng.uniform(0, 2 * np.pi, size=n)
    radii = np.concatenate([
        np.full(m, 0.12 * DOMAIN), np.full(n - m, 0.30 * DOMAIN)])
    radii = radii * (1 + rng.uniform(-0.03, 0.03, size=n))
    pts = np.stack([radii * np.cos(theta), radii * np.sin(theta)], axis=1)
    return pts + 0.5 * DOMAIN


def _collinear(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    """Points on a 1-D line embedded in R^d: two dense segments with a
    wide gap, plus a handful of isolated (noise) points on the same line."""
    n_seg = (n - 4) // 2
    step = 300.0
    a = np.arange(n_seg) * step + 0.1 * DOMAIN
    b = np.arange(n - 4 - n_seg) * step + 0.6 * DOMAIN
    iso = np.linspace(0.45 * DOMAIN, 0.55 * DOMAIN, 4)
    x = np.concatenate([a, b, iso])
    pts = np.zeros((n, d))
    pts[:, 0] = x
    if d > 1:
        pts[:, 1:] = 0.5 * DOMAIN     # constant: exactly collinear
    return pts


def _duplicates(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    """A few exact locations repeated many times (zero distances, ties)
    plus singleton outliers that must come out as noise."""
    k = 5
    centers = rng.uniform(0.2 * DOMAIN, 0.8 * DOMAIN, size=(k, d))
    n_iso = min(8, n // 10)
    reps = (n - n_iso) // k
    pts = np.repeat(centers, reps, axis=0)
    iso = rng.uniform(0, DOMAIN, size=(n - len(pts), d))
    return np.concatenate([pts, iso])


def _single_grid(rng: np.random.Generator, n: int, d: int,
                 eps: float) -> np.ndarray:
    """Everything inside ONE grid cell (side eps/sqrt(d)): exercises the
    all-core shortcut and the one-grid degenerate tree."""
    side = eps / np.sqrt(d)
    lo = 0.5 * DOMAIN
    # strictly interior so f32/f64 floor() agree on the cell
    return lo + side * 0.1 + rng.uniform(0, side * 0.8, size=(n, d))


def _eps_chain(rng: np.random.Generator, n: int, d: int, eps: float,
               margin: float = 1e-3) -> np.ndarray:
    """A chain along dim 0 with steps alternating just-below eps, and one
    single break just-above eps in the middle: exactly two clusters.

    The margin keeps every pairwise comparison decidable in float32
    (DBSCAN is discontinuous at exact equality; see module docstring).
    """
    steps = np.full(n - 1, eps * (1 - margin))
    steps[n // 2] = eps * (1 + margin)
    x = np.concatenate([[0.0], np.cumsum(steps)]) + 0.05 * DOMAIN
    pts = np.zeros((n, d))
    pts[:, 0] = x
    if d > 1:
        pts[:, 1:] = 0.5 * DOMAIN + rng.normal(scale=eps * 0.01,
                                               size=(n, d - 1))
    return pts


def _grid_boundary_lattice(rng: np.random.Generator, n: int, d: int,
                           eps: float) -> np.ndarray:
    """Points jittered around multiples of ~the grid side eps/sqrt(d), so
    many land a hair from identifier boundaries: adversarial for the
    partition (floor) step while distances stay comfortably decidable.

    Spacing is 0.95 * side, NOT side exactly: at spacing == side the
    lattice diagonal equals eps to within float rounding (side**2 * d ==
    eps**2), which would make core-ness a knife-edge f32-vs-f64 call."""
    side = eps / np.sqrt(d)
    m = int(np.ceil(n ** (1 / d)))
    axes = [np.arange(m) * side * 0.95 for _ in range(d)]
    mesh = np.meshgrid(*axes, indexing="ij")
    lattice = np.stack([g.ravel() for g in mesh], axis=1)[:n]
    jitter = rng.choice([-1.0, 1.0], size=lattice.shape) * side * 2e-3
    return lattice + jitter + 0.3 * DOMAIN


def _cross_slab_snake(rng: np.random.Generator, n: int, d: int
                      ) -> np.ndarray:
    """One long connected snake spanning the whole dim-0 extent (crosses
    every slab boundary of the distributed sharding) + uniform noise."""
    n_noise = max(n // 10, 1)
    m = n - n_noise
    t = np.linspace(0, 1, m)
    pts = np.zeros((m, d))
    pts[:, 0] = t * DOMAIN
    if d > 1:
        pts[:, 1] = 0.5 * DOMAIN + 0.1 * DOMAIN * np.sin(6 * t)
    if d > 2:
        pts[:, 2:] = 0.5 * DOMAIN
    pts += rng.normal(scale=300.0, size=pts.shape)
    noise = rng.uniform(0, DOMAIN, size=(n_noise, d))
    return np.concatenate([pts, noise])


def _seed_spreader(variant: str, restarts: int):
    def gen(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
        # seed_spreader manages its own rng; derive a seed from ours
        return seed_spreader(n, d, variant=variant, restarts=restarts,
                             seed=int(rng.integers(2 ** 31)))
    return gen


# --------------------------------------------------------------------------
# the catalogue
# --------------------------------------------------------------------------

def default_scenarios() -> List[Scenario]:
    """The cross-engine conformance / benchmark matrix.

    Tags:
      quick  -- in the default (non-slow) device conformance subset
      slab   -- spans shard boundaries; exercised by the distributed path
      degenerate -- geometry edge cases (duplicates, collinear, 1-D)
    """
    s: List[Scenario] = []

    for d in (1, 2, 3, 4, 5):
        s.append(Scenario(
            name=f"blobs-{d}d", d=d, n=220, eps=2500.0, min_pts=6,
            gen=lambda rng, n, dd: _blobs(rng, n, dd),
            tags=("quick",) if d == 3 else ()))

    s.append(Scenario(
        name="uniform-dense-2d", d=2, n=256, eps=9000.0, min_pts=5,
        gen=lambda rng, n, d: _uniform(rng, n, d, box=0.5 * DOMAIN)))
    s.append(Scenario(
        name="all-noise-3d", d=3, n=160, eps=800.0, min_pts=5,
        gen=lambda rng, n, d: _uniform(rng, n, d, box=DOMAIN)))

    s.append(Scenario(
        name="moons-2d", d=2, n=240, eps=2200.0, min_pts=5, gen=_moons))
    s.append(Scenario(
        name="rings-2d", d=2, n=240, eps=3500.0, min_pts=5, gen=_rings))

    s.append(Scenario(
        name="collinear-3d", d=3, n=200, eps=1000.0, min_pts=4,
        gen=_collinear, tags=("degenerate",)))
    s.append(Scenario(
        name="duplicates-2d", d=2, n=200, eps=1500.0, min_pts=5,
        gen=_duplicates, tags=("degenerate",)))
    s.append(Scenario(
        name="line-1d", d=1, n=150, eps=1200.0, min_pts=4,
        gen=_collinear, tags=("degenerate",)))

    s.append(Scenario(
        name="single-grid-3d", d=3, n=180, eps=4000.0, min_pts=6,
        gen=lambda rng, n, d: _single_grid(rng, n, d, eps=4000.0)))

    s.append(Scenario(
        name="eps-chain-2d", d=2, n=64, eps=1200.0, min_pts=2,
        gen=lambda rng, n, d: _eps_chain(rng, n, d, eps=1200.0)))
    s.append(Scenario(
        name="grid-boundary-2d", d=2, n=225, eps=3000.0, min_pts=4,
        gen=lambda rng, n, d: _grid_boundary_lattice(rng, n, d, eps=3000.0)))

    s.append(Scenario(
        name="cross-slab-2d", d=2, n=320, eps=2500.0, min_pts=5,
        gen=_cross_slab_snake, tags=("slab", "quick")))
    s.append(Scenario(
        name="cross-slab-3d", d=3, n=320, eps=3000.0, min_pts=5,
        gen=_cross_slab_snake, tags=("slab",)))

    s.append(Scenario(
        name="varden-3d", d=3, n=300, eps=4000.0, min_pts=8,
        gen=_seed_spreader("varden", restarts=4)))
    s.append(Scenario(
        name="simden-5d", d=5, n=300, eps=4000.0, min_pts=8,
        gen=_seed_spreader("simden", restarts=4)))

    return s


# --------------------------------------------------------------------------
# serving scenarios: base fit set + held-out query / insert traffic
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingScenario:
    """A fit-once / serve-many workload over a base :class:`Scenario`.

    ``query_batch`` produces held-out point queries against the fitted
    index (the predict plane); ``insert_batches`` produces a stream of
    micro-batches (the insert plane).  Both are deterministic in the
    seed, like the base catalogue.
    """

    name: str
    base: Scenario
    n_query: int
    n_insert: int                   # points per insert batch
    query_gen: Callable[[np.random.Generator, np.ndarray, "Scenario", int],
                        np.ndarray]
    insert_gen: Callable[[np.random.Generator, np.ndarray, "Scenario",
                          int, int, int], np.ndarray]
    insert_steps: int = 3
    tags: Tuple[str, ...] = ("serving",)

    def fit_points(self, seed: int = 0) -> np.ndarray:
        return self.base.points(seed)

    def query_batch(self, seed: int = 0, n: Optional[int] = None
                    ) -> np.ndarray:
        rng = np.random.default_rng(10_000 + seed)
        q = self.query_gen(rng, self.fit_points(seed), self.base,
                           n or self.n_query)
        assert q.shape == (n or self.n_query, self.base.d)
        return np.asarray(q, np.float64)

    def insert_batches(self, seed: int = 0,
                       steps: Optional[int] = None) -> List[np.ndarray]:
        rng = np.random.default_rng(20_000 + seed)
        base = self.fit_points(seed)
        k = steps or self.insert_steps
        return [np.asarray(
            self.insert_gen(rng, base, self.base, self.n_insert, t, k),
            np.float64) for t in range(k)]


def _queries_mixed(rng: np.random.Generator, base: np.ndarray,
                   sc: Scenario, n: int) -> np.ndarray:
    """Held-out predict traffic covering every assignment regime:

    * near-duplicates of fitted points (deep inside clusters),
    * uniform points over an *extended* box -- many land in empty grids
      or outside the fitted bounding box (negative identifiers),
    * a ring at 0.5..2 eps from fitted points (the border/noise band,
      kept a relative margin away from eps itself),
    * queries placed *exactly* on the eps boundary of a fitted point
      (one axis-aligned eps step: distance == eps up to one rounding of
      the f64 sum, landing as close to the <=-vs-> knife edge as f64
      allows -- predict and oracle must still agree bit-for-bit because
      both evaluate the identical f64 expression).
    """
    d = sc.d
    n_near = int(0.4 * n)
    n_far = int(0.25 * n)
    n_ring = int(0.2 * n)
    n_edge = n - n_near - n_far - n_ring
    near = base[rng.integers(0, len(base), n_near)] + rng.normal(
        scale=0.1 * sc.eps, size=(n_near, d))
    far = rng.uniform(-0.15 * DOMAIN, 1.15 * DOMAIN, size=(n_far, d))
    anchors = base[rng.integers(0, len(base), n_ring)]
    dirs = rng.normal(size=(n_ring, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    radii = rng.uniform(0.5, 2.0, size=(n_ring, 1)) * sc.eps
    # stay a relative margin off eps so f32 predict modes agree too
    radii = np.where(np.abs(radii - sc.eps) < 1e-3 * sc.eps,
                     sc.eps * (1 + 2e-3), radii)
    ring = anchors + dirs * radii
    edge_anchor = base[rng.integers(0, len(base), n_edge)]
    axis = rng.integers(0, d, n_edge)
    edge = edge_anchor.copy()
    edge[np.arange(n_edge), axis] += sc.eps
    return np.concatenate([near, far, ring, edge])


def _insert_drift(rng: np.random.Generator, base: np.ndarray,
                  sc: Scenario, n: int, step: int, steps: int
                  ) -> np.ndarray:
    """Streaming drift: each micro-batch is a blob whose center walks
    from inside the fitted region off past the corner of the domain
    (later batches fall *outside* the fitted bounding box, exercising
    the identifier-origin shift), plus a sprinkle of points landing on
    the fitted clusters (growing/merging existing structure)."""
    d = sc.d
    t = (step + 1) / steps
    center = ((1 - t) * 0.5 * DOMAIN
              + t * 1.12 * DOMAIN) * np.ones(d)
    n_blob = int(0.7 * n)
    blob = center + rng.normal(scale=1.5 * sc.eps, size=(n_blob, d))
    onto = base[rng.integers(0, len(base), n - n_blob)] + rng.normal(
        scale=0.4 * sc.eps, size=(n - n_blob, d))
    return np.concatenate([blob, onto])


def _quantile_cuts(base: np.ndarray, k: int = 3) -> np.ndarray:
    """Approximate slab-cut dim-0 coordinates: the equal-count cut
    policy puts them near the interior count quantiles."""
    x0 = np.sort(base[:, 0])
    return x0[[(i * len(x0)) // (k + 1) for i in range(1, k + 1)]]


def _queries_slab_band(rng: np.random.Generator, base: np.ndarray,
                       sc: Scenario, n: int) -> np.ndarray:
    """Distributed-serving predict traffic: half the mixed catalogue
    regimes (near / far / eps-ring / exact-eps), half aimed at the slab
    *cut bands* -- dim-0 coordinates within ~2.5 eps of the equal-count
    quantile lines, where the sharded router must consult both
    neighboring shards and still match the brute rule bit-for-bit."""
    n_mix = n // 2
    mix = _queries_mixed(rng, base, sc, n_mix)
    cuts = _quantile_cuts(base)
    band = base[rng.integers(0, len(base), n - n_mix)].copy()
    which = rng.integers(0, len(cuts), n - n_mix)
    band[:, 0] = cuts[which] + rng.uniform(-2.5, 2.5,
                                           n - n_mix) * sc.eps
    return np.concatenate([mix, band])


def _insert_slab_drift(rng: np.random.Generator, base: np.ndarray,
                       sc: Scenario, n: int, step: int, steps: int
                       ) -> np.ndarray:
    """Distributed-serving insert traffic: blobs centered ON a cut line
    (cross-shard structure: new cores on both sides, merges witnessed
    by shared points), bridges between random fitted pairs (label
    splices that may span slabs), plus a dim-0 drift component walking
    past the domain edge (identifier-origin shifts inside end slabs)."""
    d = sc.d
    cuts = _quantile_cuts(base)
    cut = cuts[step % len(cuts)]
    n_cut = int(0.4 * n)
    n_bridge = int(0.3 * n)
    n_drift = n - n_cut - n_bridge
    center = np.full(d, 0.5 * DOMAIN)
    center[0] = cut
    if d > 1:
        center[1:] = base[rng.integers(0, len(base)), 1:]
    blob = center + rng.normal(scale=1.2 * sc.eps, size=(n_cut, d))
    a, b = base[rng.integers(0, len(base), (2, n_bridge))]
    bridge = a + rng.uniform(0, 1, size=(n_bridge, 1)) * (b - a)
    t = (step + 1) / steps
    dcen = np.full(d, 0.5 * DOMAIN)
    dcen[0] = (1 - t) * 0.5 * DOMAIN + t * 1.15 * DOMAIN
    drift = dcen + rng.normal(scale=1.5 * sc.eps, size=(n_drift, d))
    return np.concatenate([blob, bridge, drift])


# --------------------------------------------------------------------------
# churn scenarios: interleaved insert/delete op streams
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChurnScenario:
    """A base fit plus a deterministic interleaved mutation stream.

    :meth:`ops` yields ``("insert", points)`` / ``("delete",
    arrival_ids)`` pairs; arrival ids are *global*: the base fit takes
    ``0..n-1`` and every insert appends ids in submission order --
    exactly the id discipline of ``GritIndex`` / ``ShardedGritIndex``
    (ids are never reused, deletes may target any earlier op's
    points).  Deterministic in the seed, like the rest of the
    catalogue.
    """

    name: str
    base: Scenario
    plan: Callable[[np.random.Generator, np.ndarray, Scenario],
                   List[Tuple[str, np.ndarray]]]
    tags: Tuple[str, ...] = ("churn",)

    def fit_points(self, seed: int = 0) -> np.ndarray:
        return self.base.points(seed)

    def ops(self, seed: int = 0) -> List[Tuple[str, np.ndarray]]:
        rng = np.random.default_rng(30_000 + seed)
        out = self.plan(rng, self.fit_points(seed), self.base)
        for kind, payload in out:
            assert kind in ("insert", "delete"), kind
        return out


def _plan_churn_split(rng: np.random.Generator, base: np.ndarray,
                      sc: Scenario) -> List[Tuple[str, np.ndarray]]:
    """The non-monotone corners, one op each: build two blobs + a dense
    bridge (one merged cluster), cut the bridge (split in two), empty
    one grid-sized box of the base set, insert below the fitted origin
    (id_shift) then delete half of those, and thin a blob below MinPts
    (core -> border/noise demotions)."""
    eps, mp = sc.eps, sc.min_pts
    ops: List[Tuple[str, np.ndarray]] = []
    nid = len(base)

    def ins(pts: np.ndarray) -> np.ndarray:
        nonlocal nid
        ids = np.arange(nid, nid + len(pts), dtype=np.int64)
        nid += len(pts)
        ops.append(("insert", np.asarray(pts, np.float64)))
        return ids

    c = np.full(2, 0.5 * DOMAIN)
    off = np.array([4.0 * eps, 0.0])
    left = ins((c - off) + rng.normal(scale=0.3 * eps,
                                      size=(4 * mp, 2)))
    ins((c + off) + rng.normal(scale=0.3 * eps, size=(4 * mp, 2)))
    t = np.linspace(0.0, 1.0, 8 * mp)[:, None]
    bridge = ins((c - off) + t * (2 * off)
                 + rng.normal(scale=0.05 * eps, size=(8 * mp, 2)))
    ops.append(("delete", bridge))          # bridge cut: cluster splits
    side = eps / np.sqrt(2.0)
    lo = np.quantile(base, 0.4, axis=0)
    in_box = np.flatnonzero(
        ((base >= lo) & (base < lo + side)).all(axis=1))
    ops.append(("delete", in_box))          # one whole grid emptied
    below = ins(base.min(axis=0) - 10 * eps
                + rng.uniform(0, eps, size=(3 * mp, 2)))
    ops.append(("delete", below[::2]))      # delete below shifted origin
    ops.append(("delete", left[: 3 * mp]))  # thin a blob: demotions
    return ops


def _plan_ttl_drift(rng: np.random.Generator, base: np.ndarray,
                    sc: Scenario, steps: int = 4
                    ) -> List[Tuple[str, np.ndarray]]:
    """TTL sliding window over a drifting stream: each step inserts a
    blob walking off past the domain corner (outside the fitted box:
    identifier-origin shifts) plus on-cluster points, then expires the
    oldest as many live points -- the window eventually erases entire
    original grids while the drift keeps opening new ones."""
    eps, d = sc.eps, sc.d
    ops: List[Tuple[str, np.ndarray]] = []
    nid = len(base)
    live: List[int] = list(range(len(base)))
    for step in range(steps):
        t = (step + 1) / steps
        center = ((1 - t) * 0.5 + t * 1.12) * DOMAIN * np.ones(d)
        blob = center + rng.normal(scale=1.5 * eps, size=(40, d))
        onto = base[rng.integers(0, len(base), 16)] + rng.normal(
            scale=0.4 * eps, size=(16, d))
        pts = np.concatenate([blob, onto])
        ops.append(("insert", pts))
        ids = list(range(nid, nid + len(pts)))
        nid += len(pts)
        live += ids
        expire, live = live[:len(pts)], live[len(pts):]
        ops.append(("delete", np.asarray(expire, np.int64)))
    return ops


def churn_scenarios() -> List[ChurnScenario]:
    """Interleaved insert/delete workloads for the mutation-plane
    tests and ``benchmarks/run.py --churn``."""
    base = scenario_map()
    return [
        ChurnScenario(name="churn-split-2d", base=base["blobs-2d"],
                      plan=_plan_churn_split,
                      tags=("churn", "split")),
        ChurnScenario(name="ttl-drift-3d", base=base["blobs-3d"],
                      plan=_plan_ttl_drift,
                      tags=("churn", "ttl")),
    ]


def churn_scenario_map() -> Dict[str, ChurnScenario]:
    return {sc.name: sc for sc in churn_scenarios()}


def get_churn_scenario(name: str) -> ChurnScenario:
    m = churn_scenario_map()
    if name not in m:
        raise KeyError(
            f"unknown churn scenario {name!r}; known: {sorted(m)}")
    return m[name]


def serving_scenarios() -> List[ServingScenario]:
    """Fit/query/insert workloads for the index + serving tests."""
    base = scenario_map()
    return [
        ServingScenario(
            name="query-heavy-3d", base=base["blobs-3d"],
            n_query=200, n_insert=48,
            query_gen=_queries_mixed, insert_gen=_insert_drift,
            tags=("serving", "query")),
        ServingScenario(
            name="drift-2d", base=base["blobs-2d"],
            n_query=120, n_insert=64, insert_steps=3,
            query_gen=_queries_mixed, insert_gen=_insert_drift,
            tags=("serving", "drift")),
    ]


def dist_serving_scenarios() -> List[ServingScenario]:
    """Distributed-serving workloads: slab-spanning fit sets with
    query/insert traffic engineered at the cut bands (the sharded
    index's routing and re-reconciliation paths)."""
    base = scenario_map()
    return [
        ServingScenario(
            name="slab-serve-2d", base=base["cross-slab-2d"],
            n_query=160, n_insert=40,
            query_gen=_queries_slab_band, insert_gen=_insert_slab_drift,
            tags=("serving", "dist-serving")),
        ServingScenario(
            name="slab-serve-3d", base=base["cross-slab-3d"],
            n_query=140, n_insert=36,
            query_gen=_queries_slab_band, insert_gen=_insert_slab_drift,
            tags=("serving", "dist-serving")),
        ServingScenario(
            name="slab-blobs-2d", base=base["blobs-2d"],
            n_query=120, n_insert=40, insert_steps=3,
            query_gen=_queries_slab_band, insert_gen=_insert_slab_drift,
            tags=("serving", "dist-serving")),
    ]


def dist_serving_scenario_map() -> Dict[str, ServingScenario]:
    return {sc.name: sc for sc in dist_serving_scenarios()}


def get_dist_serving_scenario(name: str) -> ServingScenario:
    m = dist_serving_scenario_map()
    if name not in m:
        raise KeyError(
            f"unknown distributed serving scenario {name!r}; "
            f"known: {sorted(m)}")
    return m[name]


def serving_scenario_map() -> Dict[str, ServingScenario]:
    return {sc.name: sc for sc in serving_scenarios()}


def get_serving_scenario(name: str) -> ServingScenario:
    m = serving_scenario_map()
    if name not in m:
        raise KeyError(
            f"unknown serving scenario {name!r}; known: {sorted(m)}")
    return m[name]


def scenario_map() -> Dict[str, Scenario]:
    return {sc.name: sc for sc in default_scenarios()}


def get_scenario(name: str) -> Scenario:
    m = scenario_map()
    if name not in m:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(m)}")
    return m[name]
