"""Deterministic, shardable synthetic token pipeline.

Production shape without production data: an order-1 Markov stream with
a per-(host, cursor) seeded generator, so

  * every data-parallel shard reads a disjoint deterministic slice,
  * a restart from a checkpointed ``cursor`` reproduces the exact stream,
  * the chain has enough structure that a ~100M model's loss visibly
    drops within a few hundred steps (examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_size: int              # per-host batch
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    cursor: int = 0              # number of batches already emitted
    latent_k: int = 0            # latent alphabet size (0 -> min(256, V))

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # order-1 Markov structure over a small latent alphabet, embedded
        # into the vocab by a fixed injective map (so the conditional
        # structure is learnable within a few hundred steps)
        k = self.latent_k or min(256, self.vocab_size)
        k = min(k, self.vocab_size)
        raw = rng.dirichlet(np.full(k, 0.05), size=k)
        self._trans = raw / raw.sum(1, keepdims=True)
        self._k = k
        self._vocab_map = rng.permutation(self.vocab_size)[:k]

    def _batch_rng(self, cursor: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, self.host_id, self.num_hosts, cursor))

    def next_batch(self) -> dict:
        """Returns {"tokens": [B, S+1] int32} and advances the cursor."""
        rng = self._batch_rng(self.cursor)
        B, S, k = self.batch_size, self.seq_len, self._k
        toks = np.empty((B, S + 1), np.int64)
        state = rng.integers(0, k, size=B)
        toks[:, 0] = state
        # vectorized Markov walk via inverse-CDF sampling
        cdf = np.cumsum(self._trans, axis=1)
        for t in range(1, S + 1):
            u = rng.random(B)
            state = (cdf[state] < u[:, None]).sum(1)
            toks[:, t] = state
        toks = self._vocab_map[toks]
        self.cursor += 1
        return {"tokens": toks.astype(np.int32)}

    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed,
                "host_id": self.host_id, "num_hosts": self.num_hosts}

    @classmethod
    def from_state(cls, vocab_size: int, seq_len: int, batch_size: int,
                   state: dict) -> "TokenPipeline":
        return cls(vocab_size=vocab_size, seq_len=seq_len,
                   batch_size=batch_size, seed=state["seed"],
                   host_id=state["host_id"], num_hosts=state["num_hosts"],
                   cursor=state["cursor"])
