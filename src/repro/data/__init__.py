"""data: synthetic generators (paper §5.1) + LM token pipeline."""
