"""Seed-spreader synthetic data generator (Gan & Tao, used by paper §5.1).

Maintains a current location; emits points uniformly in the vicinity of
the location, drifts after each burst, and with some probability restarts
at a random location (forming a new cluster).  ``varden`` scales each
cluster's vicinity radius (and thus density) by a random factor.  A small
fraction of uniform noise is added.  Domain is [0, 1e5]^d, matching the
paper's normalization to the integer domain [0, 10^5].
"""

from __future__ import annotations

import numpy as np

DOMAIN = 1e5


def seed_spreader(n: int, d: int, *, variant: str = "simden",
                  restarts: int = 10, c_reset: int = 100,
                  r_vicinity: float = 200.0, r_shift: float = 75.0,
                  noise_frac: float = 0.001,
                  seed: int = 0) -> np.ndarray:
    """Generate n points in [0, DOMAIN]^d with `restarts` clusters."""
    rng = np.random.default_rng(seed)
    n_noise = int(n * noise_frac)
    n_sig = n - n_noise
    p_restart = restarts / max(n_sig // c_reset, 1)
    loc = rng.uniform(0, DOMAIN, size=d)
    rv = r_vicinity
    out = np.empty((n_sig, d), dtype=np.float64)
    i = 0
    while i < n_sig:
        if rng.uniform() < p_restart:
            loc = rng.uniform(0, DOMAIN, size=d)
            if variant == "varden":
                rv = r_vicinity * float(rng.uniform(0.3, 4.0))
        m = min(c_reset, n_sig - i)
        delta = rng.uniform(-rv, rv, size=(m, d))
        out[i:i + m] = np.clip(loc[None, :] + delta, 0, DOMAIN)
        i += m
        loc = np.clip(loc + rng.uniform(-r_shift, r_shift, size=d) *
                      (rv / r_vicinity), 0, DOMAIN)
    noise = rng.uniform(0, DOMAIN, size=(n_noise, d))
    pts = np.concatenate([out, noise], axis=0)
    rng.shuffle(pts)
    return pts
