"""Model zoo: 10 assigned architectures behind one pure-function API."""

from .config import LMConfig, MoECfg
from .lm import (init_params, forward, loss_fn, init_cache, prefill,
                 decode_step, count_params, active_params, encode)
