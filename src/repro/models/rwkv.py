"""RWKV6 "Finch" blocks (rwkv6-3b): attention-free, data-dependent decay.

TPU adaptation: the per-timestep recurrence

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)

is evaluated *chunkwise* (GLA/FLA-style): within a chunk of length C the
intra-chunk term becomes masked matmuls against cumulative log-decays,
and a ``lax.scan`` carries the [H, Dk, Dv] state across chunks.  This
turns a length-S sequential scan into S/C MXU-friendly steps -- the same
"prune work via structure" insight the paper applies to distance
calculations, applied to a recurrence.

Numerics: decays are computed in log space; per-step log-decay is clamped
at ``LOG_DECAY_MIN`` so intra-chunk exp() factors stay inside f32 range
(documented deviation; contributions below e^{LOG_DECAY_MIN} per step are
zero in bf16 anyway).  ``rwkv_sequential`` is the exact oracle used by
tests.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import LMConfig
from .layers import dense_init, split, rms_norm

LOG_DECAY_MIN = -5.0
LORA_DIM = 64


def rwkv_time_mix_params(cfg: LMConfig, key) -> dict:
    d = cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    ks = split(key, 8)
    H = cfg.num_heads
    Dh = d // H
    return {
        # token-shift interpolation coefficients for r,k,v,g,w
        "mu": jnp.full((5, d), 0.5, pd),
        "w_r": dense_init(ks[0], d, d, pd),
        "w_k": dense_init(ks[1], d, d, pd),
        "w_v": dense_init(ks[2], d, d, pd),
        "w_g": dense_init(ks[3], d, d, pd),
        "w_o": dense_init(ks[4], d, d, pd),
        # data-dependent decay: w0 + tanh(x A) B   (low-rank lora)
        "w0": jnp.full((d,), -0.6, pd),
        "dec_a": dense_init(ks[5], d, LORA_DIM, pd, scale=0.01),
        "dec_b": dense_init(ks[6], LORA_DIM, d, pd, scale=0.01),
        "u": (jax.random.normal(ks[7], (H, Dh), jnp.float32) * 0.1
              ).astype(pd),
        "ln_scale": jnp.ones((d,), pd),   # per-head group norm on wkv out
    }


def rwkv_channel_mix_params(cfg: LMConfig, key) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = split(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, pd),
        "w_k": dense_init(k1, d, ff, pd),
        "w_v": dense_init(k2, ff, d, pd),
        "w_r": dense_init(k3, d, d, pd),
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Previous-token features; ``last`` [B, d] seeds position 0 (decode)."""
    if last is None:
        last = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _decays(p: dict, xw: jnp.ndarray) -> jnp.ndarray:
    """log-decay per channel, clamped. xw: [B, S, d] -> [B, S, d] (f32, <0)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["dec_a"].astype(jnp.float32)
                    ) @ p["dec_b"].astype(jnp.float32)
    lw = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + lora, -8.0, 4.0))
    return jnp.clip(lw, LOG_DECAY_MIN, -1e-4)


def _wkv_chunk(r, k, v, lw, u, state):
    """One chunk of the WKV recurrence.

    r/k/v: [B, C, H, Dh(k|v)] f32; lw: [B, C, H, Dk] f32 log decays;
    u: [H, Dk]; state: [B, H, Dk, Dv].
    Returns (y [B, C, H, Dv], new state).
    """
    B, C, H, Dk = k.shape
    L = jnp.cumsum(lw, axis=1)                 # inclusive
    Lm1 = L - lw                               # exclusive
    r_t = r * jnp.exp(Lm1)                     # <= |r|
    k_s = k * jnp.exp(-L)                      # bounded by clamp
    scores = jnp.einsum("bthi,bshi->bhts", r_t, k_s)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)   # strictly s < t
    scores = jnp.where(mask[None, None], scores, 0.0)
    y = jnp.einsum("bhts,bshj->bthj", scores, v)
    # current-token bonus
    bonus = jnp.einsum("bthi,bthi,hi->bth", r, k, u)
    y = y + bonus[..., None] * v
    # state contribution
    y = y + jnp.einsum("bthi,bhij->bthj", r_t, state)
    # state update
    decay_all = jnp.exp(L[:, -1])              # [B, H, Dk]
    k_rem = k_s * decay_all[:, None]           # k * exp(L_C - L_s)
    new_state = state * decay_all[..., None] + \
        jnp.einsum("bshi,bshj->bhij", k_rem, v)
    return y, new_state


def rwkv_time_mix(cfg: LMConfig, p: dict, x: jnp.ndarray,
                  state: Optional[dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, d]. state (decode): {"wkv": [B, H, Dk, Dv], "shift": [B, d]}."""
    B, S, d = x.shape
    H = cfg.num_heads
    Dh = d // H
    last = state["shift"] if state is not None else None
    xs = _token_shift(x, last)
    xr, xk, xv, xg, xw = (_mix(x, xs, p["mu"][i]) for i in range(5))
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"].astype(x.dtype))
    lw = _decays(p, xw).reshape(B, S, H, Dh)
    u = p["u"].astype(jnp.float32)

    s0 = state["wkv"].astype(jnp.float32) if state is not None else \
        jnp.zeros((B, H, Dh, Dh), jnp.float32)

    C = min(cfg.chunk_size, S)
    if S % C == 0 and S > 1:
        nc = S // C

        def step(carry, inp):
            rc, kc, vc, lwc = inp
            y, new = _wkv_chunk(rc, kc, vc, lwc, u, carry)
            return new, y

        resh = lambda a: a.reshape(B, nc, C, H, Dh).transpose(1, 0, 2, 3, 4)
        s_fin, ys = jax.lax.scan(step, s0, (resh(r), resh(k), resh(v), resh(lw)))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)
    else:
        y, s_fin = _wkv_chunk(r, k, v, lw, u, s0)

    # per-head group norm, gate, output projection
    y = y.reshape(B, S, H, Dh)
    yn = rms_norm(y.reshape(B * S * H, Dh),
                  jnp.zeros((Dh,), jnp.float32), cfg.norm_eps)
    y = (yn.reshape(B, S, d) * p["ln_scale"].astype(jnp.float32)
         ).astype(x.dtype) * g
    out = y @ p["w_o"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"wkv": s_fin.astype(state["wkv"].dtype),
                     "shift": x[:, -1]}
    return out, new_state


def rwkv_channel_mix(cfg: LMConfig, p: dict, x: jnp.ndarray,
                     state: Optional[dict] = None
                     ) -> Tuple[jnp.ndarray, Optional[dict]]:
    last = state["shift"] if state is not None else None
    xs = _token_shift(x, last)
    xk = _mix(x, xs, p["mu"][0])
    xr = _mix(x, xs, p["mu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["w_r"].astype(x.dtype)) * \
        (k @ p["w_v"].astype(x.dtype))
    new_state = {"shift": x[:, -1]} if state is not None else None
    return out, new_state


# --------------------------------------------------------------------------
# sequential oracle (tests)
# --------------------------------------------------------------------------

def wkv_sequential(r, k, v, lw, u, state):
    """Step-by-step WKV recurrence; same signature as _wkv_chunk."""
    def step(s, inp):
        rt, kt, vt, lwt = inp                          # [B, H, D*]
        w = jnp.exp(lwt)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       s + u[None, :, :, None] * kv)
        s = s * w[..., None] + kv
        return s, y

    tr = lambda a: a.transpose(1, 0, 2, 3)
    s_fin, ys = jax.lax.scan(step, state, (tr(r), tr(k), tr(v), tr(lw)))
    return ys.transpose(1, 0, 2, 3), s_fin
