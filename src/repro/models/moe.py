"""Mixture-of-Experts FFN (mixtral 8e top-2, arctic 128e top-2 + dense).

TPU adaptation: sort-based (MegaBlocks-style) dispatch with a static
per-expert capacity rather than the [T, E, C] one-hot dispatch einsum
(which is O(T*E*C) memory -- infeasible at T=1M tokens, E=128).

  1. top-k routing (f32 softmax over router logits),
  2. flat (token, choice) list sorted by expert id; position-in-expert by
     rank arithmetic,
  3. gather tokens into a dense [E, C, d] buffer (capacity-dropped tokens
     fall into a zero row),
  4. batched expert GLU FFN: einsums with the E axis sharded over the
     'model'/'expert' mesh axis,
  5. weighted scatter-add back to token positions.

Load-balancing auxiliary loss follows the switch-transformer formulation.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import LMConfig
from .layers import dense_init, split
from .sharding_ctx import constrain, get_shardmap_moe


def moe_params(cfg: LMConfig, key) -> dict:
    m = cfg.moe
    d = cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    k_r, k_g, k_u, k_d = split(key, 4)
    p = {
        "router": dense_init(k_r, d, m.num_experts, pd, scale=0.02),
        "w_gate": jax.vmap(lambda k: dense_init(k, d, m.d_ff, pd))(
            jax.random.split(k_g, m.num_experts)),
        "w_up": jax.vmap(lambda k: dense_init(k, d, m.d_ff, pd))(
            jax.random.split(k_u, m.num_experts)),
        "w_down": jax.vmap(lambda k: dense_init(k, m.d_ff, d, pd))(
            jax.random.split(k_d, m.num_experts)),
    }
    return p


def capacity(cfg: LMConfig, num_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * num_tokens / m.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(cfg: LMConfig, p: dict, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> (y [B, S, d], aux_loss scalar f32)."""
    ctx = get_shardmap_moe()
    if ctx is not None:
        mesh, batch_axes, model_axis = ctx
        sizes = dict(mesh.shape)
        n_data = 1
        for a in batch_axes:
            n_data *= sizes[a]
        if n_data > 1 and cfg.moe.num_experts % n_data == 0 and \
                cfg.moe.d_ff % sizes[model_axis] == 0:
            return moe_forward_shardmap_ep(cfg, p, x, *ctx)
        return moe_forward_shardmap(cfg, p, x, *ctx)
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    C = capacity(cfg, T)
    xf = x.reshape(T, d)

    # ---- routing (f32) ----
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    top_p, top_e = jax.lax.top_k(probs, K)                     # [T, K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (switch-style)
    me = probs.mean(axis=0)                                    # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T * K))
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    flat_e = top_e.reshape(T * K)                              # expert of choice
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)     # token of choice
    flat_w = top_p.reshape(T * K).astype(x.dtype)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.cumsum(counts) - counts                      # [E]
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - offsets[se]
    keep = pos_in_e < C                                        # capacity drop
    slot = jnp.where(keep, se * C + pos_in_e, E * C)           # pad slot

    # gather tokens into expert buffers (+1 zero pad row)
    tok_for_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(keep, st, T))[:E * C]
    w_for_slot = jnp.zeros((E * C + 1,), x.dtype).at[slot].set(
        jnp.where(keep, sw, 0))[:E * C]
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), x.dtype)])
    expert_in = xpad[tok_for_slot].reshape(E, C, d)
    # steer GSPMD toward all-to-all dispatch (expert axis over 'model')
    # instead of all-gathering x across the model axis (§Perf lever; the
    # launcher enables the "moe_ecd" tag when experts are model-sharded)
    expert_in = constrain(expert_in, "moe_ecd")

    # ---- batched expert FFN (E axis shardable over 'model') ----
    # "moe_w_in"/"moe_w_out" re-lay the *compute* copy of the FSDP-stored
    # weights (Megatron column/row-parallel): a per-layer weight
    # all-gather over 'data' replaces the (much larger) activation
    # all-reduce GSPMD otherwise inserts for the d-contraction (§Perf).
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    wg = constrain(p["w_gate"].astype(x.dtype), "moe_w_in")
    wu = constrain(p["w_up"].astype(x.dtype), "moe_w_in")
    wd = constrain(p["w_down"].astype(x.dtype), "moe_w_out")
    h = act(jnp.einsum("ecd,edf->ecf", expert_in, wg)) * \
        jnp.einsum("ecd,edf->ecf", expert_in, wu)
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd)             # [E, C, d]
    expert_out = constrain(expert_out, "moe_ecd")

    # ---- weighted combine ----
    flat_out = expert_out.reshape(E * C, d) * w_for_slot[:, None]
    y = jnp.zeros((T + 1, d), x.dtype).at[tok_for_slot].add(flat_out)[:T]
    return y.reshape(B, S, d), aux


def moe_forward_shardmap_ep(cfg: LMConfig, p: dict, x: jnp.ndarray,
                            mesh, batch_axes, model_axis
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE: experts sharded over 'data', FFN dim over
    'model' -- the GShard/DeepSpeed all-to-all pattern (§Perf, arctic).

    Storage == compute layout (see sharding.param_pspec with moe_ep), so
    there is NO per-layer weight gather.  Per layer the only collectives
    are two token all-to-alls over 'data' (top-k token copies, not full
    activations) and the ff-slice psum over 'model':

      1. each data shard buckets its tokens by destination shard
         (= owner row of the routed expert) into [n_data, E_loc, C, d];
      2. all-to-all over 'data' delivers [n_data(source), E_loc, C, d];
      3. local batched FFN on the chip's [E_loc, ff/n_model] slice;
      4. reverse all-to-all returns outputs to each token's home shard,
         which combines with its locally-kept slot->token map;
      5. psum over 'model' sums the ff slices.

    Requires E % n_data == 0 and ff % n_model == 0.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    sizes = dict(mesh.shape)
    n_model = sizes[model_axis]
    n_data = 1
    for a in batch_axes:
        n_data *= sizes[a]
    assert E % n_data == 0 and m.d_ff % n_model == 0
    E_loc = E // n_data
    assert B % n_data == 0
    B_loc = B // n_data
    T_loc = B_loc * S
    # capacity per (source shard, expert)
    C = max(8, int(np.ceil(m.capacity_factor * K * T_loc / E / 8)) * 8)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

    def body(xb, router, wg, wu, wd):
        # xb [B_loc, S, d]; wg/wu [E_loc, d, ff_loc]; wd [E_loc, ff_loc, d]
        xf = xb.reshape(T_loc, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
            1.0 / (T_loc * K))
        aux = m.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, batch_axes)

        # bucket my tokens into [n_data(dest), E_loc, C] slots
        flat_e = top_e.reshape(T_loc * K)              # global expert id
        flat_t = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), K)
        flat_w = top_p.reshape(T_loc * K).astype(xb.dtype)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(T_loc * K, dtype=jnp.int32) - offsets[se]
        keep = pos < C
        slot = jnp.where(keep, se * C + pos, E * C)    # (dest,e_loc,c) flat
        tok = jnp.full((E * C + 1,), T_loc, jnp.int32).at[slot].set(
            jnp.where(keep, st, T_loc))[:E * C]
        w_slot = jnp.zeros((E * C + 1,), xb.dtype).at[slot].set(
            jnp.where(keep, sw, 0))[:E * C]
        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xb.dtype)])
        send = xpad[tok].reshape(n_data, E_loc * C, d)

        # ---- all-to-all over the (possibly multi-name) data axes ----
        recv = jax.lax.all_to_all(send, batch_axes, split_axis=0,
                                  concat_axis=0)       # [n_data(src), ...]
        expert_in = recv.reshape(n_data, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, n_data * C, d)
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, wg)) * \
            jnp.einsum("ecd,edf->ecf", expert_in, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)        # [E_loc, n_data*C, d]
        back = out.reshape(E_loc, n_data, C, d).transpose(1, 0, 2, 3) \
            .reshape(n_data, E_loc * C, d)
        ret = jax.lax.all_to_all(back, batch_axes, split_axis=0,
                                 concat_axis=0)        # my slots again
        flat_out = ret.reshape(E * C, d) * w_slot[:, None]
        y = jnp.zeros((T_loc + 1, d), xb.dtype).at[tok].add(flat_out)[:T_loc]
        y = jax.lax.psum(y, model_axis)                # sum ff slices
        return y.reshape(B_loc, S, d), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P(batch_axes, None, model_axis),
                  P(batch_axes, None, model_axis),
                  P(batch_axes, model_axis, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_rep=False)
    return fn(x, p["router"],
              p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
              p["w_down"].astype(x.dtype))


def moe_forward_shardmap(cfg: LMConfig, p: dict, x: jnp.ndarray,
                         mesh, batch_axes, model_axis
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Manual-SPMD MoE (the beyond-paper collective fix, §Perf).

    Key insight: under the (data, model) mesh, activations are already
    *replicated over the model axis* within each data shard, so every
    model shard can locally bucket the tokens destined for the experts
    it owns -- dispatch needs NO communication at all.  The only
    collective is one psum of the combined output over 'model' (the
    Megatron row-parallel reduction), replacing the activation
    all-reduces / replicating gathers GSPMD derives from the global-sort
    formulation in ``moe_forward``.

    Experts map onto the model axis as ``V = max(E, n_model)`` virtual
    experts: E >= n_model shards whole experts (arctic 128/16); E <
    n_model splits each expert's FFN dim into ``n_model/E`` column
    halves (mixtral 8 -> 16), whose partial down-projections the same
    psum recombines exactly.

    Capacity is per (shard, expert) -- drops differ slightly from the
    global-capacity reference; equivalence at high capacity_factor is
    tested.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    n_model = dict(mesh.shape)[model_axis]
    n_batch = 1
    for a in batch_axes:
        n_batch *= dict(mesh.shape)[a]
    assert B % n_batch == 0
    B_loc = B // n_batch
    T_loc = B_loc * S
    if E % n_model == 0:
        split, v_loc = 1, E // n_model
    else:
        assert n_model % E == 0, (E, n_model)
        split, v_loc = n_model // E, 1
    ff = m.d_ff
    assert ff % split == 0
    ff_v = ff // split
    C = max(8, int(np.ceil(m.capacity_factor * K * T_loc / E / 8)) * 8)

    # virtual-expert weight layout [V, d|ff_v, ...] built in GSPMD land;
    # the shard_map in_spec places V on 'model' (a per-layer weight gather
    # over 'data' where the stored layout was FSDP-sharded).
    def to_virtual(w, axis):           # axis: which dim holds ff
        if split == 1:
            return w
        if axis == 2:                  # [E, d, ff] -> [V, d, ff_v]
            return w.reshape(E, d, split, ff_v).transpose(0, 2, 1, 3) \
                .reshape(E * split, d, ff_v)
        # [E, ff, d] -> [V, ff_v, d]
        return w.reshape(E, split, ff_v, d).reshape(E * split, ff_v, d)

    wg = to_virtual(p["w_gate"].astype(x.dtype), 2)
    wu = to_virtual(p["w_up"].astype(x.dtype), 2)
    wd = to_virtual(p["w_down"].astype(x.dtype), 1)
    router = p["router"]
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

    def body(xb, router, wg, wu, wd):
        j = jax.lax.axis_index(model_axis)
        xf = xb.reshape(T_loc, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
            1.0 / (T_loc * K))
        aux = m.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, batch_axes)

        flat_e = top_e.reshape(T_loc * K)
        flat_t = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), K)
        flat_w = top_p.reshape(T_loc * K).astype(xb.dtype)
        if split == 1:
            e0 = j * v_loc
            local_e = flat_e - e0
            mine = (flat_e >= e0) & (flat_e < e0 + v_loc)
        else:
            local_e = jnp.zeros_like(flat_e)
            mine = flat_e == j // split
        key = jnp.where(mine, local_e, v_loc)
        order = jnp.argsort(key, stable=True)
        se, st, sw = key[order], flat_t[order], flat_w[order]
        counts = jnp.zeros((v_loc + 1,), jnp.int32).at[key].add(1)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(T_loc * K, dtype=jnp.int32) - offsets[se]
        keep = (se < v_loc) & (pos < C)
        slot = jnp.where(keep, se * C + pos, v_loc * C)
        tok = jnp.full((v_loc * C + 1,), T_loc, jnp.int32).at[slot].set(
            jnp.where(keep, st, T_loc))[:v_loc * C]
        w_slot = jnp.zeros((v_loc * C + 1,), xb.dtype).at[slot].set(
            jnp.where(keep, sw, 0))[:v_loc * C]
        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xb.dtype)])
        expert_in = xpad[tok].reshape(v_loc, C, d)
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, wg)) * \
            jnp.einsum("ecd,edf->ecf", expert_in, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        flat_out = out.reshape(v_loc * C, d) * w_slot[:, None]
        y = jnp.zeros((T_loc + 1, d), xb.dtype).at[tok].add(flat_out)[:T_loc]
        y = jax.lax.psum(y, model_axis)
        return y.reshape(B_loc, S, d), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_rep=False)
    return fn(x, router, wg, wu, wd)


def moe_forward_dense_fallback(cfg: LMConfig, p: dict, x: jnp.ndarray
                               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: computes every expert densely and mixes by router weights.

    O(T * E * ff) compute -- only for tests of the sparse dispatch path.
    """
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], top_e].set(top_p)    # [T, E]
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(x.dtype))) * \
        jnp.einsum("td,edf->tef", xf, p["w_up"].astype(x.dtype))
    out = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("ted,te->td", out, w.astype(x.dtype))
    return y.reshape(B, S, d), jnp.zeros((), jnp.float32)
