"""Activation-sharding context.

The model code is mesh-agnostic: it calls ``constrain(x, tag)`` at the
few points where GSPMD needs a nudge (residual stream, attention heads,
MoE expert buffers, logit chunks).  The launcher installs a tag ->
PartitionSpec mapping before tracing; on CPU / in unit tests the mapping
is empty and ``constrain`` is the identity.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_SPECS: Dict[str, PartitionSpec] = {}
_SHARDMAP_MOE = None      # (mesh, batch_axes tuple, model_axis name) | None


def set_policy(specs: Optional[Dict[str, PartitionSpec]]) -> None:
    global _SPECS
    _SPECS = dict(specs or {})


def set_shardmap_moe(ctx) -> None:
    """Enable the manual-SPMD MoE path: ctx = (mesh, batch_axes,
    model_axis) or None to disable."""
    global _SHARDMAP_MOE
    _SHARDMAP_MOE = ctx


def get_shardmap_moe():
    return _SHARDMAP_MOE


def get_policy() -> Dict[str, PartitionSpec]:
    return dict(_SPECS)


@contextlib.contextmanager
def policy(specs: Optional[Dict[str, PartitionSpec]]):
    old = get_policy()
    set_policy(specs)
    try:
        yield
    finally:
        set_policy(old)


def constrain(x: jax.Array, tag: str) -> jax.Array:
    spec = _SPECS.get(tag)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
