"""Shared transformer building blocks (pure functions, params as dicts).

Conventions
-----------
* Parameters are nested dicts of jnp arrays; a *stack* of layers holds the
  same dict with a leading layer axis on every leaf (for ``lax.scan``).
* Activations run in ``cfg.dtype`` (bf16 by default); norms/softmax in f32.
* Attention has four execution paths (``cfg.attn_impl``):
    direct -- full [Sq, Sk] logits; small sequences.
    rect   -- lax.scan over KV chunks, online softmax. O(chunk) memory but
              rectangular FLOPs (computes masked-out blocks).
    tri    -- static block-pair schedule covering only the causal band:
              exact triangular FLOPs (the beyond-paper hillclimb lever).
    banded -- sliding-window band schedule: O(S * window) FLOPs for SWA /
              gemma2-local layers; required for mixtral long-context.
  ``auto`` picks direct for short seqs, banded when a window is set, and
  rect otherwise (paper-faithful XLA baseline).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import LMConfig

NEG_INF = -1e30


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jnp.ndarray:
    scale = scale if scale is not None else (1.0 / math.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def split(key, n: int):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_params(cfg: LMConfig) -> dict:
    if cfg.norm == "rms":
        return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32),
            "bias": jnp.zeros((cfg.d_model,), jnp.float32)}


def apply_norm(cfg: LMConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"], cfg.norm_eps)
    return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(cfg: LMConfig) -> jnp.ndarray:
    rot = int(cfg.head_dim * cfg.rope_fraction) // 2 * 2
    return 1.0 / (cfg.rope_theta ** (
        jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               freqs: jnp.ndarray) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] (or [S]); rotate first 2*|freqs| dims."""
    rot = 2 * freqs.shape[0]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# attention cores (all take q [B, H, Sq, D], k/v [B, H, Sk, D])
# --------------------------------------------------------------------------

def _mask_logits(logits, qpos, kpos, causal, window, sk_valid=None):
    mask = jnp.ones(logits.shape[-2:], bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & ((qpos - kpos) < window)
    if sk_valid is not None:
        mask = mask & sk_valid
    return jnp.where(mask[None, None], logits, NEG_INF)


def _soft_cap(logits, cap):
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


def attn_direct(q, k, v, *, causal, window, softcap, scale, q_offset=0,
                logit_dtype=jnp.float32):
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=logit_dtype
                        ).astype(jnp.float32) * scale
    logits = _soft_cap(logits, softcap)
    Sq, Sk = q.shape[2], k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    logits = _mask_logits(logits, qpos, kpos, causal, window)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def attn_rect(q, k, v, *, causal, window, softcap, scale, chunk, q_offset=0,
              logit_dtype=jnp.float32):
    """Online-softmax scan over KV chunks (flash semantics, jnp)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    nc = Sk // chunk
    kc = k.reshape(B, H, nc, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, chunk, D).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(Sq)[:, None] + q_offset

    def step(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kj,
                            preferred_element_type=logit_dtype
                            ).astype(jnp.float32) * scale
        logits = _soft_cap(logits, softcap)
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        logits = _mask_logits(logits, qpos, kpos, causal, window)
        m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, H, Sq, 1), jnp.float32),
            jnp.zeros((B, H, Sq, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (jnp.arange(nc), kc, vc))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attn_tri(q, k, v, *, causal, softcap, scale, chunk, q_offset=0,
             logit_dtype=jnp.float32):
    """Causal attention over the static lower-triangular block schedule.

    Exact triangular FLOPs: scans a flat list of (qi, kj) block pairs with
    kj <= qi (assumes q/k aligned: q_offset == Sk - Sq and both chunked the
    same).  Beyond-paper optimization lever for §Perf.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    nq, nk = Sq // chunk, Sk // chunk
    shift = (Sk - Sq) // chunk        # q block i aligns to k block i+shift
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if j <= i + shift]
    pairs = jnp.asarray(pairs, jnp.int32)            # [P, 2]
    qc = q.reshape(B, H, nq, chunk, D)
    kc = k.reshape(B, H, nk, chunk, D)
    vc = v.reshape(B, H, nk, chunk, D)

    def step(carry, pair):
        m, l, acc = carry              # [nq, B, H, chunk, 1/D]
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qc, i, 2, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 2, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 2, keepdims=False)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                            preferred_element_type=logit_dtype
                            ).astype(jnp.float32) * scale
        logits = _soft_cap(logits, softcap)
        qpos = i * chunk + jnp.arange(chunk)[:, None] + q_offset
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        logits = jnp.where((kpos <= qpos)[None, None], logits, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(mi - m_new)
        li = li * alpha + p.sum(-1, keepdims=True)
        ai = ai * alpha + jnp.einsum("bhqk,bhkd->bhqd",
                                     p.astype(vj.dtype), vj)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, li, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, ai, i, 0)
        return (m, l, acc), None

    init = (jnp.full((nq, B, H, chunk, 1), NEG_INF, jnp.float32),
            jnp.zeros((nq, B, H, chunk, 1), jnp.float32),
            jnp.zeros((nq, B, H, chunk, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, pairs)
    out = acc / jnp.maximum(l, 1e-30)                # [nq, B, H, chunk, D]
    return out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D).astype(q.dtype)


def attn_banded(q, k, v, *, window, softcap, scale, chunk, q_offset=0,
                logit_dtype=jnp.float32):
    """Sliding-window attention over the static band schedule.

    For each q block, gathers the fixed-width KV band [start, start + W')
    with W' = window rounded up to a chunk multiple plus one chunk; masks
    exactly. FLOPs O(Sq * (window + chunk)) -- sub-quadratic in S.
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    nq = Sq // chunk
    band = min(((window + chunk - 1) // chunk + 1) * chunk, Sk)
    qc = q.reshape(B, H, nq, chunk, D)

    def per_block(i):
        qi = qc[:, :, i]
        q_lo = i * chunk + q_offset
        start = jnp.clip(q_lo + chunk - 1 - (band - 1), 0, Sk - band)
        kj = jax.lax.dynamic_slice_in_dim(k, start, band, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, start, band, axis=2)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                            preferred_element_type=logit_dtype
                            ).astype(jnp.float32) * scale
        logits = _soft_cap(logits, softcap)
        qpos = q_lo + jnp.arange(chunk)[:, None]
        kpos = start + jnp.arange(band)[None, :]
        logits = _mask_logits(logits, qpos, kpos, True, window)
        p = jax.nn.softmax(logits, axis=-1).astype(vj.dtype)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vj)

    out = jax.lax.map(per_block, jnp.arange(nq))     # [nq, B, H, chunk, D]
    return out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              scale=None, impl="auto", chunk=1024, q_offset=None,
              logit_dtype=jnp.float32):
    """Dispatch across attention paths. q/k/v: [B, H, S, D]."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    if scale is None:
        scale = D ** -0.5
    if q_offset is None:
        q_offset = Sk - Sq
    if impl == "auto":
        if Sq == 1 or Sk <= 2 * chunk:
            impl = "direct"
        elif window is not None and window < Sk:
            impl = "banded"
        else:
            impl = "rect"
    ld = jnp.dtype(logit_dtype)
    if impl == "direct" or Sk < chunk or Sk % chunk:
        return attn_direct(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, q_offset=q_offset,
                           logit_dtype=ld)
    if impl == "banded" and window is not None:
        return attn_banded(q, k, v, window=window, softcap=softcap,
                           scale=scale, chunk=chunk, q_offset=q_offset,
                           logit_dtype=ld)
    if impl == "tri" and causal and Sq % chunk == 0:
        return attn_tri(q, k, v, causal=causal, softcap=softcap,
                        scale=scale, chunk=chunk, q_offset=q_offset,
                        logit_dtype=ld)
    return attn_rect(q, k, v, causal=causal, window=window, softcap=softcap,
                     scale=scale, chunk=chunk, q_offset=q_offset,
                     logit_dtype=ld)


# --------------------------------------------------------------------------
# GQA attention layer (params + forward incl. KV cache)
# --------------------------------------------------------------------------

def attn_params(cfg: LMConfig, key) -> dict:
    ks = split(key, 4)
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, pd),
        "wk": dense_init(ks[1], d, KV * Dh, pd),
        "wv": dense_init(ks[2], d, KV * Dh, pd),
        "wo": dense_init(ks[3], H * Dh, d, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), pd)
        p["bk"] = jnp.zeros((KV * Dh,), pd)
        p["bv"] = jnp.zeros((KV * Dh,), pd)
    return p


def _project_qkv(cfg: LMConfig, p: dict, x: jnp.ndarray):
    B, S, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(B, S, H, Dh), k.reshape(B, S, KV, Dh),
            v.reshape(B, S, KV, Dh))


def _broadcast_kv(k: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """[B, KV, S, D] -> [B, KV*q_per_kv, S, D]."""
    if q_per_kv == 1:
        return k
    B, KV, S, D = k.shape
    return jnp.broadcast_to(k[:, :, None], (B, KV, q_per_kv, S, D)
                            ).reshape(B, KV * q_per_kv, S, D)


def attn_forward(cfg: LMConfig, p: dict, x: jnp.ndarray, freqs: jnp.ndarray,
                 *, window: Optional[int], cache: Optional[dict] = None,
                 positions: Optional[jnp.ndarray] = None) -> tuple:
    """Self-attention with optional KV cache.

    cache (decode): {"k": [B, KV, S_cache, Dh], "v": same, "pos": [] int32}.
    If ``window`` is set the cache is a ring buffer of size min(S_cache,
    window rounded to S_cache).  Returns (out [B, S, d], new_cache).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    if positions is None:
        if cache is not None:
            positions = cache["pos"] + jnp.arange(S)[None, :]
        else:
            positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, freqs)
    k = apply_rope(k, positions, freqs)
    q = q.transpose(0, 2, 1, 3)         # [B, H, S, Dh]
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None:
        # Cache layout: when ``window`` is set the cache was allocated as a
        # ring buffer with S_c <= window entries (init_cache), so every
        # live entry is inside the window by construction and only a
        # validity mask is needed.  RoPE is applied pre-cache with absolute
        # positions, so ring rotation does not disturb relative phases.
        S_c = cache["k"].shape[2]
        ring = window is not None
        if S == 1:
            slot = (cache["pos"] % S_c) if ring else cache["pos"]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=2)
        else:                       # prefill into an empty cache
            if S >= S_c:            # keep the trailing window
                ck = k[:, :, S - S_c:]
                cv = v[:, :, S - S_c:]
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=2)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=2)
        new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + S}
        if S == 1:
            # decode: attend over the valid cached prefix
            kk = _broadcast_kv(ck, cfg.q_per_kv)
            vv = _broadcast_kv(cv, cfg.q_per_kv)
            idx = jnp.arange(S_c)
            valid = (idx <= cache["pos"]) | (cache["pos"] >= S_c)
            out = _masked_decode_attn(cfg, q, kk, vv, valid,
                                      softcap=cfg.attn_softcap)
        else:
            kk = _broadcast_kv(k, cfg.q_per_kv)
            vv = _broadcast_kv(v, cfg.q_per_kv)
            out = attention(q, kk, vv, causal=True, window=window,
                            softcap=cfg.attn_softcap, impl=cfg.attn_impl,
                            chunk=cfg.attn_chunk,
                            logit_dtype=cfg.logit_dtype)
    else:
        kk = _broadcast_kv(k, cfg.q_per_kv)
        vv = _broadcast_kv(v, cfg.q_per_kv)
        out = attention(q, kk, vv, causal=True, window=window,
                        softcap=cfg.attn_softcap, impl=cfg.attn_impl,
                        chunk=cfg.attn_chunk, logit_dtype=cfg.logit_dtype)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return out @ p["wo"].astype(out.dtype), new_cache


def _masked_decode_attn(cfg, q, k, v, valid, softcap=None):
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * cfg.head_dim ** -0.5
    logits = _soft_cap(logits, softcap)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_params(cfg: LMConfig, key, d_ff: Optional[int] = None) -> dict:
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    if cfg.mlp_kind == "glu":
        k1, k2, k3 = split(key, 3)
        return {"w_gate": dense_init(k1, d, ff, pd),
                "w_up": dense_init(k2, d, ff, pd),
                "w_down": dense_init(k3, ff, d, pd)}
    k1, k2 = split(key, 2)
    return {"w_up": dense_init(k1, d, ff, pd),
            "w_down": dense_init(k2, ff, d, pd)}


def mlp_forward(cfg: LMConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    if cfg.mlp_kind == "glu":
        h = act(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    else:
        h = act(x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)
