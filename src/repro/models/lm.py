"""Unified LM: init / forward / loss / cache / decode for every family.

Public surface used by the launcher, examples, and tests:

  init_params(cfg, key)                 -> params pytree
  forward(cfg, params, batch)           -> (hidden, aux) training forward
  loss_fn(cfg, params, batch)           -> (loss, metrics) chunked CE
  init_cache(cfg, batch, max_len, ...)  -> decode cache
  prefill(cfg, params, batch, cache)    -> (last logits, cache)
  decode_step(cfg, params, tokens, cache) -> (logits, cache)
  count_params(cfg)                     -> exact param count (eval_shape)

Batch dict keys: "tokens" [B, S+1] int32 always; "frames" [B, T, d]
(whisper stub frontend); "patches" [B, P, d] (internvl stub frontend).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import LMConfig
from . import layers as L
from .transformer import (group_layout, num_groups, stack_params,
                          stack_forward, block_params, init_block_cache)
from .sharding_ctx import constrain


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> dict:
    ks = L.split(key, 6)
    pd = jnp.dtype(cfg.param_dtype)
    layout = group_layout(cfg)
    G = num_groups(cfg)
    p = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(pd),
        "blocks": stack_params(cfg, ks[1], layout, G),
        "ln_f": L.norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, pd)
    if cfg.family == "hybrid":
        p["shared"] = block_params(cfg, "attn:full", ks[3])
    if cfg.family == "encdec":
        p["enc_blocks"] = stack_params(cfg, ks[4], ("enc_attn",),
                                       cfg.enc_layers)
        p["ln_enc"] = L.norm_params(cfg)
    return p


def count_params(cfg: LMConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(shapes))


def active_params(cfg: LMConfig) -> int:
    """Params touched per token (MoE: top-k experts only)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = (3 if cfg.mlp_kind == "glu" else 2) * cfg.d_model * m.d_ff
    inactive = cfg.num_layers * (m.num_experts - m.top_k) * per_expert
    return total - inactive


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def embed(cfg: LMConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "btd")


def unembed_weights(cfg: LMConfig, params: dict) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def logits_for(cfg: LMConfig, params: dict, h: jnp.ndarray) -> jnp.ndarray:
    w = unembed_weights(cfg, params).astype(h.dtype)
    # logit *buffer* in cfg.logit_dtype (perf lever); softcap/CE math in f32
    logits = jnp.einsum("...d,dv->...v", h, w,
                        preferred_element_type=jnp.dtype(cfg.logit_dtype)
                        ).astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, "btv")


# --------------------------------------------------------------------------
# forward (training / prefill trunk)
# --------------------------------------------------------------------------

def _frontend(cfg: LMConfig, params: dict, batch: dict) -> jnp.ndarray:
    """Token (+stub modality) embedding -> [B, S_total, d]."""
    tokens = batch["tokens"]
    x = embed(cfg, params, tokens)
    if cfg.family == "vlm" and "patches" in batch:
        patches = batch["patches"].astype(x.dtype)      # [B, P, d] stub
        x = jnp.concatenate([patches, x], axis=1)
    return x


def encode(cfg: LMConfig, params: dict, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over (stub) audio frame embeddings [B, T, d]."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x, _, _ = stack_forward(cfg, params["enc_blocks"], x, ("enc_attn",))
    return L.apply_norm(cfg, params["ln_enc"], x)


def forward(cfg: LMConfig, params: dict, batch: dict,
            cache: Optional[dict] = None):
    """Trunk forward. Returns (hidden [B, S, d], new_cache, aux)."""
    x = _frontend(cfg, params, batch)
    enc_out = None
    if cfg.family == "encdec" and "frames" in batch:
        enc_out = encode(cfg, params, batch["frames"])
    shared = params.get("shared")
    x, new_cache, aux = stack_forward(
        cfg, params["blocks"], x, group_layout(cfg),
        cache=cache, shared=shared, enc_out=enc_out)
    x = L.apply_norm(cfg, params["ln_f"], x)
    return x, new_cache, aux


# --------------------------------------------------------------------------
# loss (chunked CE; never materializes [B, S, V])
# --------------------------------------------------------------------------

def _ce_chunk(cfg, params, h, labels, mask):
    logits = logits_for(cfg, params, h)                  # [B, C, V] f32
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum(), mask.sum()


def loss_fn(cfg: LMConfig, params: dict, batch: dict):
    """Next-token CE. tokens [B, S+1]; optional loss_mask [B, S]."""
    tokens = batch["tokens"]
    inputs = {**batch, "tokens": tokens[:, :-1]}
    labels = tokens[:, 1:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    h, _, aux = forward(cfg, params, inputs)
    if cfg.family == "vlm" and "patches" in batch:
        h = h[:, batch["patches"].shape[1]:]             # text positions only
    B, S, _ = h.shape
    C = min(cfg.ce_chunk, S)
    if S % C == 0 and S > C:
        nc = S // C
        hs = h.reshape(B, nc, C, -1).swapaxes(0, 1)
        ls = labels.reshape(B, nc, C).swapaxes(0, 1)
        ms = mask.reshape(B, nc, C).swapaxes(0, 1)

        def step(carry, inp):
            tot, cnt = carry
            s, c = _ce_chunk(cfg, params, inp[0], inp[1], inp[2])
            return (tot + s, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            step, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    else:
        tot, cnt = _ce_chunk(cfg, params, h, labels, mask)
    loss = tot / jnp.maximum(cnt, 1.0)
    metrics = {"ce": loss, "aux": aux, "tokens": cnt}
    return loss + aux, metrics


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    layout = group_layout(cfg)
    G = num_groups(cfg)

    def one(kind):
        c = init_block_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape), c)

    return {"pos": jnp.zeros((), jnp.int32),
            "slots": tuple(one(k) for k in layout)}


def prefill(cfg: LMConfig, params: dict, batch: dict, cache: dict):
    """Run the prompt through the trunk, filling the cache.

    Returns (logits of the last position [B, V], cache).
    """
    if cfg.family == "encdec":
        cache = _fill_cross_kv(cfg, params, batch["frames"], cache)
        batch = {k: v for k, v in batch.items() if k != "frames"}
    h, cache, _ = forward(cfg, params, batch, cache=cache)
    logits = logits_for(cfg, params, h[:, -1:])[:, 0]
    return logits, cache


def _fill_cross_kv(cfg: LMConfig, params: dict, frames, cache):
    enc_out = encode(cfg, params, frames)
    KV, Dh = cfg.num_kv_heads, cfg.head_dim

    def per_group(gp, slot):
        p = gp["xattn"]
        B = enc_out.shape[0]
        k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(B, -1, KV, Dh)
        v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(B, -1, KV, Dh)
        slot = dict(slot)
        slot["xk"] = k.transpose(0, 2, 1, 3)
        slot["xv"] = v.transpose(0, 2, 1, 3)
        return slot

    slots = list(cache["slots"])
    slots[0] = jax.vmap(per_group)(params["blocks"][0], slots[0])
    return {**cache, "slots": tuple(slots)}


def decode_step(cfg: LMConfig, params: dict, tokens: jnp.ndarray,
                cache: dict):
    """One decode step. tokens [B] -> (logits [B, V], new cache)."""
    batch = {"tokens": tokens[:, None]}
    h, cache, _ = forward(cfg, params, batch, cache=cache)
    logits = logits_for(cfg, params, h)[:, 0]
    return logits, cache
