"""Mamba2 (SSD) blocks for the zamba2 hybrid backbone.

State-space recurrence with scalar-per-head decay:

    h_t = exp(dt_t * A_h) h_{t-1} + (dt_t * B_t) (x) x_t
    y_t = C_t . h_t + D_h * x_t

evaluated chunkwise (the SSD algorithm): scalar decays make the
intra-chunk term a [C, C] masked score matrix per head -- exp of log-decay
*differences*, so no overflow.  A ``lax.scan`` carries the
[B, H, d_state, d_head] state across chunks; decode is the O(1) update.

``mamba_sequential`` is the exact oracle used by the tests.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import LMConfig
from .layers import dense_init, split, rms_norm

NEG_INF = -1e30


def mamba_params(cfg: LMConfig, key) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    ns = cfg.ssm_state
    nh = cfg.n_ssm_heads
    pd = jnp.dtype(cfg.param_dtype)
    ks = split(key, 4)
    conv_ch = di + 2 * ns
    return {
        # in_proj -> [z, xc, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * di + 2 * ns + nh, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "a_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "w_out": dense_init(ks[2], di, d, pd),
        "gn_scale": jnp.ones((di,), pd),              # gated RMSNorm
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [B, S, ch], w: [W, ch].

    state (decode): [B, W-1, ch] trailing inputs. Returns (y, new_state).
    """
    B, S, ch = x.shape
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, W - 1, ch), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # [B, S+W-1, ch]
    y = sum(xp[:, i:i + S] * w[i].astype(x.dtype) for i in range(W))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):] if state is not None else None
    return y, new_state


def _ssd_chunk(xh, Bm, Cm, dt, la, state, score_dtype=jnp.float32):
    """One SSD chunk.

    xh: [B, C, H, P] values; Bm/Cm: [B, C, N] in/out mix; dt: [B, C, H];
    la: [B, C, H] log decay (<0); state: [B, H, N, P].
    ``score_dtype``: buffer dtype of the [B, C, C, H] score tensor -- the
    chunk's dominant HBM traffic; math stays f32 inside the fusion.
    Returns (y [B, C, H, P], new state).
    """
    L = jnp.cumsum(la, axis=1)                        # [B, C, H] inclusive
    # intra-chunk: scores[t,s] = exp(L_t - L_s) * (C_t.B_s) * dt_s, s <= t
    diff = L[:, :, None, :] - L[:, None, :, :]        # [B, C, C, H]
    C_len = xh.shape[1]
    mask = jnp.tril(jnp.ones((C_len, C_len), bool))
    diff = jnp.where(mask[None, :, :, None], diff, NEG_INF)
    cb = jnp.einsum("btn,bsn->bts", Cm, Bm)           # [B, C, C]
    scores = (jnp.exp(diff) * cb[..., None] * dt[:, None, :, :]
              ).astype(score_dtype)
    y = jnp.einsum("btsh,bshp->bthp", scores, xh.astype(score_dtype),
                   preferred_element_type=jnp.float32)
    # inter-chunk: y += exp(L_t) C_t . h0
    y = y + jnp.einsum("bth,btn,bhnp->bthp", jnp.exp(L), Cm, state)
    # state update
    decay_all = jnp.exp(L[:, -1])                     # [B, H]
    rem = jnp.exp(L[:, -1][:, None] - L)              # [B, C, H]
    upd = jnp.einsum("bsh,bsn,bshp->bhnp", rem * dt, Bm, xh)
    new_state = state * decay_all[:, :, None, None] + upd
    return y, new_state


def mamba_forward(cfg: LMConfig, p: dict, x: jnp.ndarray,
                  state: Optional[dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: [B, S, d]. state (decode): {"ssm": [B, H, N, P], "conv": [B, W-1, ch]}."""
    B, S, d = x.shape
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = di // nh
    proj = x @ p["w_in"].astype(x.dtype)
    z, xc, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + ns, 2 * di + 2 * ns], axis=-1)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"], p["conv_b"],
        state["conv"] if state is not None else None)
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [di, di + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B, S, H]
    A = -jnp.exp(p["a_log"])                                      # [H] < 0
    la = jnp.clip(dt * A[None, None, :], -30.0, -1e-6)            # log decay
    xh = xc.astype(jnp.float32).reshape(B, S, nh, P)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    s0 = state["ssm"].astype(jnp.float32) if state is not None else \
        jnp.zeros((B, nh, ns, P), jnp.float32)

    score_dtype = jnp.dtype(cfg.logit_dtype)
    C = min(cfg.chunk_size, S)
    if S % C == 0 and S > 1:
        nc = S // C

        def step(carry, inp):
            xci, bi, ci, dti, lai = inp
            y, new = _ssd_chunk(xci, bi, ci, dti, lai, carry,
                                score_dtype=score_dtype)
            return new, y

        r4 = lambda a: a.reshape(B, nc, C, *a.shape[2:]).swapaxes(0, 1)
        s_fin, ys = jax.lax.scan(
            step, s0, (r4(xh), r4(Bm), r4(Cm), r4(dt), r4(la)))
        y = ys.swapaxes(0, 1).reshape(B, S, nh, P)
    else:
        y, s_fin = _ssd_chunk(xh, Bm, Cm, dt, la, s0,
                              score_dtype=score_dtype)

    y = y + p["d_skip"][None, None, :, None] * xh                 # skip
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2) then out projection
    y = rms_norm(y * jax.nn.silu(z), p["gn_scale"].astype(jnp.float32) - 1.0,
                 cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"ssm": s_fin.astype(state["ssm"].dtype),
                     "conv": new_conv}
    return out, new_state


# --------------------------------------------------------------------------
# sequential oracle (tests)
# --------------------------------------------------------------------------

def ssd_sequential(xh, Bm, Cm, dt, la, state):
    """Step-by-step SSD recurrence; same contract as _ssd_chunk."""
    def step(s, inp):
        xt, bt, ct, dtt, lat = inp
        a = jnp.exp(lat)                                   # [B, H]
        upd = jnp.einsum("bh,bn,bhp->bhnp", dtt, bt, xt)
        s = s * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, s)
        return s, y

    tr = lambda a: a.swapaxes(0, 1)
    s_fin, ys = jax.lax.scan(step, state,
                             (tr(xh), tr(Bm), tr(Cm), tr(dt), tr(la)))
    return ys.swapaxes(0, 1), s_fin
